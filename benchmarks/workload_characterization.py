"""Paper §2.2 workload characterization, MEASURED from the running serving
engine (reduced compute model, deployment-scale memory accounting):
read:write ratio >1000:1, fully sequential reads, append-only writes,
KV bytes/token, weight-read amplification per token."""
from __future__ import annotations

import time

import jax
import numpy as np


def compute(arch="llama2-70b", requests=6, max_new=12) -> dict:
    from repro.configs import get_config, reduced
    from repro.core.memclass import HBM3E, MRM_RRAM
    from repro.core.simulator import MemorySystem
    from repro.models import init_params
    from repro.serving import EngineConfig, ServeEngine

    full = get_config(arch)
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 40), "hbm": (HBM3E, 1 << 37)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=3, max_cache_len=96,
                                   weight_tier="mrm", kv_tier="mrm",
                                   expected_session_s=30.0),
                      account_cfg=full)
    rng = np.random.default_rng(0)
    for _ in range(requests):
        eng.submit(list(rng.integers(2, cfg.vocab_size, rng.integers(8, 40))),
                   max_new)
    rep = eng.run_until_idle()
    mrm = rep["memory"]["tiers"]["mrm"]
    return {
        "steady_rw_ratio": rep["steady_rw_ratio"],
        "seq_read_fraction": mrm["seq_fraction"],
        "kv_bytes_per_token": full.kv_bytes_per_token(),
        "weight_read_bytes_per_token": eng.active_weight_bytes,
        "weight_to_kvwrite_amplification":
            eng.active_weight_bytes / full.kv_bytes_per_token(),
        "energy_per_token_j": rep["energy_per_token_j"],
        "tokens": rep["tokens_generated"],
        "refresh": rep["memory"]["refresh_stats"],
    }


def run(csv=True):
    t0 = time.perf_counter()
    out = compute()
    dt = (time.perf_counter() - t0) * 1e6
    if csv:
        for k in ("steady_rw_ratio", "seq_read_fraction", "kv_bytes_per_token",
                  "weight_to_kvwrite_amplification", "energy_per_token_j"):
            print(f"workload_char/{k},{dt:.1f},{out[k]:.4e}")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1, default=float))
