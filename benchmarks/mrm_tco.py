"""MRM vs HBM-only: placement feasibility, sustained memory power, capacity
cost, and tokens/joule for a llama2-70b-class inference machine (the
paper's 'tokens per dollar' §5 motivation, made concrete).

Also reports the reliability plane's density lever (DESIGN.md §11): per
MRM technology and retention state, the ECC check-bit overhead of the
domain-specific split codeword vs a uniform strict code — the domain code
must shrink on every demoted/cold/spilled state — plus the placement
solve re-run with ``ecc_profile="domain"`` so the check bits show up as a
metered capacity/bandwidth tenant."""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.core.ecc import STATE_RETENTION_FRAC, TierEcc
from repro.core.memclass import HBM3E, HOUR, LPDDR5X, MRM_MRAM, MRM_PCM, MRM_RRAM
from repro.core.tiering import DataClassProfile, Tier, solve_placement

DECODE_TOKENS_PER_S = 600.0


def _classes():
    cfg = get_config("llama2-70b")
    w_bytes = cfg.param_counts()["total"] * 2
    kv_tok = cfg.kv_bytes_per_token()
    # decode reads all weights + live KV per token (paper §2.2)
    read_bw_w = DECODE_TOKENS_PER_S * w_bytes / 64        # batch-64 amortized
    kv_live = 300e9
    read_bw_kv = DECODE_TOKENS_PER_S * kv_live / 64
    return [
        DataClassProfile("weights", w_bytes, read_bw_w, w_bytes / (24 * HOUR),
                         24 * HOUR, False),
        DataClassProfile("kv_cache", kv_live, read_bw_kv,
                         DECODE_TOKENS_PER_S * kv_tok * 12, 600, True),
        DataClassProfile("activations", 8e9, 0.4e12, 0.4e12, 0.01, True,
                         random_access=True),
    ]


SYSTEMS = {
    "hbm_only": [Tier(HBM3E, 640e9, count=16)],
    "hbm+mrm_pcm": [Tier(HBM3E, 96e9, count=4), Tier(MRM_PCM, 768e9, count=12)],
    "hbm+mrm_rram": [Tier(HBM3E, 96e9, count=4), Tier(MRM_RRAM, 768e9, count=12)],
    "hbm+mrm_mram": [Tier(HBM3E, 96e9, count=4), Tier(MRM_MRAM, 768e9, count=12)],
    "hbm+lpddr": [Tier(HBM3E, 96e9, count=4), Tier(LPDDR5X, 768e9, count=12)],
}


def ecc_table() -> dict:
    """Per-(MRM technology, retention state) ECC check-bit overhead:
    domain-specific split codeword vs uniform strict code. The density
    lever the paper's §4 co-design argues for — lower-retention states
    (cheaper cells, higher RBER) pay more parity, but the domain code
    pays measurably less than uniform on every derated state."""
    out = {}
    for tech in (MRM_PCM, MRM_RRAM, MRM_MRAM):
        dom = TierEcc(tech, "domain")
        uni = TierEcc(tech, "uniform")
        rows = {}
        for state, frac in STATE_RETENTION_FRAC.items():
            r = tech.retention_s * frac
            od, ou = dom.overhead_for("kv", r), uni.overhead_for("kv", r)
            shrink = 1.0 - od / ou if ou else 0.0
            if state != "hot":
                # the CI density gate: the split code must beat uniform on
                # every derated (demoted/cold/spilled) retention state
                assert od < ou, (
                    f"{tech.name}/{state}: domain {od:.5f} !< uniform {ou:.5f}")
            rows[state] = {"retention_s": r, "domain": od, "uniform": ou,
                           "shrink": shrink}
        out[tech.name] = rows
    return out


def compute() -> dict:
    classes = _classes()
    out = {}
    for name, tiers in SYSTEMS.items():
        res = solve_placement(classes, tiers)
        tokens_per_joule = DECODE_TOKENS_PER_S / res.energy_w if res.feasible else 0.0
        ecc = solve_placement(classes, tiers, ecc_profile="domain")
        out[name] = {
            "feasible": res.feasible,
            "assignment": res.assignment,
            "energy_w": res.energy_w,
            "capacity_cost_usd": res.cost_usd,
            "tokens_per_joule": tokens_per_joule,
            "violations": res.violations[:3],
            # same placement with ECC check bits metered as a tenant
            "ecc_overhead": ecc.ecc_overhead,
            "ecc_energy_w": ecc.energy_w,
        }
    base = out["hbm_only"]["energy_w"]
    for name in out:
        out[name]["energy_vs_hbm"] = out[name]["energy_w"] / base if base else None
    out["ecc_table"] = ecc_table()
    return out


def run(csv=True):
    t0 = time.perf_counter()
    out = compute()
    dt = (time.perf_counter() - t0) * 1e6
    if csv:
        for name, r in out.items():
            if name == "ecc_table":
                continue
            print(f"mrm_tco/{name}_energy_w,{dt:.1f},{r['energy_w']:.2f}")
            print(f"mrm_tco/{name}_tokens_per_j,{dt:.1f},{r['tokens_per_joule']:.3f}")
            print(f"mrm_tco/{name}_cost_usd,{dt:.1f},{r['capacity_cost_usd']:.0f}")
        for tech, rows in out["ecc_table"].items():
            for state, row in rows.items():
                print(f"mrm_tco/ecc_{tech}_{state}_shrink,{dt:.1f},"
                      f"{row['shrink']:.4f}")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1, default=str))
