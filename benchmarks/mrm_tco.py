"""MRM vs HBM-only: placement feasibility, sustained memory power, capacity
cost, and tokens/joule for a llama2-70b-class inference machine (the
paper's 'tokens per dollar' §5 motivation, made concrete)."""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.core.memclass import HBM3E, HOUR, LPDDR5X, MRM_MRAM, MRM_PCM, MRM_RRAM
from repro.core.tiering import DataClassProfile, Tier, solve_placement

DECODE_TOKENS_PER_S = 600.0


def _classes():
    cfg = get_config("llama2-70b")
    w_bytes = cfg.param_counts()["total"] * 2
    kv_tok = cfg.kv_bytes_per_token()
    # decode reads all weights + live KV per token (paper §2.2)
    read_bw_w = DECODE_TOKENS_PER_S * w_bytes / 64        # batch-64 amortized
    kv_live = 300e9
    read_bw_kv = DECODE_TOKENS_PER_S * kv_live / 64
    return [
        DataClassProfile("weights", w_bytes, read_bw_w, w_bytes / (24 * HOUR),
                         24 * HOUR, False),
        DataClassProfile("kv_cache", kv_live, read_bw_kv,
                         DECODE_TOKENS_PER_S * kv_tok * 12, 600, True),
        DataClassProfile("activations", 8e9, 0.4e12, 0.4e12, 0.01, True,
                         random_access=True),
    ]


SYSTEMS = {
    "hbm_only": [Tier(HBM3E, 640e9, count=16)],
    "hbm+mrm_pcm": [Tier(HBM3E, 96e9, count=4), Tier(MRM_PCM, 768e9, count=12)],
    "hbm+mrm_rram": [Tier(HBM3E, 96e9, count=4), Tier(MRM_RRAM, 768e9, count=12)],
    "hbm+mrm_mram": [Tier(HBM3E, 96e9, count=4), Tier(MRM_MRAM, 768e9, count=12)],
    "hbm+lpddr": [Tier(HBM3E, 96e9, count=4), Tier(LPDDR5X, 768e9, count=12)],
}


def compute() -> dict:
    classes = _classes()
    out = {}
    for name, tiers in SYSTEMS.items():
        res = solve_placement(classes, tiers)
        tokens_per_joule = DECODE_TOKENS_PER_S / res.energy_w if res.feasible else 0.0
        out[name] = {
            "feasible": res.feasible,
            "assignment": res.assignment,
            "energy_w": res.energy_w,
            "capacity_cost_usd": res.cost_usd,
            "tokens_per_joule": tokens_per_joule,
            "violations": res.violations[:3],
        }
    base = out["hbm_only"]["energy_w"]
    for name in out:
        out[name]["energy_vs_hbm"] = out[name]["energy_w"] / base if base else None
    return out


def run(csv=True):
    t0 = time.perf_counter()
    out = compute()
    dt = (time.perf_counter() - t0) * 1e6
    if csv:
        for name, r in out.items():
            print(f"mrm_tco/{name}_energy_w,{dt:.1f},{r['energy_w']:.2f}")
            print(f"mrm_tco/{name}_tokens_per_j,{dt:.1f},{r['tokens_per_joule']:.3f}")
            print(f"mrm_tco/{name}_cost_usd,{dt:.1f},{r['capacity_cost_usd']:.0f}")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1, default=str))
