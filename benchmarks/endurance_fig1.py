"""Figure 1 reproduction: endurance requirements for KV cache and model
weights vs endurance of memory technologies.

Inputs (paper §3): 5-year device life; weight updates hourly (conservative)
and once-per-second (intensive); KV-cache writes from the Splitwise [35]
llama2-70b serving numbers (prefill-dominated token rate, median context
lengths ~1-1.3k tokens) spread over the KV region with software wear
levelling. Validation = the paper's qualitative orderings, since the figure
publishes no point values.
"""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.core.endurance import weight_update_writes, writes_per_cell
from repro.core.memclass import HOUR, TECHNOLOGIES, YEAR

# Splitwise-derived serving point (per inference machine)
PREFILL_TOKENS_PER_S = 7000.0   # llama2-70b prefill throughput class
DECODE_TOKENS_PER_S = 600.0     # sustained decode across batch
KV_REGION_BYTES = 400e9         # KV working region per machine
LIFETIME_S = 5 * YEAR


def compute() -> dict:
    cfg = get_config("llama2-70b")
    kv_tok = cfg.kv_bytes_per_token()
    kv_write_bw = (PREFILL_TOKENS_PER_S + DECODE_TOKENS_PER_S) * kv_tok
    reqs = {
        "weights_hourly": weight_update_writes(HOUR, LIFETIME_S),
        "weights_per_second": weight_update_writes(1.0, LIFETIME_S),
        "kv_cache": writes_per_cell(kv_write_bw, KV_REGION_BYTES, LIFETIME_S),
        "kv_cache_worstlevel": writes_per_cell(kv_write_bw, KV_REGION_BYTES,
                                               LIFETIME_S, leveling_efficiency=0.5),
    }
    techs = {name: {"device": t.endurance_device, "potential": t.endurance_potential}
             for name, t in TECHNOLOGIES.items()}
    hardest = max(reqs["kv_cache_worstlevel"], reqs["weights_per_second"])
    verdicts = {
        # paper §3 observation 2: existing SCM devices do not meet the
        # requirements (PCM/RRAM devices fail the per-second weight-update
        # bar; RRAM also fails the worst-levelled KV bar) ...
        "flash_slc_insufficient_for_kv":
            techs["nand_slc"]["device"] < reqs["kv_cache"],
        "scm_devices_insufficient":
            techs["rram"]["device"] < reqs["kv_cache_worstlevel"] and
            techs["optane_pcm"]["device"] < reqs["weights_per_second"],
        # ... but the underlying technologies have the potential to do so
        "technology_potential_sufficient":
            all(techs[t]["potential"] > hardest
                for t in ("optane_pcm", "rram", "stt_mram")),
        # paper §3 observation 1: HBM is vastly overprovisioned on endurance
        "hbm_vastly_overprovisioned":
            techs["hbm3e"]["device"] > 1e4 * hardest,
        # and the MRM operating points we propose cover the requirements
        "mrm_operating_points_sufficient":
            all(techs[t]["device"] > hardest
                for t in ("mrm_pcm", "mrm_rram", "mrm_mram")),
    }
    return {"requirements": reqs, "technologies": techs, "verdicts": verdicts,
            "kv_bytes_per_token": kv_tok}


def run(csv=True):
    t0 = time.perf_counter()
    out = compute()
    dt = (time.perf_counter() - t0) * 1e6
    if csv:
        for k, v in out["requirements"].items():
            print(f"endurance_fig1/{k},{dt:.1f},{v:.3e}")
        for k, v in out["verdicts"].items():
            print(f"endurance_fig1/verdict_{k},{dt:.1f},{int(v)}")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1))
