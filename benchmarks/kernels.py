"""Kernel micro-benchmarks (interpret-mode wall time is NOT TPU performance;
recorded for regression tracking) + the analytic VMEM/roofline sizing per
kernel block configuration."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def _time(fn, *args, iters=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def flash_block_analysis(q_block=512, kv_block=512, d=128,
                         dtype_bytes=2) -> dict:
    """VMEM working set + arithmetic intensity for one flash block."""
    vmem = (q_block * d + 2 * kv_block * d) * dtype_bytes \
        + q_block * kv_block * 4 + (q_block * d + 2 * q_block) * 4
    flops = 2 * q_block * kv_block * d * 2  # qk + pv
    hbm = (kv_block * d * 2) * dtype_bytes  # streamed K,V per block
    return {
        "vmem_bytes": vmem,
        "vmem_fits_16mb": vmem <= 16 * 2**20,
        "arithmetic_intensity": flops / hbm,
        "mxu_aligned": (q_block % 128 == 0 and kv_block % 128 == 0 and d % 128 == 0),
        "block_time_compute_s": flops / PEAK_FLOPS_BF16,
        "block_time_hbm_s": hbm / HBM_BW,
    }


def compute() -> dict:
    from repro.kernels.decode_attention import decode_attention
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ssd_scan import ssd_scan

    rng = np.random.default_rng(0)
    out = {"blocks": {}}
    for qb, kb in ((256, 256), (512, 512), (512, 1024)):
        out["blocks"][f"flash_{qb}x{kb}"] = flash_block_analysis(qb, kb)

    B, S, H, D = 1, 256, 4, 64
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    out["flash_interp_us"] = _time(
        lambda *a: flash_attention(*a, scale=D**-0.5, q_block=64, kv_block=64),
        q, k, v)

    C = 256
    kc = jnp.asarray(rng.normal(0, 1, (B, C, H, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(0, 1, (B, C, H, D)), jnp.float32)
    pos = jnp.arange(C, dtype=jnp.int32)[None]
    q1 = jnp.asarray(rng.normal(0, 1, (B, 1, H, D)), jnp.float32)
    out["decode_interp_us"] = _time(
        lambda *a: decode_attention(*a, scale=D**-0.5, page_size=64),
        q1, kc, vc, pos, jnp.asarray([C - 1], jnp.int32))

    x = jnp.asarray(rng.normal(0, 1, (B, S, H, 16)), jnp.float32)
    dt_ = jnp.asarray(rng.uniform(0.001, 0.1, (B, S, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(1, 4, (H,)), jnp.float32)
    bb = jnp.asarray(rng.normal(0, 1, (B, S, 1, 16)), jnp.float32)
    cc = jnp.asarray(rng.normal(0, 1, (B, S, 1, 16)), jnp.float32)
    out["ssd_interp_us"] = _time(lambda *a_: ssd_scan(*a_, chunk=64),
                                 x, dt_, a, bb, cc)
    return out


def run(csv=True):
    t0 = time.perf_counter()
    out = compute()
    dt = (time.perf_counter() - t0) * 1e6
    if csv:
        for name, b in out["blocks"].items():
            print(f"kernels/{name}_ai,{dt:.1f},{b['arithmetic_intensity']:.1f}")
            print(f"kernels/{name}_vmem_kb,{dt:.1f},{b['vmem_bytes']/1024:.0f}")
        for k in ("flash_interp_us", "decode_interp_us", "ssd_interp_us"):
            print(f"kernels/{k},{out[k]:.1f},0")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1, default=float))
