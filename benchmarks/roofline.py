"""§Roofline reporter: reads the dry-run artifacts and emits the per-cell
three-term roofline table, plus an ANALYTIC fused-kernel memory model that
quantifies what the Pallas kernels buy (the XLA path materializes the
attention probability matrices in HBM; a fused kernel keeps them in VMEM,
so its HBM traffic is the boundary IO: weights + activations + KV streams).
"""
from __future__ import annotations

import glob
import json
import pathlib
import time

from repro.configs import get_config, get_shape
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def ecc_kv_read_overhead(state: str = "demoted") -> dict:
    """Check-bit read overhead for KV pages held on MRM (mrm_rram) at one
    retention state, per ECC profile (DESIGN.md §11). Every KV byte the
    kernel streams drags its parity bytes across the same interface, so
    the roofline's memory term scales by ``1 + overhead`` — the domain
    split code keeps that scaling smaller than a uniform strict code."""
    from repro.core.ecc import STATE_RETENTION_FRAC, TierEcc
    from repro.core.memclass import MRM_RRAM
    r = MRM_RRAM.retention_s * STATE_RETENTION_FRAC[state]
    return {prof: TierEcc(MRM_RRAM, prof).overhead_for("kv", r)
            for prof in ("uniform", "domain")}


def analytic_kernel_bytes(arch: str, shape_name: str, n_chips: int = 256,
                          ecc_kv_overhead: float = 0.0) -> float:
    """Per-device HBM bytes for a fused-kernel implementation (lower bound):
    weights read once per step + residual-stream activations (fwd+bwd with
    full remat ~ 3 passes) + flash-attention KV streaming (K,V re-read once
    per q-block pass) + logits/loss traffic. bf16 everywhere.
    ``ecc_kv_overhead`` scales the KV-stream terms by ``1 + overhead`` —
    the reliability plane's check-bit reads on paged KV (DESIGN.md §11)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    counts = cfg.param_counts()
    B, S = shape.global_batch, shape.seq_len
    bpe = 2

    if shape.kind == "decode":
        # one token: all active weights + the whole live KV, once (paper §2.2)
        w = counts["active"] * bpe / n_chips
        kv = B * S * cfg.kv_bytes_per_token() / n_chips
        act = B * cfg.num_layers * cfg.d_model * bpe * 8 / n_chips
        return w + kv * (1.0 + ecc_kv_overhead) + act

    tokens = B * S
    passes = 3 if shape.kind == "train" else 1  # fwd + remat-fwd + bwd
    w_stream = counts["total"] * bpe / n_chips * passes
    if shape.kind == "train":
        w_stream += counts["total"] * (2 + 4 + 4 + 4) / n_chips  # grads+adam m,v rw
    act = tokens * cfg.d_model * bpe * cfg.num_layers * passes * 4 / n_chips
    # flash attention KV streaming: nq passes over K,V per layer
    q_block = 512
    attn_kv = 0.0
    for spec in cfg.layer_specs():
        if spec.kind in ("attn", "hybrid"):
            span = min(spec.window or S, S)
            nq = max(S // q_block, 1)
            attn_kv += (tokens * 2 * cfg.n_kv_heads * cfg.resolved_head_dim *
                        bpe) * min(nq, max(span // q_block, 1)) / n_chips * passes
        elif spec.kind == "mla":
            attn_kv += tokens * (cfg.kv_lora_rank + cfg.qk_rope_dim) * bpe * \
                max(S // q_block, 1) / n_chips * passes
    logits = tokens * cfg.padded_vocab * 4 / n_chips * (2 if shape.kind == "train" else 0)
    return w_stream + act + attn_kv * (1.0 + ecc_kv_overhead) + logits


def load_cells(mesh="single", variant="base"):
    rows = []
    for f in sorted(glob.glob(str(ART / f"*__{mesh}__{variant}.json"))):
        d = json.loads(pathlib.Path(f).read_text())
        if d.get("ok"):
            rows.append(d)
    return rows


def table(mesh="single") -> list:
    ecc_ov = ecc_kv_read_overhead("demoted")
    rows = []
    for d in load_cells(mesh):
        rt = d["roofline"]
        ka_bytes = analytic_kernel_bytes(d["arch"], d["shape"], d["n_devices"])
        ka_ecc = analytic_kernel_bytes(d["arch"], d["shape"], d["n_devices"],
                                       ecc_kv_overhead=ecc_ov["domain"])
        rows.append({
            "arch": d["arch"], "shape": d["shape"],
            "compute_s": rt["compute_s"], "memory_s": rt["memory_s"],
            "collective_s": rt["collective_s"], "dominant": rt["dominant"],
            "kernel_memory_s": ka_bytes / HBM_BW,
            "kernel_memory_ecc_s": ka_ecc / HBM_BW,
            "useful_ratio": d["model_flops"]["useful_ratio"],
            "per_device_gib": d["memory"]["per_device_gib"],
            "fits": d["memory"]["fits_16gib"],
            "roofline_fraction": rt["compute_s"] / max(rt["compute_s"],
                                                       rt["memory_s"],
                                                       rt["collective_s"]),
        })
    return rows


def run(csv=True):
    t0 = time.perf_counter()
    rows = table()
    dt = (time.perf_counter() - t0) * 1e6
    if csv:
        for r in rows:
            print(f"roofline/{r['arch']}__{r['shape']}_dom_{r['dominant']},"
                  f"{dt:.1f},{r['roofline_fraction']:.4f}")
        ov = ecc_kv_read_overhead("demoted")
        # density gate: domain check bits must undercut uniform on the
        # demoted state the roofline models
        assert 0.0 < ov["domain"] < ov["uniform"]
        for prof, o in ov.items():
            print(f"roofline/ecc_kv_overhead_{prof}_demoted,{dt:.1f},{o:.5f}")
    return rows


if __name__ == "__main__":
    rows = table()
    hdr = (f"{'arch':<22}{'shape':<13}{'dom':<11}{'comp_s':>10}{'mem_s':>10}"
           f"{'kmem_s':>10}{'coll_s':>10}{'useful':>8}{'GiB':>8} fit")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:<22}{r['shape']:<13}{r['dominant']:<11}"
              f"{r['compute_s']:>10.2e}{r['memory_s']:>10.2e}"
              f"{r['kernel_memory_s']:>10.2e}{r['collective_s']:>10.2e}"
              f"{(r['useful_ratio'] or 0):>8.3f}{r['per_device_gib']:>8.1f} "
              f"{'Y' if r['fits'] else 'N'}")
