"""Serving-simulator benchmarks over the MRM control plane.

1. Retention-policy sweep (paper §4: DCM 'right-provisioning'): vary the
   DCM expected-session-lifetime programming and measure refresh overhead
   vs write energy — the knob the cluster control plane owns.
2. Cluster sweep: replica count x capacity-constrained MRM KV tier with
   chunked prefill — every failed KV allocation must be resolved by an
   explicit eviction/spill/recompute decision (zero silent drops), and the
   fleet report aggregates tokens/bytes across replicas.
"""
from __future__ import annotations

import time

import jax
import numpy as np


def compute(arch="deepseek-7b") -> dict:
    from repro.configs import get_config, reduced
    from repro.core.memclass import HBM3E, MRM_RRAM
    from repro.core.simulator import MemorySystem
    from repro.models import init_params
    from repro.serving import EngineConfig, ServeEngine

    full = get_config(arch)
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    out = {}
    for session_s in (0.01, 1.0, 60.0, 3600.0):
        mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 40), "hbm": (HBM3E, 1 << 37)})
        eng = ServeEngine(cfg, params, mem,
                          EngineConfig(max_slots=2, max_cache_len=64,
                                       weight_tier="mrm", kv_tier="mrm",
                                       expected_session_s=session_s),
                          account_cfg=full)
        rng = np.random.default_rng(0)
        for _ in range(4):
            eng.submit(list(rng.integers(2, cfg.vocab_size, 16)), 10)
        rep = eng.run_until_idle()
        mrm = rep["memory"]["tiers"]["mrm"]
        out[f"session_{session_s}s"] = {
            "refresh_events": rep["memory"]["refresh_stats"]["refresh"],
            "refresh_gb": mrm["refresh_gb"],
            "write_gb": mrm["write_gb"],
            "energy_per_token_j": rep["energy_per_token_j"],
            "refresh_overhead": mrm["refresh_gb"] / max(mrm["write_gb"], 1e-12),
        }
    return out


def cluster_sweep(arch="deepseek-7b", replica_counts=(1, 2),
                  kv_capacity_bytes=1 << 25, requests=8) -> dict:
    """Replica sweep under a capacity-constrained MRM KV tier: chunked
    prefill on, pressure policy 'evict-lru' (prefix-LRU eviction with
    drop-and-recompute fallback). Asserts the pressure ledger balances and
    no allocation was silently dropped."""
    from repro.configs import get_config, reduced
    from repro.core.memclass import HBM3E, MRM_RRAM
    from repro.core.simulator import MemorySystem
    from repro.models import init_params
    from repro.serving import ClusterFrontend, EngineConfig, ServeEngine

    full = get_config(arch)
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    out = {}
    for n in replica_counts:
        engines = []
        for _ in range(n):
            mem = MemorySystem({"mrm": (MRM_RRAM, kv_capacity_bytes),
                                "hbm": (HBM3E, 1 << 34)})
            engines.append(ServeEngine(
                cfg, params, mem,
                EngineConfig(max_slots=2, max_cache_len=64, weight_tier="hbm",
                             kv_tier="mrm", eos_token=-1, chunk_tokens=16,
                             page_tokens=16,
                             kv_pressure_policy="evict-lru",
                             kv_high_watermark=0.9),
                account_cfg=full))
        fe = ClusterFrontend(engines)
        rng = np.random.default_rng(0)
        for i in range(requests):
            fe.submit(list(rng.integers(2, cfg.vocab_size, 40)), 8,
                      session_key=f"user-{i}")
        rep = fe.run_until_idle()
        p = rep["pressure"]
        resolved = (p["resolved_evict"] + p["resolved_spill"] +
                    p["resolved_recompute"])
        assert p["events"] > 0, "tier was supposed to be capacity-constrained"
        assert p["events"] == resolved + p["unresolved"], p
        assert p["unresolved"] == 0, p
        assert rep["dropped_allocs"] == 0, \
            f"silent drops under pressure: {rep['dropped_allocs']}"
        assert rep["tokens_generated"] == sum(
            r["tokens_generated"] for r in rep["per_replica"])
        out[f"replicas_{n}"] = {
            "finished": rep["finished"],
            "tokens_generated": rep["tokens_generated"],
            "fleet_tokens_per_s": rep["fleet_tokens_per_s"],
            "energy_per_token_j": rep["energy_per_token_j"],
            "pressure_events": p["events"],
            "pressure_resolved": resolved,
            "prefix_evictions": p["prefix_evictions"],
            "recompute_tokens": p["recompute_tokens"],
            "dropped_allocs": rep["dropped_allocs"],
        }
    return out


def run(csv=True):
    t0 = time.perf_counter()
    out = compute()
    dt = (time.perf_counter() - t0) * 1e6
    if csv:
        for k, v in out.items():
            print(f"serving_sim/{k}_refresh_overhead,{dt:.1f},{v['refresh_overhead']:.4f}")
            print(f"serving_sim/{k}_energy_per_token,{dt:.1f},{v['energy_per_token_j']:.3e}")
    t0 = time.perf_counter()
    fleet = cluster_sweep()
    dt = (time.perf_counter() - t0) * 1e6
    out.update(fleet)
    if csv:
        for k, v in fleet.items():
            print(f"serving_sim/{k}_fleet_tokens_per_s,{dt:.1f},{v['fleet_tokens_per_s']:.4f}")
            print(f"serving_sim/{k}_pressure_events,{dt:.1f},{v['pressure_events']}")
            print(f"serving_sim/{k}_dropped_allocs,{dt:.1f},{v['dropped_allocs']}")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1, default=float))
