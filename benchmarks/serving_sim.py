"""Serving-simulator benchmarks over the MRM control plane.

1. Retention-policy sweep (paper §4: DCM 'right-provisioning'): vary the
   DCM expected-session-lifetime programming and measure refresh overhead
   vs write energy — the knob the cluster control plane owns.
2. Cluster sweep: replica count x capacity-constrained MRM KV tier with
   chunked prefill — every failed KV allocation must be resolved by an
   explicit eviction/spill/recompute decision (zero silent drops), and the
   fleet report aggregates tokens/bytes across replicas.
3. Prefix-reuse sweep: shared-prefix traffic (multi-turn chat, shared
   system prompts, RAG fan-out) with the radix prefix tree on vs off —
   reuse must cut prefill tokens computed by >= 30% at equal (identical)
   output tokens, and the hit rate / tokens reused / TTFT land in the
   JSON trajectory. Runs once per snapshot family (DESIGN.md §8):
   attention (deepseek-7b), SSM point snapshots (mamba2-2.7b) and the
   hybrid union (hymba-1.5b); stacks with a KV write stream must also cut
   KV-tier write bytes >= 30%.
4. Tail-reuse sweep (DESIGN.md §9): shared prefixes whose length
   straddles a page boundary, tail-copy on vs the page-aligned matcher —
   the prefill-token cut with sub-page tails must strictly exceed the
   page-aligned cut at identical decoded tokens, with tail-copy bytes
   metered and pressure ledgers balanced.
5. Fleet-reuse sweep: N replicas x shared-prefix fan-out with the fleet
   prefix directory + cross-replica migration on vs the per-replica radix
   baseline (each replica recomputes the shared head cold) — must show a
   cross-replica hit rate > 0, a >= 20% fleet prefill-token cut at
   identical decoded tokens, non-zero metered interconnect traffic, and
   zero pressure-ledger imbalance. Runs for an attention stack and an SSM
   stack (the latter transfers a *point* state snapshot over the wire).
6. Reliability sweep (DESIGN.md §11; suite ``reliability``, trajectory in
   ``BENCH_reliability.json``): fault injection over the paged plane at a
   target RBER, three arms on identical prompts — clean (no injection),
   protected (domain ECC + refresh: scrubs fire, decode matches clean
   within tolerance) and over-aged (refresh disabled, the clock jumped
   past 4x retention: uncorrectable blocks reported, decode measurably
   degrades) — plus the per-state ECC overhead ladder showing the split
   code shrinking check bits on demoted/cold/spilled pages.
7. Replication sweep (DESIGN.md §13; suite ``replication``, trajectory
   in ``BENCH_fleet.json``): reactive vs predictive prefix replication
   on the event-driven fleet simulator — the herald-led rag_storm
   fan-out must cut TTFT p95 >= 40% at bit-identical decoded tokens
   with speculative push bytes > 0 and strictly fewer demand
   migrations; the diurnal tenant mix must eliminate demand migrations
   at flat TTFT; trace digests bit-stable across reruns and submission
   shuffles.
"""
from __future__ import annotations

import time

import jax
import numpy as np


def prefix_workloads(rng, vocab: int, n_users: int = 3, turns: int = 2,
                     fanout: int = 4, n_system: int = 5) -> list:
    """Shared-prefix traffic at three granularities. Returns
    ``[(prompt_tokens, max_new, session_key), ...]``:

    - **shared system prompt** — one 48-token head, distinct 16-token asks;
    - **multi-turn chat** — each user's context grows turn over turn (the
      next prompt extends the previous one, radix-matchable because the
      serving path keeps prompts unpadded / position-aligned);
    - **RAG fan-out** — one 64-token document, `fanout` question variants.
    """
    reqs = []
    system = list(rng.integers(2, vocab, 48))
    for i in range(n_system):
        reqs.append((system + list(rng.integers(2, vocab, 16)), 6, f"sys-{i}"))
    for u in range(n_users):
        hist = list(rng.integers(2, vocab, 24))
        for _ in range(turns):
            reqs.append((list(hist), 6, f"chat-{u}"))
            hist = hist + list(rng.integers(2, vocab, 12))  # model reply etc.
    doc = list(rng.integers(2, vocab, 64))
    for q in range(fanout):
        reqs.append((doc + list(rng.integers(2, vocab, 12)), 6, f"rag-{q}"))
    return reqs


def prefix_reuse(arch="deepseek-7b", **workload_kw) -> dict:
    """Radix prefix reuse on shared-prefix traffic vs prefix_caching=False:
    identical decoded tokens and >= 30% fewer prefill tokens computed —
    for *every* snapshot family (attention ring caches, MLA latent pages,
    SSM/hybrid point snapshots; DESIGN.md §8). Stacks with a real KV
    write stream must also cut KV-tier write bytes >= 30%; a pure-SSM
    stack appends no per-token KV, so that axis is reported as None.

    ``workload_kw`` scales :func:`prefix_workloads` — point-snapshot
    stacks pay a one-time capture recompute per observed share boundary
    (§8), so their savings amortize over fan-out depth where positional
    stacks save from the second request on."""
    from repro.configs import get_config, reduced
    from repro.core.memclass import HBM3E, MRM_RRAM
    from repro.core.simulator import MemorySystem
    from repro.models import init_params
    from repro.serving import EngineConfig, ServeEngine

    full = get_config(arch)
    # fp32 keeps extend-from-the-match-boundary greedy argmax bit-equal to
    # the cold prefill (bf16 amplifies accumulation-order differences)
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    reqs = prefix_workloads(np.random.default_rng(0), cfg.vocab_size,
                            **workload_kw)

    def run_one(prefix_caching: bool):
        mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 40),
                            "hbm": (HBM3E, 1 << 37)})
        eng = ServeEngine(cfg, params, mem,
                          EngineConfig(max_slots=2, max_cache_len=96,
                                       weight_tier="hbm", kv_tier="mrm",
                                       eos_token=-1, chunk_tokens=16,
                                       page_tokens=16,
                                       prefix_caching=prefix_caching,
                                       radix_hot_threshold=2),
                          account_cfg=full)
        for prompt, max_new, _key in reqs:
            eng.submit(list(prompt), max_new)
        rep = eng.run_until_idle()
        return eng, rep

    eng_on, on = run_one(True)
    eng_off, off = run_one(False)
    assert on["tokens_generated"] == off["tokens_generated"]
    outs_on = {k: list(v) for k, v in eng_on.outputs.items()}
    outs_off = {k: list(v) for k, v in eng_off.outputs.items()}
    assert outs_on == outs_off, "prefix reuse changed decoded tokens"
    prefill_cut = 1 - on["prefill_tokens_computed"] / off["prefill_tokens_computed"]
    assert prefill_cut >= 0.30, f"prefill cut {prefill_cut:.2%} < 30%"
    kv_w_on = on["memory"]["tiers"]["mrm"]["write_gb"]
    kv_w_off = off["memory"]["tiers"]["mrm"]["write_gb"]
    kv_write_cut = None
    if full.kv_bytes_per_token() > 0:
        kv_write_cut = 1 - kv_w_on / kv_w_off
        assert kv_write_cut >= 0.30, f"KV write cut {kv_write_cut:.2%} < 30%"
    return {
        "requests": len(reqs),
        "snapshot_kind": on["prefix"]["snapshot_kind"],
        "prefix_hits": on["prefix_hits"],
        "prefix_hit_rate": on["prefix_hits"] / len(reqs),
        "tokens_reused": on["prefix_tokens_reused"],
        "tokens_skipped_compute": on["prefill_tokens_skipped"],
        "prefill_tokens_computed": on["prefill_tokens_computed"],
        "prefill_tokens_cold": off["prefill_tokens_computed"],
        "prefill_cut": prefill_cut,
        "kv_write_gb": kv_w_on,
        "kv_write_gb_cold": kv_w_off,
        "kv_write_cut": kv_write_cut,
        "retention_promotions": on["prefix"]["retention_promotions"],
        "ttft_p50_s": on["latency"]["ttft_p50"],
        "ttft_p95_s": on["latency"]["ttft_p95"],
        "ttft_p50_cold_s": off["latency"]["ttft_p50"],
        "itl_p50_s": on["latency"]["itl_p50"],
    }


def tail_reuse(arch="deepseek-7b", page_tokens=16, head_tokens=56,
               fanout=6, tail_len=9) -> dict:
    """Sub-page tail reuse (DESIGN.md §9) on prefix lengths that straddle
    page boundaries: the shared head is deliberately NOT page-aligned
    (``head_tokens % page_tokens != 0``), so a page-aligned matcher (the
    PR 4 behavior, ``tail_copy=False``) recomputes the mid-page tail on
    every hit while the tail-copy path resumes extend from the exact
    token boundary. Asserts, at identical decoded tokens across all three
    runs (tail on / page-aligned / prefix caching off):

    - the tail-on prefill-token cut **strictly exceeds** the page-aligned
      cut (the PR 4 baseline);
    - tail-copy bytes were actually metered (read + write over the bus);
    - every pressure ledger balances with zero unresolved events.
    """
    from repro.configs import get_config, reduced
    from repro.core.memclass import HBM3E, MRM_RRAM
    from repro.core.simulator import MemorySystem
    from repro.models import init_params
    from repro.serving import EngineConfig, ServeEngine

    assert head_tokens % page_tokens != 0, "head must straddle a page"
    full = get_config(arch)
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    head = list(rng.integers(2, cfg.vocab_size, head_tokens))
    prompts = [head + list(rng.integers(2, cfg.vocab_size, tail_len))
               for _ in range(fanout)]

    def run_one(tail_copy: bool, prefix_caching: bool = True):
        mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 40),
                            "hbm": (HBM3E, 1 << 37)})
        eng = ServeEngine(cfg, params, mem,
                          EngineConfig(max_slots=2, max_cache_len=96,
                                       weight_tier="hbm", kv_tier="mrm",
                                       eos_token=-1, chunk_tokens=16,
                                       page_tokens=page_tokens,
                                       prefix_caching=prefix_caching,
                                       tail_copy=tail_copy),
                          account_cfg=full)
        for p in prompts:   # sequential: every later prompt can hit
            eng.submit(list(p), 6)
            eng.run_until_idle()
        return eng, eng.report()

    eng_tail, on = run_one(True)
    eng_page, page = run_one(False)
    eng_cold, cold = run_one(True, prefix_caching=False)
    outs = [{k: list(v) for k, v in e.outputs.items()}
            for e in (eng_tail, eng_page, eng_cold)]
    assert outs[0] == outs[1] == outs[2], "tail reuse changed decoded tokens"
    assert on["tokens_generated"] == page["tokens_generated"] \
        == cold["tokens_generated"]
    cut_tail = 1 - on["prefill_tokens_computed"] / cold["prefill_tokens_computed"]
    cut_page = 1 - page["prefill_tokens_computed"] / cold["prefill_tokens_computed"]
    assert cut_tail > cut_page, \
        f"tail cut {cut_tail:.2%} must strictly beat page-aligned {cut_page:.2%}"
    prefix = on["prefix"]
    assert prefix["tail_hits"] > 0, prefix
    assert prefix["tail_copy_bytes"] > 0, prefix
    for rep in (on, page, cold):
        p = rep["pressure"]
        assert p["events"] == (p["resolved_evict"] + p["resolved_spill"]
                               + p["resolved_recompute"] + p["unresolved"])
        assert p["unresolved"] == 0 and rep["dropped_allocs"] == 0
    return {
        "requests": len(prompts),
        "page_tokens": page_tokens,
        "head_tokens": head_tokens,
        "prefill_tokens_tail": on["prefill_tokens_computed"],
        "prefill_tokens_page_aligned": page["prefill_tokens_computed"],
        "prefill_tokens_cold": cold["prefill_tokens_computed"],
        "prefill_cut": cut_tail,
        "prefill_cut_page_aligned": cut_page,
        "tail_hits": prefix["tail_hits"],
        "tail_tokens_copied": prefix["tail_tokens_copied"],
        "tail_copy_bytes": prefix["tail_copy_bytes"],
        "tokens_skipped_compute": on["prefill_tokens_skipped"],
        "ttft_p50_s": on["latency"]["ttft_p50"],
        "ttft_p50_page_aligned_s": page["latency"]["ttft_p50"],
    }


def paged_kernel(arch="deepseek-7b", n_shares=4, head_tokens=48,
                 ask_tokens=12) -> dict:
    """Paged compute plane vs the ring path (DESIGN.md §10) on shared-
    prefix fan-out traffic: the same prompts, decoded greedily in fp32,
    with ``paged_kernel`` on vs off. The plane is universal (ISSUE 7):
    attention/MLA serve on KV pages, SSM/hybrid on pooled point-state
    pages — there is no ring fallback for any family. Asserts:

    - prefix-hit decode on the paged plane is **bit-identical** to a
      cold paged start, and to the ring plane whenever no sliding window
      wraps the ring buffer (a wrapped window sums the same values in
      rotated order — fp32 accumulation order is layout-specific there,
      so the cross-plane comparison is decoded-token *counts* only);
    - the paged engine really is paged (``ring_fallbacks == 0`` in the
      result — the smoke gate for recurrent stacks);
    - the paged plane's prefix-hit copy bytes are exactly **zero** (no
      donor-seed cache-tree copy, no published snapshot) while the ring
      plane pays ``seed_copy_bytes > 0`` per hit (the PR 5 comparator);
    - the KV tier's metered read bytes equal the kernel's page-gather
      byte count exactly (tail copies disabled for a clean identity).
    """
    from repro.configs import get_config, reduced
    from repro.core.memclass import HBM3E, MRM_RRAM
    from repro.core.simulator import MemorySystem
    from repro.models import init_params
    from repro.serving import EngineConfig, ServeEngine

    full = get_config(arch)
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    head = list(rng.integers(2, cfg.vocab_size, head_tokens))
    prompts = [head + list(rng.integers(2, cfg.vocab_size, ask_tokens))
               for _ in range(n_shares)]

    def run_one(paged: bool, prefix_caching: bool = True):
        mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 40),
                            "hbm": (HBM3E, 1 << 37)})
        eng = ServeEngine(cfg, params, mem,
                          EngineConfig(max_slots=2, max_cache_len=96,
                                       weight_tier="hbm", kv_tier="mrm",
                                       eos_token=-1, chunk_tokens=16,
                                       page_tokens=16, tail_copy=False,
                                       paged_kernel=paged,
                                       prefix_caching=prefix_caching,
                                       radix_hot_threshold=2),
                          account_cfg=full)
        for p in prompts:   # sequential: every later prompt can hit
            eng.submit(list(p), 6)
            eng.run_until_idle()
        return eng, eng.report()

    eng_p, on = run_one(True)
    eng_r, off = run_one(False)
    eng_c, _cold = run_one(True, prefix_caching=False)
    outs_p = {k: list(v) for k, v in eng_p.outputs.items()}
    outs_r = {k: list(v) for k, v in eng_r.outputs.items()}
    outs_c = {k: list(v) for k, v in eng_c.outputs.items()}
    assert outs_p == outs_c, "paged prefix hit changed decoded tokens"
    specs = cfg.layer_specs() if callable(cfg.layer_specs) \
        else cfg.layer_specs
    if not any(s.window for s in specs):
        assert outs_p == outs_r, "paged plane changed decoded tokens"
    assert on["tokens_generated"] == off["tokens_generated"]
    assert eng_p.paged and eng_p.backend.paged, \
        f"{arch}: paged_kernel=on must not fall back to the ring path"
    assert on["prefix"]["compute_hits"] >= n_shares - 1
    # the zero-copy-hit invariant (and the PR 5 comparator on the ring)
    assert on["seed_copy_bytes"] == 0.0, on["seed_copy_bytes"]
    assert on["snapshot_bytes"] == 0.0, on["snapshot_bytes"]
    assert off["seed_copy_bytes"] > 0, off["seed_copy_bytes"]
    # per-tier metering identity: weights stream from hbm, so every KV
    # tier byte read is the kernel's page gather — no synthetic traffic
    kernel_reads = on["kernel_read_bytes"]
    mrm_reads = eng_p.mem.devices["mrm"].stats.read_bytes
    assert kernel_reads > 0 and abs(mrm_reads - kernel_reads) < 1e-6, \
        (mrm_reads, kernel_reads)
    per_tier_reads = {t: d.stats.read_bytes
                      for t, d in eng_p.mem.devices.items()}
    return {
        "arch": arch,
        "requests": len(prompts),
        "ring_fallbacks": 0,
        "state_bytes_page": eng_p.kv.state_bytes_page,
        "compute_hits": on["prefix"]["compute_hits"],
        "seed_copy_bytes": on["seed_copy_bytes"],
        "seed_copy_bytes_ring": off["seed_copy_bytes"],
        "snapshot_bytes": on["snapshot_bytes"],
        "snapshot_bytes_ring": off["snapshot_bytes"],
        "kernel_read_bytes": kernel_reads,
        "read_bytes_by_tier": per_tier_reads,  # hbm = weight stream
        "kv_tier_read_bytes": mrm_reads,
        "prefill_tokens_computed": on["prefill_tokens_computed"],
        "prefill_tokens_computed_ring": off["prefill_tokens_computed"],
        "tokens_generated": on["tokens_generated"],
        "ttft_p50_s": on["latency"]["ttft_p50"],
        "ttft_p50_ring_s": off["latency"]["ttft_p50"],
    }


def compute(arch="deepseek-7b") -> dict:
    from repro.configs import get_config, reduced
    from repro.core.memclass import HBM3E, MRM_RRAM
    from repro.core.simulator import MemorySystem
    from repro.models import init_params
    from repro.serving import EngineConfig, ServeEngine

    full = get_config(arch)
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    out = {}
    for session_s in (0.01, 1.0, 60.0, 3600.0):
        mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 40), "hbm": (HBM3E, 1 << 37)})
        eng = ServeEngine(cfg, params, mem,
                          EngineConfig(max_slots=2, max_cache_len=64,
                                       weight_tier="mrm", kv_tier="mrm",
                                       expected_session_s=session_s),
                          account_cfg=full)
        rng = np.random.default_rng(0)
        for _ in range(4):
            eng.submit(list(rng.integers(2, cfg.vocab_size, 16)), 10)
        rep = eng.run_until_idle()
        mrm = rep["memory"]["tiers"]["mrm"]
        out[f"session_{session_s}s"] = {
            "refresh_events": rep["memory"]["refresh_stats"]["refresh"],
            "refresh_gb": mrm["refresh_gb"],
            "write_gb": mrm["write_gb"],
            "energy_per_token_j": rep["energy_per_token_j"],
            "refresh_overhead": mrm["refresh_gb"] / max(mrm["write_gb"], 1e-12),
        }
    return out


def cluster_sweep(arch="deepseek-7b", replica_counts=(1, 2),
                  kv_capacity_bytes=1 << 25, requests=8) -> dict:
    """Replica sweep under a capacity-constrained MRM KV tier: chunked
    prefill on, pressure policy 'evict-lru' (prefix-LRU eviction with
    drop-and-recompute fallback). Asserts the pressure ledger balances and
    no allocation was silently dropped."""
    from repro.configs import get_config, reduced
    from repro.core.memclass import HBM3E, MRM_RRAM
    from repro.core.simulator import MemorySystem
    from repro.models import init_params
    from repro.serving import ClusterFrontend, EngineConfig, ServeEngine

    full = get_config(arch)
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    out = {}
    for n in replica_counts:
        engines = []
        for _ in range(n):
            mem = MemorySystem({"mrm": (MRM_RRAM, kv_capacity_bytes),
                                "hbm": (HBM3E, 1 << 34)})
            engines.append(ServeEngine(
                cfg, params, mem,
                EngineConfig(max_slots=2, max_cache_len=64, weight_tier="hbm",
                             kv_tier="mrm", eos_token=-1, chunk_tokens=16,
                             page_tokens=16,
                             kv_pressure_policy="evict-lru",
                             kv_high_watermark=0.9),
                account_cfg=full))
        fe = ClusterFrontend(engines)
        rng = np.random.default_rng(0)
        for i in range(requests):
            fe.submit(list(rng.integers(2, cfg.vocab_size, 40)), 8,
                      session_key=f"user-{i}")
        rep = fe.run_until_idle()
        p = rep["pressure"]
        resolved = (p["resolved_evict"] + p["resolved_spill"] +
                    p["resolved_recompute"])
        assert p["events"] > 0, "tier was supposed to be capacity-constrained"
        assert p["events"] == resolved + p["unresolved"], p
        assert p["unresolved"] == 0, p
        assert rep["dropped_allocs"] == 0, \
            f"silent drops under pressure: {rep['dropped_allocs']}"
        assert rep["tokens_generated"] == sum(
            r["tokens_generated"] for r in rep["per_replica"])
        out[f"replicas_{n}"] = {
            "finished": rep["finished"],
            "tokens_generated": rep["tokens_generated"],
            "fleet_tokens_per_s": rep["fleet_tokens_per_s"],
            "energy_per_token_j": rep["energy_per_token_j"],
            "pressure_events": p["events"],
            "pressure_resolved": resolved,
            "prefix_evictions": p["prefix_evictions"],
            "recompute_tokens": p["recompute_tokens"],
            "dropped_allocs": rep["dropped_allocs"],
            "prefix_hits": rep["prefix_hits"],
            "prefix_tokens_reused": rep["prefix_tokens_reused"],
            "radix_routed": rep["radix_routed"],
            "ttft_p50_s": rep["latency"]["ttft_p50"],
        }
    return out


def fleet_reuse(arch="deepseek-7b", replicas=3, fanout=12,
                seed_tail_tokens=16) -> dict:
    """Fleet-level prefix reuse: a shared system-prompt head fanned out
    across a cluster. With the fleet directory + migration on, the head
    is computed cold exactly once and then *moved* (metered interconnect
    transfer) wherever load sends its traffic; the per-replica baseline
    (no fleet awareness: sticky/least-loaded routing, per-replica radix
    trees) recomputes it cold on every replica it lands on.

    ``seed_tail_tokens=0`` makes the seed prompt exactly the shared head —
    the shape that exercises *point*-snapshot transfer for SSM/hybrid
    stacks (their state snapshot is only valid at the exact boundary the
    fan-out matches, DESIGN.md §8; a divergent seed tail would leave the
    boundary snapshot to the first borrower instead of the seed)."""
    from repro.configs import get_config, reduced
    from repro.core.memclass import HBM3E, MRM_RRAM
    from repro.core.simulator import MemorySystem
    from repro.models import init_params
    from repro.serving import ClusterFrontend, EngineConfig, ServeEngine

    full = get_config(arch)
    # fp32: the migrated-hit extend path must stay bit-equal to cold
    # prefill (same policy as prefix_reuse above)
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    head = list(rng.integers(2, cfg.vocab_size, 64))
    seed_tail = list(rng.integers(2, cfg.vocab_size, seed_tail_tokens))
    tails = [list(rng.integers(2, cfg.vocab_size, 16)) for _ in range(fanout)]

    def run_one(fleet: bool):
        engines = []
        for _ in range(replicas):
            mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 40),
                                "hbm": (HBM3E, 1 << 37)})
            engines.append(ServeEngine(
                cfg, params, mem,
                EngineConfig(max_slots=2, max_cache_len=96,
                             weight_tier="hbm", kv_tier="mrm",
                             eos_token=-1, chunk_tokens=16, page_tokens=16,
                             radix_hot_threshold=2),
                account_cfg=full))
        fe = ClusterFrontend(engines, migrate_prefixes=fleet,
                             interconnect_gbps=50.0, migrate_load_gap=1,
                             prefix_affinity=fleet)
        # wave 1 establishes the hot head on one replica...
        fe.submit(head + seed_tail, 6, session_key="seed")
        fe.run_until_idle()
        # ...then the fan-out wave arrives as a burst of distinct users
        for i, tail in enumerate(tails):
            fe.submit(head + tail, 6, session_key=f"fan-{i}")
        rep = fe.run_until_idle()
        outs = {r: list(fe.output(r)) for r in range(fanout + 1)}
        return fe, rep, outs

    fe_on, on, outs_on = run_one(True)
    fe_off, off, outs_off = run_one(False)
    assert on["tokens_generated"] == off["tokens_generated"]
    assert outs_on == outs_off, "fleet migration changed decoded tokens"

    def imbalance(rep):
        return sum(abs(r["pressure"]["events"]
                       - (r["pressure"]["resolved_evict"]
                          + r["pressure"]["resolved_spill"]
                          + r["pressure"]["resolved_recompute"]
                          + r["pressure"]["unresolved"]))
                   for r in rep["per_replica"])

    ledger_imbalance = imbalance(on) + imbalance(off)
    prefill_cut = 1 - (on["prefill_tokens_computed"]
                       / off["prefill_tokens_computed"])
    inter = on["interconnect"]
    assert ledger_imbalance == 0, (on["pressure"], off["pressure"])
    assert on["dropped_allocs"] == off["dropped_allocs"] == 0
    assert inter["migrations"] > 0 and inter["migration_bytes"] > 0, inter
    assert on["prefix_hits_migrated"] > 0, "no cross-replica hits"
    assert prefill_cut >= 0.20, f"fleet prefill cut {prefill_cut:.2%} < 20%"
    n_reqs = fanout + 1
    return {
        "replicas": replicas,
        "requests": n_reqs,
        "snapshot_kind": on["per_replica"][0]["prefix"]["snapshot_kind"],
        "prefill_tokens_fleet": on["prefill_tokens_computed"],
        "prefill_tokens_baseline": off["prefill_tokens_computed"],
        "prefill_cut": prefill_cut,
        "cross_replica_hits": on["prefix_hits_migrated"],
        "cross_replica_hit_rate": on["prefix_hits_migrated"] / n_reqs,
        "prefix_hits": on["prefix_hits"],
        "migrations": inter["migrations"],
        "migrated_tokens": inter["migrated_tokens"],
        "migration_bytes": inter["migration_bytes"],
        "migration_s": inter["migration_s"],
        "snapshot_bytes": on["snapshot_bytes"],
        "directory_entries": on["directory"]["entries"],
        "ledger_imbalance": ledger_imbalance,
        "dropped_allocs": on["dropped_allocs"],
        "ttft_p50_s": on["latency"]["ttft_p50"],
        "ttft_p50_baseline_s": off["latency"]["ttft_p50"],
    }


def reliability(arch="deepseek-7b", rber=1e-3, n_shares=3, head_tokens=32,
                ask_tokens=8, max_new=6, session_s=600.0) -> dict:
    """Fault-injection A/B gate (DESIGN.md §11). Three engine runs on the
    paged plane with identical prompts, greedy fp32 decode:

    - **clean** — domain ECC profile, refresh on, no injection;
    - **protected** — same, plus ``inject_rber``; after the first decode
      tokens the clock jumps to 80% of the refresh deadline, so the next
      page visits cross the scrub threshold deterministically (scrub-on-
      read corrects + re-arms, metered through the lifecycle) and decode
      must match the clean run within ``tolerance``;
    - **over-aged** — refresh servicing disabled and the clock jumped past
      4x the pages' programmed retention: RBER saturates, the strict code
      fails at the accounting scale (uncorrectable blocks > 0 in the
      report) and decoded tokens must degrade measurably vs protected.

    Also emits the per-retention-state ECC overhead ladder (mrm_rram)
    asserting the domain split code's check bits shrink vs the uniform
    code on every demoted-or-colder state — the density lever.
    """
    from repro.configs import get_config, reduced
    from repro.core.ecc import STATE_RETENTION_FRAC, TierEcc
    from repro.core.memclass import HBM3E, MRM_RRAM
    from repro.core.simulator import MemorySystem
    from repro.models import init_params
    from repro.serving import EngineConfig, ServeEngine

    full = get_config(arch)
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    head = list(rng.integers(2, cfg.vocab_size, head_tokens))
    prompts = [head + list(rng.integers(2, cfg.vocab_size, ask_tokens))
               for _ in range(n_shares)]
    # session pages are DCM-programmed at retention = 2 * session_s
    # (margin); the refresh deadline sits at half that
    retention_s = 2.0 * session_s
    deadline_s = retention_s / 2.0

    def run_one(inject, refresh=True, age_jump=0.0):
        mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 40),
                            "hbm": (HBM3E, 1 << 37)},
                           ecc_profile="domain", service_refresh=refresh)
        eng = ServeEngine(cfg, params, mem,
                          EngineConfig(max_slots=2, max_cache_len=96,
                                       weight_tier="hbm", kv_tier="mrm",
                                       eos_token=-1, chunk_tokens=16,
                                       page_tokens=16, tail_copy=False,
                                       paged_kernel=True,
                                       expected_session_s=session_s,
                                       inject_rber=inject, inject_seed=0),
                          account_cfg=full)
        for p in prompts:
            eng.submit(list(p), max_new)
        # run prefill through the first decode tokens, then age every page
        # with one clock jump before the remaining decode rounds
        steps = 0
        while not eng.sched.idle and eng.tokens_generated < 2 and steps < 500:
            eng.step()
            steps += 1
        if age_jump:
            eng.mem.advance(age_jump)
        rep = eng.run_until_idle()
        outs = {k: list(v) for k, v in eng.outputs.items()}
        return eng, rep, outs

    _, clean_rep, outs_clean = run_one(None, age_jump=0.8 * deadline_s)
    eng_p, prot_rep, outs_prot = run_one(rber, age_jump=0.8 * deadline_s)
    eng_o, over_rep, outs_over = run_one(rber, refresh=False,
                                         age_jump=4.0 * retention_s)

    def match_fraction(a, b):
        total = hits = 0
        for k, toks in a.items():
            other = b.get(k, [])
            total += max(len(toks), len(other))
            hits += sum(1 for x, y in zip(toks, other) if x == y)
        return hits / max(total, 1)

    prot_match = match_fraction(outs_clean, outs_prot)
    over_match = match_fraction(outs_clean, outs_over)
    prot_rel = prot_rep["reliability"]
    over_rel = over_rep["reliability"]
    # the CI gate: corrected decode holds, unrefreshed decode degrades
    assert prot_match >= 0.95, \
        f"protected decode match {prot_match:.2%} under RBER {rber}"
    assert prot_rel["injection"]["uncorrectable_blocks"] == 0, prot_rel
    assert eng_p.kv.lifecycle.stats.scrubbed_pages > 0, \
        "scrub-on-read never fired in the protected arm"
    assert prot_rel["tiers"]["mrm"]["scrub_read_bytes"] > 0
    assert prot_rel["tiers"]["mrm"]["ecc_write_bytes"] > 0
    assert over_rel["injection"]["uncorrectable_blocks"] > 0, \
        "over-aged pages must report uncorrectable blocks"
    assert over_match < prot_match, (over_match, prot_match)
    assert over_match <= 0.9, \
        f"over-aged decode match {over_match:.2%} — no measurable degradation"
    # density lever: the domain split code must spend fewer check bits
    # than the uniform-strong baseline on every demoted-or-colder state
    dom = TierEcc(MRM_RRAM, "domain")
    uni = TierEcc(MRM_RRAM, "uniform")
    ladder = {}
    for state, frac in STATE_RETENTION_FRAC.items():
        r = MRM_RRAM.retention_s * frac
        od, ou = dom.overhead_for("kv", r), uni.overhead_for("kv", r)
        ladder[state] = {"domain": od, "uniform": ou,
                         "shrink": 1.0 - od / ou}
        if state != "hot":
            assert od < ou, f"{state}: domain {od} !< uniform {ou}"
    return {
        "arch": arch,
        "inject_rber": rber,
        "requests": len(prompts),
        "tokens_generated": clean_rep["tokens_generated"],
        "protected_match": prot_match,
        "overaged_match": over_match,
        "scrubbed_pages": eng_p.kv.lifecycle.stats.scrubbed_pages,
        "scrub_read_bytes": prot_rel["tiers"]["mrm"]["scrub_read_bytes"],
        "ecc_write_bytes": prot_rel["tiers"]["mrm"]["ecc_write_bytes"],
        "ecc_read_bytes": prot_rel["tiers"]["mrm"]["ecc_read_bytes"],
        "protected_injection": prot_rel["injection"],
        "overaged_injection": over_rel["injection"],
        "overaged_uncorrectable": over_rel["injection"]["uncorrectable_blocks"],
        "ecc_overhead_ladder": ladder,
    }


def replication(scenario="rag_storm", preset="smoke", threshold=2,
                copies=7, min_ttft_cut=0.40, ttft_slack=0.02) -> dict:
    """Predictive prefix replication A/B on the fleet simulator
    (DESIGN.md §13): the same scenario run reactive (no replication —
    demand migrations only) vs predictive (directory hit counts cross
    ``threshold`` → speculative ``REPLICATION_PUSH`` pre-places the group
    on the ``copies`` least-loaded non-owners over the shared fabric).

    Gates, on bit-identical decoded tokens across both arms:

    - ``min_ttft_cut`` > 0 (the rag_storm arm): predictive TTFT p95 must
      land at least that fraction below the reactive baseline — the
      herald-led fan-out hits warm owners instead of piling on one;
    - ``min_ttft_cut`` = 0 (the diurnal arm): predictive TTFT p95 must
      not regress beyond ``ttft_slack``;
    - speculative push bytes > 0 and demand-migration count strictly
      below the reactive baseline (pre-placement absorbs the pulls);
    - the fabric byte ledger balances (transfers == migrated +
      replicated bytes, enforced by ``FleetSim.check``) and the trace
      digest is bit-stable across a rerun *and* a shuffled submission
      order (the event queue, not submission order, fixes the timeline).
    """
    import random
    from dataclasses import replace as dc_replace

    from repro.serving.fleet_sim import FleetSim

    from experiments.scenarios import build

    def run_one(predictive: bool, shuffle_seed=None):
        sc = build(scenario, preset)
        cfg = sc.fleet()
        if predictive:
            cfg = dc_replace(cfg, replicate_threshold=threshold,
                             replicate_copies=copies)
        sim = FleetSim(cfg)
        rng = random.Random(sc.seed)
        if shuffle_seed is None:
            sc.submit_all(sim, rng)
        else:   # open-loop only: shuffled submission must not move events
            reqs = list(sc.generate(rng))
            random.Random(shuffle_seed).shuffle(reqs)
            for r in reqs:
                sim.submit(r)
        rep = sim.run(max_events=20_000_000)
        sim.check()
        return rep

    base = run_one(False)
    pred = run_one(True)
    rerun = run_one(True)
    shuffled = run_one(True, shuffle_seed=1234)
    assert pred["trace"]["digest"] == rerun["trace"]["digest"], \
        f"{scenario}: predictive trace digest unstable across reruns"
    assert pred["trace"]["digest"] == shuffled["trace"]["digest"], \
        f"{scenario}: trace digest moved under submission shuffle"
    bf, pf = base["fleet"], pred["fleet"]
    assert pf["decoded_tokens"] == bf["decoded_tokens"], \
        (pf["decoded_tokens"], bf["decoded_tokens"])
    rp = pred["replication"]
    assert rp["replicated_bytes"] > 0, "no speculative push bytes metered"
    assert pf["migrations"] < bf["migrations"], \
        f"demand migrations {pf['migrations']} !< baseline {bf['migrations']}"
    ttft_base = base["slo"]["ttft"]["p95"]
    ttft_pred = pred["slo"]["ttft"]["p95"]
    ttft_cut = 1.0 - ttft_pred / ttft_base
    if min_ttft_cut > 0:
        assert ttft_cut >= min_ttft_cut, \
            f"{scenario}: TTFT p95 cut {ttft_cut:.2%} < {min_ttft_cut:.0%}"
    else:
        assert ttft_cut >= -ttft_slack, \
            f"{scenario}: predictive regressed TTFT p95 by {-ttft_cut:.2%}"
    shards = pred["directory"]
    assert shards["delta_batches"] <= shards["delta_ops"]
    return {
        "scenario": f"{scenario}/{preset}+replication",
        "threshold": threshold,
        "copies": copies,
        "ttft_p95_reactive_s": ttft_base,
        "ttft_p95_predictive_s": ttft_pred,
        "ttft_p95_cut": ttft_cut,
        "ttft_p99_reactive_s": base["slo"]["ttft"]["p99"],
        "ttft_p99_predictive_s": pred["slo"]["ttft"]["p99"],
        "decoded_tokens": pf["decoded_tokens"],
        "migrations_reactive": bf["migrations"],
        "migrations_predictive": pf["migrations"],
        "replication_pushes": rp["pushes_scheduled"],
        "replications": rp["replications"],
        "replicated_bytes": rp["replicated_bytes"],
        "pushes_deferred": rp["pushes_deferred"],
        "pushes_abandoned": rp["pushes_abandoned"],
        # every fabric byte is exactly one demand or speculative byte
        "ledger_imbalance": pred["fabric"]["bytes"]
        - pf["migrated_bytes"] - rp["replicated_bytes"],
        "fabric": pred["fabric"],
        "directory_shards": shards,
        "reuse_frac_reactive": bf["reuse_frac"],
        "reuse_frac_predictive": pf["reuse_frac"],
        "trace_digest": pred["trace"]["digest"],
    }


def run_replication(csv=True):
    """The ``replication`` benchmark suite (its own CI leg): reactive vs
    predictive on the herald-led rag_storm fan-out (hard >= 40% TTFT p95
    cut) and the diurnal tenant mix (migration elimination at flat TTFT),
    both persisted to BENCH_fleet.json alongside the fleet trajectory."""
    from repro.core.trajectory import persist_trajectory

    out = {}
    for key, kw in (
            ("rag_storm", dict(scenario="rag_storm", threshold=2, copies=7,
                               min_ttft_cut=0.40)),
            ("diurnal", dict(scenario="diurnal", threshold=4, copies=2,
                             min_ttft_cut=0.0))):
        t0 = time.perf_counter()
        entry = replication(**kw)
        dt = (time.perf_counter() - t0) * 1e6
        out[key] = entry
        persist_trajectory("BENCH_fleet.json", entry, key="scenario",
                           ignore=("at",))
        if csv:
            print(f"serving_sim/repl_{key}_ttft_p95_cut,{dt:.1f},"
                  f"{entry['ttft_p95_cut']:.4f}")
            print(f"serving_sim/repl_{key}_migrations,{dt:.1f},"
                  f"{entry['migrations_predictive']}")
            print(f"serving_sim/repl_{key}_migrations_reactive,{dt:.1f},"
                  f"{entry['migrations_reactive']}")
            print(f"serving_sim/repl_{key}_replicated_gb,{dt:.1f},"
                  f"{entry['replicated_bytes'] / 1e9:.4f}")
            print(f"serving_sim/repl_{key}_pushes_deferred,{dt:.1f},"
                  f"{entry['pushes_deferred']}")
    return out


def _persist_paged_trajectory(entry: dict) -> None:
    """Append the paged_kernel sweep result to BENCH_paged.json at the
    repo root — the benchmark trajectory file CI and later sessions diff
    against (acceptance: seed_copy_bytes stays 0 while the ring
    comparator stays > 0). The sweep is deterministic, so re-runs of the
    same code produce identical metrics: an entry whose metric fields
    match the last persisted entry (for the same arch) is dropped instead
    of appended — ``at`` is tiebreak metadata, not a metric, and without
    the dedupe every CI run grew the file by one duplicate row."""
    _persist_trajectory("BENCH_paged.json", entry)


def _persist_reliability_trajectory(entry: dict) -> None:
    """Append the reliability sweep result to BENCH_reliability.json —
    the CI artifact tracking decode-match / scrub / uncorrectable metrics
    run over run (same dedupe rule as the paged trajectory)."""
    _persist_trajectory("BENCH_reliability.json", entry)


def _persist_trajectory(filename: str, entry: dict) -> None:
    # shared with experiments/run_fleet.py (BENCH_fleet.json) — one
    # dedupe-on-identical-metrics rule for every trajectory file
    from repro.core.trajectory import persist_trajectory
    persist_trajectory(filename, entry, key="arch")


def run_reliability(csv=True):
    """The ``reliability`` benchmark suite (its own CI leg — the fault-
    injection gate is an A/B over three full engine runs and stays out of
    the smoke-path serving suite)."""
    t0 = time.perf_counter()
    rel = reliability()
    dt = (time.perf_counter() - t0) * 1e6
    _persist_reliability_trajectory(rel)
    if csv:
        print(f"serving_sim/reliability_protected_match,{dt:.1f},"
              f"{rel['protected_match']:.4f}")
        print(f"serving_sim/reliability_overaged_match,{dt:.1f},"
              f"{rel['overaged_match']:.4f}")
        print(f"serving_sim/reliability_scrubbed_pages,{dt:.1f},"
              f"{rel['scrubbed_pages']}")
        print(f"serving_sim/reliability_uncorrectable,{dt:.1f},"
              f"{rel['overaged_uncorrectable']}")
        for state, row in rel["ecc_overhead_ladder"].items():
            print(f"serving_sim/reliability_ecc_shrink_{state},{dt:.1f},"
                  f"{row['shrink']:.4f}")
    return {"reliability": rel}


def run(csv=True):
    t0 = time.perf_counter()
    out = compute()
    dt = (time.perf_counter() - t0) * 1e6
    if csv:
        for k, v in out.items():
            print(f"serving_sim/{k}_refresh_overhead,{dt:.1f},{v['refresh_overhead']:.4f}")
            print(f"serving_sim/{k}_energy_per_token,{dt:.1f},{v['energy_per_token_j']:.3e}")
    t0 = time.perf_counter()
    fleet = cluster_sweep()
    dt = (time.perf_counter() - t0) * 1e6
    out.update(fleet)
    if csv:
        for k, v in fleet.items():
            print(f"serving_sim/{k}_fleet_tokens_per_s,{dt:.1f},{v['fleet_tokens_per_s']:.4f}")
            print(f"serving_sim/{k}_pressure_events,{dt:.1f},{v['pressure_events']}")
            print(f"serving_sim/{k}_dropped_allocs,{dt:.1f},{v['dropped_allocs']}")
    # prefix reuse must be real compute savings for EVERY snapshot family
    # (ISSUE 4): attention ring caches, SSM point snapshots, hybrid union
    # the hybrid sweep runs a denser fan-out: a point-snapshot stack pays
    # one capture recompute per observed boundary before borrowers save
    for key, reuse_arch, wkw in (
            ("prefix_reuse", "deepseek-7b", {}),
            ("prefix_reuse_ssm", "mamba2-2.7b", {}),
            ("prefix_reuse_hybrid", "hymba-1.5b",
             dict(n_system=8, turns=3, fanout=8))):
        t0 = time.perf_counter()
        reuse = prefix_reuse(reuse_arch, **wkw)
        dt = (time.perf_counter() - t0) * 1e6
        out[key] = reuse
        if csv:
            tag = key.replace("prefix_reuse", "prefix")
            print(f"serving_sim/{tag}_hit_rate,{dt:.1f},{reuse['prefix_hit_rate']:.4f}")
            print(f"serving_sim/{tag}_tokens_reused,{dt:.1f},{reuse['tokens_reused']}")
            print(f"serving_sim/{tag}_prefill_cut,{dt:.1f},{reuse['prefill_cut']:.4f}")
            if reuse["kv_write_cut"] is not None:
                print(f"serving_sim/{tag}_kv_write_cut,{dt:.1f},{reuse['kv_write_cut']:.4f}")
            print(f"serving_sim/{tag}_ttft_p50_s,{dt:.1f},{reuse['ttft_p50_s']:.6f}")
    # paged compute plane (DESIGN.md §10), now universal (ISSUE 7):
    # zero-copy hits, bit-identical tokens, the KV-tier read stream ==
    # the kernel's page gathers, and zero ring fallbacks for the
    # recurrent families; trajectory persists to BENCH_paged.json
    for key, paged_arch in (("paged_kernel", "deepseek-7b"),
                            ("paged_kernel_ssm", "mamba2-2.7b"),
                            ("paged_kernel_hybrid", "hymba-1.5b")):
        t0 = time.perf_counter()
        paged = paged_kernel(paged_arch)
        dt = (time.perf_counter() - t0) * 1e6
        out[key] = paged
        _persist_paged_trajectory(paged)
        if csv:
            tag = key.replace("paged_kernel", "paged")
            print(f"serving_sim/{tag}_seed_copy_bytes,{dt:.1f},"
                  f"{paged['seed_copy_bytes']:.0f}")
            print(f"serving_sim/{tag}_seed_copy_bytes_ring,{dt:.1f},"
                  f"{paged['seed_copy_bytes_ring']:.0f}")
            print(f"serving_sim/{tag}_kernel_read_gb,{dt:.1f},"
                  f"{paged['kernel_read_bytes'] / 1e9:.4f}")
            print(f"serving_sim/{tag}_ring_fallbacks,{dt:.1f},"
                  f"{paged['ring_fallbacks']}")
            print(f"serving_sim/{tag}_compute_hits,{dt:.1f},"
                  f"{paged['compute_hits']}")
            print(f"serving_sim/{tag}_ttft_p50_s,{dt:.1f},"
                  f"{paged['ttft_p50_s']:.6f}")
    # sub-page tails: boundary-straddling prefixes must beat the
    # page-aligned cut strictly (DESIGN.md §9)
    t0 = time.perf_counter()
    tail = tail_reuse()
    dt = (time.perf_counter() - t0) * 1e6
    out["tail_reuse"] = tail
    if csv:
        print(f"serving_sim/tail_prefill_cut,{dt:.1f},{tail['prefill_cut']:.4f}")
        print(f"serving_sim/tail_prefill_cut_page_aligned,{dt:.1f},"
              f"{tail['prefill_cut_page_aligned']:.4f}")
        print(f"serving_sim/tail_hits,{dt:.1f},{tail['tail_hits']}")
        print(f"serving_sim/tail_copy_bytes,{dt:.1f},{tail['tail_copy_bytes']:.0f}")
    for key, fleet_arch, seed_tail in (("fleet_reuse", "deepseek-7b", 16),
                                       ("fleet_reuse_ssm", "mamba2-2.7b", 0)):
        t0 = time.perf_counter()
        fleet_r = fleet_reuse(fleet_arch, seed_tail_tokens=seed_tail)
        dt = (time.perf_counter() - t0) * 1e6
        out[key] = fleet_r
        if csv:
            tag = key.replace("fleet_reuse", "fleet")
            print(f"serving_sim/{tag}_prefill_cut,{dt:.1f},{fleet_r['prefill_cut']:.4f}")
            print(f"serving_sim/{tag}_cross_replica_hits,{dt:.1f},{fleet_r['cross_replica_hits']}")
            print(f"serving_sim/{tag}_migrations,{dt:.1f},{fleet_r['migrations']}")
            print(f"serving_sim/{tag}_migration_gb,{dt:.1f},{fleet_r['migration_bytes'] / 1e9:.4f}")
            print(f"serving_sim/{tag}_ledger_imbalance,{dt:.1f},{fleet_r['ledger_imbalance']}")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1, default=float))
