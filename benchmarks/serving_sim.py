"""Retention-policy sweep (paper §4: DCM 'right-provisioning'): vary the
DCM expected-session-lifetime programming and measure refresh overhead vs
write energy — the knob the cluster control plane owns."""
from __future__ import annotations

import time

import jax
import numpy as np


def compute(arch="deepseek-7b") -> dict:
    from repro.configs import get_config, reduced
    from repro.core.memclass import HBM3E, MRM_RRAM
    from repro.core.simulator import MemorySystem
    from repro.models import init_params
    from repro.serving import EngineConfig, ServeEngine

    full = get_config(arch)
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    out = {}
    for session_s in (0.01, 1.0, 60.0, 3600.0):
        mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 40), "hbm": (HBM3E, 1 << 37)})
        eng = ServeEngine(cfg, params, mem,
                          EngineConfig(max_slots=2, max_cache_len=64,
                                       weight_tier="mrm", kv_tier="mrm",
                                       expected_session_s=session_s),
                          account_cfg=full)
        rng = np.random.default_rng(0)
        for _ in range(4):
            eng.submit(list(rng.integers(2, cfg.vocab_size, 16)), 10)
        rep = eng.run_until_idle()
        mrm = rep["memory"]["tiers"]["mrm"]
        out[f"session_{session_s}s"] = {
            "refresh_events": rep["memory"]["refresh_stats"]["refresh"],
            "refresh_gb": mrm["refresh_gb"],
            "write_gb": mrm["write_gb"],
            "energy_per_token_j": rep["energy_per_token_j"],
            "refresh_overhead": mrm["refresh_gb"] / max(mrm["write_gb"], 1e-12),
        }
    return out


def run(csv=True):
    t0 = time.perf_counter()
    out = compute()
    dt = (time.perf_counter() - t0) * 1e6
    if csv:
        for k, v in out.items():
            print(f"serving_sim/{k}_refresh_overhead,{dt:.1f},{v['refresh_overhead']:.4f}")
            print(f"serving_sim/{k}_energy_per_token,{dt:.1f},{v['energy_per_token_j']:.3e}")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1, default=float))
