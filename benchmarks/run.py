"""Benchmark harness — one module per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV per the scaffold convention.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig1 tco   # subset
"""
from __future__ import annotations

import sys
import traceback

SUITES = ("fig1", "workload", "tco", "serving", "kernels", "roofline")


def main() -> None:
    want = set(sys.argv[1:]) or set(SUITES)
    failures = []

    if "fig1" in want:
        from benchmarks import endurance_fig1
        _run("endurance_fig1", endurance_fig1.run, failures)
    if "workload" in want:
        from benchmarks import workload_characterization
        _run("workload_characterization", workload_characterization.run, failures)
    if "tco" in want:
        from benchmarks import mrm_tco
        _run("mrm_tco", mrm_tco.run, failures)
    if "serving" in want:
        from benchmarks import serving_sim
        _run("serving_sim", serving_sim.run, failures)
    if "kernels" in want:
        from benchmarks import kernels
        _run("kernels", kernels.run, failures)
    if "roofline" in want:
        from benchmarks import roofline
        _run("roofline", roofline.run, failures)

    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


def _run(name, fn, failures):
    try:
        fn(csv=True)
    except Exception:
        traceback.print_exc()
        failures.append(name)


if __name__ == "__main__":
    main()
