"""Benchmark harness — one module per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV per the scaffold convention; with
``--json out.json`` it additionally writes a machine-readable trajectory
(suite -> metric -> value) for CI tracking.

  PYTHONPATH=src python -m benchmarks.run                   # all
  PYTHONPATH=src python -m benchmarks.run fig1 tco          # subset
  PYTHONPATH=src python -m benchmarks.run serving --json out.json
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

SUITES = ("fig1", "workload", "tco", "serving", "kernels", "kernel_bench",
          "roofline", "reliability", "replication")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("suites", nargs="*",
                    help=f"subset of suites (default: all of {SUITES})")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="write a suite->metric->value JSON trajectory")
    args = ap.parse_args(argv)
    unknown = set(args.suites) - set(SUITES)
    if unknown:
        ap.error(f"unknown suites {sorted(unknown)}; choose from {SUITES}")
    want = set(args.suites) or set(SUITES)
    failures = []
    results = {}

    if "fig1" in want:
        from benchmarks import endurance_fig1
        results["fig1"] = _run("endurance_fig1", endurance_fig1.run, failures)
    if "workload" in want:
        from benchmarks import workload_characterization
        results["workload"] = _run("workload_characterization",
                                   workload_characterization.run, failures)
    if "tco" in want:
        from benchmarks import mrm_tco
        results["tco"] = _run("mrm_tco", mrm_tco.run, failures)
    if "serving" in want:
        from benchmarks import serving_sim
        results["serving"] = _run("serving_sim", serving_sim.run, failures)
    if "kernels" in want:
        from benchmarks import kernels
        results["kernels"] = _run("kernels", kernels.run, failures)
    if "kernel_bench" in want:
        from benchmarks import kernel_bench
        results["kernel_bench"] = _run("kernel_bench", kernel_bench.run,
                                       failures)
    if "roofline" in want:
        from benchmarks import roofline
        results["roofline"] = _run("roofline", roofline.run, failures)
    if "reliability" in want:
        from benchmarks import serving_sim
        results["reliability"] = _run("serving_sim.reliability",
                                      serving_sim.run_reliability, failures)
    if "replication" in want:
        from benchmarks import serving_sim
        results["replication"] = _run("serving_sim.replication",
                                      serving_sim.run_replication, failures)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": results, "failures": failures}, f,
                      indent=1, default=float)
        print(f"# wrote {args.json}", file=sys.stderr)

    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


def _run(name, fn, failures):
    try:
        return fn(csv=True)
    except Exception:
        traceback.print_exc()
        failures.append(name)
        return None


if __name__ == "__main__":
    main()
