"""Paged-attention kernel microbench: grouped grid vs the ungrouped
PR 6 gather on sparse page tables, per-config timings, and a persisted
trajectory.

For each (page_size, head_dim) geometry the bench builds a ragged batch
on a *sparse* table (interior null slots — the shape radix splices and
windowed decode produce) and runs

- the **grouped, null-skipping grid** under a handful of
  (block_q, block_kv, num_buffers) configs (timed per config), and
- the **ungrouped baseline** (``skip_blocks=False``: one full-width
  gather per sequence, nulls masked in-register — the PR 6 behavior),

asserting the outputs bit-equal each other and the jnp reference (fp32)
— identical decoded values — and metering the *achieved page-read
bytes* of each grid with the kernel's host-side gather replica
(``kernel.pages_gathered``). On sparse tables the grouped grid must read
strictly less; smoke.sh gates that from the persisted trajectory.

Results append to ``BENCH_kernels.json`` at the repo root:
``{"entries": [{at, arch, cases: [{page_size, head_dim, read-bytes per
grid, configs: [{block_q, block_kv, num_buffers, time_us}, ...]}]}]}``.
Timings are wall-clock per call (interpret mode off TPU — ranking, not
absolute numbers; the arch field says which kind a row is).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

GEOMETRIES = ((8, 16), (16, 16), (32, 16))
CONFIGS = ((8, 4, 2), (16, 8, 2), (16, 8, 4), (32, 16, 3))

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_kernels.json")


def _sparse_case(ps: int, D: int, *, Hkv: int = 2, G: int = 2,
                 seqs: int = 3, width: int = 8, seed: int = 0):
    """Ragged batch over mostly-null tables: every other slot of each
    row is the null page, query lengths mix decode and extend."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    W = width
    P = 1 + seqs * W
    kv = jnp.asarray(rng.standard_normal((P, ps, 2 * Hkv, D)),
                     jnp.float32).at[0].set(0.0)
    tbl = np.zeros((seqs, W), np.int32)
    kvl = np.zeros((seqs,), np.int32)
    q_lens = []
    for s in range(seqs):
        used = W - s % 3
        for j in range(used):
            if j % 2 == 1:
                continue                      # interior null slot
            tbl[s, j] = 1 + s * W + j
        kvl[s] = used * ps - (s % ps)
        q_lens.append(1 + (s * 7) % (2 * ps))  # decode + ragged extend
    cu = np.concatenate([[0], np.cumsum(q_lens)]).astype(np.int32)
    q = jnp.asarray(rng.standard_normal((int(cu[-1]), Hkv * G, D)),
                    jnp.float32)
    return (q, kv, jnp.asarray(tbl), jnp.asarray(cu), jnp.asarray(kvl),
            int(max(q_lens)))


def _time_call(fn, repeats: int = 3) -> float:
    fn()                                       # compile / warm the cache
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats * 1e6


def bench_case(ps: int, D: int, repeats: int = 3) -> dict:
    """One geometry: grouped configs + ungrouped baseline, bit-equality
    against the reference, achieved page-read bytes per grid."""
    import jax.numpy as jnp

    from repro.kernels.paged_attention.kernel import pages_gathered
    from repro.kernels.paged_attention.ops import ragged_paged_attention
    from repro.kernels.paged_attention.ref import ragged_paged_attention_ref

    q, kv, tbl, cu, kvl, max_q = _sparse_case(ps, D)
    scale = 1.0 / D ** 0.5
    ref = ragged_paged_attention_ref(q, kv, tbl, cu, kvl, scale=scale)
    page_bytes = ps * kv.shape[2] * D * kv.dtype.itemsize

    def call(**kw):
        return ragged_paged_attention(
            q, kv, tbl, cu, kvl, scale=scale, max_q_len=max_q,
            backend="pallas", **kw).block_until_ready()

    configs = []
    for bq, bkv, nb in CONFIGS:
        out = call(block_q=bq, block_kv=bkv, num_buffers=nb)
        assert np.array_equal(np.asarray(out), np.asarray(ref)), \
            f"grouped grid ps={ps} cfg=({bq},{bkv},{nb}) diverged from ref"
        configs.append({
            "block_q": bq, "block_kv": bkv, "num_buffers": nb,
            "time_us": _time_call(
                lambda: call(block_q=bq, block_kv=bkv, num_buffers=nb),
                repeats),
        })
    base = call(skip_blocks=False)
    assert np.array_equal(np.asarray(base), np.asarray(ref)), \
        f"ungrouped baseline ps={ps} diverged from ref"
    time_base = _time_call(lambda: call(skip_blocks=False), repeats)

    pages_grouped = pages_gathered(tbl, cu, kvl, page_size=ps,
                                   max_q_len=max_q, block_q=CONFIGS[0][0])
    pages_full = pages_gathered(tbl, cu, kvl, page_size=ps,
                                max_q_len=max_q, skip_blocks=False)
    assert 0 < pages_grouped < pages_full, (pages_grouped, pages_full)
    return {
        "page_size": ps,
        "head_dim": D,
        "seqs": int(tbl.shape[0]),
        "table_width": int(tbl.shape[1]),
        "query_rows": int(q.shape[0]),
        "pages_read_grouped": pages_grouped,
        "pages_read_ungrouped": pages_full,
        "kernel_read_bytes_grouped": pages_grouped * page_bytes,
        "kernel_read_bytes_ungrouped": pages_full * page_bytes,
        "read_bytes_cut": 1.0 - pages_grouped / pages_full,
        "time_us_ungrouped": time_base,
        "configs": configs,
    }


def _persist(entry: dict) -> None:
    data = {"entries": []}
    if os.path.exists(BENCH_PATH):
        try:
            with open(BENCH_PATH) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {"entries": []}
    data.setdefault("entries", []).append(entry)
    with open(BENCH_PATH, "w") as f:
        json.dump(data, f, indent=1, default=float)
        f.write("\n")


def run(csv: bool = True) -> dict:
    import jax

    from repro.kernels.paged_attention.tune import _arch

    cases = []
    for ps, D in GEOMETRIES:
        t0 = time.perf_counter()
        case = bench_case(ps, D)
        dt = (time.perf_counter() - t0) * 1e6
        cases.append(case)
        if csv:
            tag = f"kernel_bench/ps{ps}_d{D}"
            print(f"{tag}_read_bytes_grouped,{dt:.1f},"
                  f"{case['kernel_read_bytes_grouped']}")
            print(f"{tag}_read_bytes_ungrouped,{dt:.1f},"
                  f"{case['kernel_read_bytes_ungrouped']}")
            print(f"{tag}_read_cut,{dt:.1f},{case['read_bytes_cut']:.4f}")
            best = min(case["configs"], key=lambda c: c["time_us"])
            print(f"{tag}_best_config,{dt:.1f},"
                  f"bq{best['block_q']}-bkv{best['block_kv']}"
                  f"-nb{best['num_buffers']}")
    entry = {"at": time.time(), "arch": _arch(),
             "backend": jax.default_backend(), "cases": cases}
    _persist(entry)
    return entry


if __name__ == "__main__":
    print(json.dumps(run(csv=False), indent=1, default=float))
