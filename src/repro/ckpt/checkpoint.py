"""Sharded, atomic, async checkpointing with resharding-on-restore.

Layout: <dir>/step_<N>/ arrays.npz (path-keyed leaves) + manifest.json
(step, arch, pytree paths, dtypes, shapes). Writes go to a tmp dir + atomic
rename, so a crash mid-save never corrupts the latest checkpoint. ``save``
can run in a background thread (async off the training critical path);
``restore`` applies new shardings (mesh-shape-agnostic — the elastic
re-mesh path restores onto whatever mesh is available).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None,
         keep_last: int = 3) -> str:
    """Synchronous atomic save. Returns the checkpoint path."""
    base = pathlib.Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _flatten(tree)
    arrays = {}
    for i, (_, leaf) in enumerate(leaves):
        a = np.asarray(leaf)
        if a.dtype.name == "bfloat16":  # npz can't serialize ml_dtypes
            a = a.view(np.uint16)
        arrays[f"a{i}"] = a
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "paths": [p for p, _ in leaves],
        "dtypes": [str(np.asarray(l).dtype) for _, l in leaves],
        "shapes": [list(np.asarray(l).shape) for _, l in leaves],
        "extra": extra or {},
        "saved_at": time.time(),
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(base, keep_last)
    return str(final)


def save_async(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None,
               keep_last: int = 3) -> threading.Thread:
    """Snapshot to host memory now; write in a background thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree, extra,
                                            keep_last), daemon=True)
    t.start()
    return t


def _gc(base: pathlib.Path, keep_last: int) -> None:
    steps = sorted(p for p in base.iterdir() if p.name.startswith("step_"))
    for p in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in base.iterdir()
                   if p.name.startswith("step_"))
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like,
            shardings=None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional pytree of NamedShardings —
    arrays are device_put with them (resharding restore; works across mesh
    shapes because the on-disk format is unsharded host arrays)."""
    path = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    import ml_dtypes
    with np.load(path / "arrays.npz") as z:
        arrays = {}
        for i in range(len(manifest["paths"])):
            a = z[f"a{i}"]
            if manifest["dtypes"][i] == "bfloat16":
                a = a.view(ml_dtypes.bfloat16)
            arrays[manifest["paths"][i]] = a

    like_flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in like_flat[0]:
        key = jax.tree_util.keystr(p)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        a = arrays[key]
        want_shape = tuple(leaf.shape)
        if tuple(a.shape) != want_shape:
            raise ValueError(f"{key}: shape {a.shape} != {want_shape}")
        leaves.append(a)
    tree = jax.tree_util.tree_unflatten(like_flat[1], leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(
            lambda a, l: jax.numpy.asarray(a, dtype=getattr(l, "dtype", None)),
            tree, like)
    return tree, manifest["extra"]
