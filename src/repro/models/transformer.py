"""Model assembly: embeddings -> scanned layer groups -> head.

Layers are grouped into runs of identical (or 2-alternating) LayerSpecs
(`ModelConfig.scan_groups`) and executed under `lax.scan` with stacked
parameters — this keeps HLO size and compile time bounded for 80-layer
models and is what makes the 512-device dry-run tractable.

Entry points:
- ``loss_and_metrics`` — training forward (+ seq-chunked CE so the
  (B, S, vocab) logits tensor never materializes);
- ``prefill``          — the *maximal first chunk* of the one unpadded
  serving path (DESIGN.md §5): embeds the meta/frontend prefix + prompt
  tokens at absolute positions 0..S-1 into fresh per-group caches
  (ring-buffered to the window for local-attention layers);
- ``extend``           — every later chunk: new tokens at absolute
  positions against the carried caches;
- ``decode``           — one-token step against the caches.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig, ScanGroup
from repro.models import param as prm
from repro.models.attention import (abstract_cache, attention_sublayer, attn_defs,
                                    cache_len_for, init_cache)
from repro.models.hybrid import hybrid_defs, hybrid_sublayer
from repro.models.layers import embed, embed_defs, lm_logits, mlp, mlp_defs, rmsnorm, rmsnorm_def
from repro.models.mla import mla_cache_init, mla_defs, mla_sublayer
from repro.models.moe import moe_defs, moe_sublayer
from repro.models.param import ParamDef
from repro.models.ssm import (ssm_cache_init, ssm_defs, ssm_paged_init,
                              ssm_sublayer)

# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def block_defs(cfg: ModelConfig, spec: LayerSpec) -> dict:
    defs: dict = {"norm1": rmsnorm_def(cfg.d_model)}
    if spec.kind == "attn":
        defs["mixer"] = attn_defs(cfg)
    elif spec.kind == "mla":
        defs["mixer"] = mla_defs(cfg)
    elif spec.kind == "ssm":
        defs["mixer"] = ssm_defs(cfg)
    elif spec.kind == "hybrid":
        defs["mixer"] = hybrid_defs(cfg)
    if cfg.post_norms:
        defs["post_norm1"] = rmsnorm_def(cfg.d_model)
    if spec.mlp != "none":
        defs["norm2"] = rmsnorm_def(cfg.d_model)
        defs["mlp"] = moe_defs(cfg) if spec.mlp == "moe" else mlp_defs(cfg, cfg.d_ff)
        if cfg.post_norms:
            defs["post_norm2"] = rmsnorm_def(cfg.d_model)
    return defs


def model_defs(cfg: ModelConfig) -> dict:
    groups = []
    for g in cfg.scan_groups():
        unit_defs = tuple(prm.stack_defs(block_defs(cfg, spec), g.repeats) for spec in g.unit)
        groups.append(unit_defs)
    defs = {
        "embed": embed_defs(cfg),
        "groups": tuple(groups),
        "final_norm": rmsnorm_def(cfg.d_model),
    }
    if cfg.n_meta_tokens:
        defs["meta_tokens"] = ParamDef((cfg.n_meta_tokens, cfg.d_model),
                                       (None, "embed"), std=0.02)
    return defs


def init_params(cfg: ModelConfig, key: jax.Array):
    return prm.materialize(model_defs(cfg), key, cfg.param_dtype)


def abstract_params(cfg: ModelConfig, shardings=None):
    return prm.abstract(model_defs(cfg), cfg.param_dtype, shardings)


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def apply_block(cfg: ModelConfig, spec: LayerSpec, p: dict, x, *, positions,
                sh=None, cache=None, mode="train", cur_pos=None,
                decode_active=None, page_table=None, page_tokens=None):
    """Pre-norm residual block. Returns (x, new_cache, aux). With
    ``page_table`` every mixer family computes in place on pooled pages
    (DESIGN.md §10): KV pages for attention/MLA, conv+SSD state pages for
    SSM, both for the hybrid union (``page_tokens`` is the static page
    size the point stacks need to resolve state-page slots)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        h, new_cache = attention_sublayer(cfg, p["mixer"], h, positions=positions,
                                          window=spec.window, sh=sh, cache=cache,
                                          mode=mode, cur_pos=cur_pos,
                                          decode_active=decode_active,
                                          page_table=page_table)
    elif spec.kind == "mla":
        h, new_cache = mla_sublayer(cfg, p["mixer"], h, positions=positions, sh=sh,
                                    cache=cache, mode=mode, cur_pos=cur_pos,
                                    decode_active=decode_active,
                                    page_table=page_table)
    elif spec.kind == "ssm":
        h, new_cache = ssm_sublayer(cfg, p["mixer"], h, sh=sh, cache=cache,
                                    mode=mode, decode_active=decode_active,
                                    positions=positions, cur_pos=cur_pos,
                                    page_table=page_table,
                                    page_tokens=page_tokens)
    elif spec.kind == "hybrid":
        h, new_cache = hybrid_sublayer(cfg, p["mixer"], h, positions=positions,
                                       window=spec.window, sh=sh, cache=cache,
                                       mode=mode, cur_pos=cur_pos,
                                       decode_active=decode_active,
                                       page_table=page_table,
                                       page_tokens=page_tokens)
    else:
        raise ValueError(spec.kind)
    if cfg.post_norms:
        h = rmsnorm(h, p["post_norm1"], cfg.norm_eps)
    x = x + h
    if sh is not None:
        x = sh.c(x, ("act_batch", "act_seq_res", "act_embed"))

    if spec.mlp != "none":
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if spec.mlp == "moe":
            h, aux = moe_sublayer(cfg, p["mlp"], h, sh=sh)
        else:
            h = mlp(cfg, p["mlp"], h, constrain=(sh.c if sh is not None else None))
        if cfg.post_norms:
            h = rmsnorm(h, p["post_norm2"], cfg.norm_eps)
        x = x + h
        if sh is not None:
            x = sh.c(x, ("act_batch", "act_seq_res", "act_embed"))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def _unit_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int,
                dtype, abstract: bool):
    if spec.kind == "attn":
        clen = cache_len_for(spec.window, max_len)
        return (abstract_cache if abstract else init_cache)(cfg, batch, clen, dtype)
    if spec.kind == "mla":
        return mla_cache_init(cfg, batch, max_len, dtype, abstract=abstract)
    if spec.kind == "ssm":
        return ssm_cache_init(cfg, batch, dtype, abstract=abstract)
    if spec.kind == "hybrid":
        clen = cache_len_for(spec.window, max_len)
        return {
            "attn": (abstract_cache if abstract else init_cache)(cfg, batch, clen, dtype),
            "ssm": ssm_cache_init(cfg, batch, dtype, abstract=abstract),
        }
    raise ValueError(spec.kind)


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
                abstract: bool = False):
    """Per-group tuple of per-unit-position caches stacked over repeats."""
    def stack(tree, r):
        if abstract:
            return jax.tree.map(lambda s: jax.ShapeDtypeStruct((r,) + s.shape, s.dtype), tree)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (r,) + a.shape).copy()
                            if hasattr(a, "shape") else a, tree)

    groups = []
    for g in cfg.scan_groups():
        groups.append(tuple(
            stack(_unit_cache(cfg, spec, batch, max_len, dtype, abstract), g.repeats)
            for spec in g.unit))
    return tuple(groups)


def _paged_unit_cache(cfg: ModelConfig, spec: LayerSpec, n_pages: int,
                      page_tokens: int, dtype):
    """One unit's paged-plane pool (DESIGN.md §10). Page id 0 is the
    reserved null page. Attention pages hold fused head-interleaved KV;
    MLA pages hold one fused latent head: K' = [c, kr], V' = [c, 0];
    SSM pages hold the conv left-context + SSD recurrent state after the
    last written token of the page (point-state pages); hybrid pages are
    the union of the attention and SSM pools under one table."""
    if spec.kind == "attn":
        shape = (n_pages, page_tokens, 2 * cfg.n_kv_heads,
                 cfg.resolved_head_dim)
        return {"kv_pages": jnp.zeros(shape, dtype)}
    if spec.kind == "mla":
        shape = (n_pages, page_tokens, 2, cfg.kv_lora_rank + cfg.qk_rope_dim)
        return {"kv_pages": jnp.zeros(shape, dtype)}
    if spec.kind == "ssm":
        return ssm_paged_init(cfg, n_pages, dtype)
    if spec.kind == "hybrid":
        shape = (n_pages, page_tokens, 2 * cfg.n_kv_heads,
                 cfg.resolved_head_dim)
        return {"attn": {"kv_pages": jnp.zeros(shape, dtype)},
                "ssm": ssm_paged_init(cfg, n_pages, dtype)}
    raise ValueError(spec.kind)


def init_paged_caches(cfg: ModelConfig, n_pages: int, page_tokens: int,
                      dtype=jnp.bfloat16):
    """Per-group tuple of per-unit page pools stacked over repeats —
    shaped like ``init_caches`` output so the scan machinery is shared,
    but sized by pool pages instead of (batch, ring)."""
    groups = []
    for g in cfg.scan_groups():
        groups.append(tuple(
            jax.tree.map(lambda a: jnp.broadcast_to(
                a, (g.repeats,) + a.shape).copy(),
                _paged_unit_cache(cfg, spec, n_pages, page_tokens, dtype))
            for spec in g.unit))
    return tuple(groups)


# ---------------------------------------------------------------------------
# Trunk
# ---------------------------------------------------------------------------


def _embed_inputs(cfg: ModelConfig, params, batch: dict, sh=None):
    """tokens (+ frontend embeds + meta tokens) -> x (B, S_total, d), and the
    index of the first 'real' output position (for loss slicing)."""
    x = embed(cfg, params["embed"], batch["tokens"])
    prefix = 0
    if cfg.frontend == "vision" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(x.dtype)
        x = jnp.concatenate([img, x], axis=1)
        prefix += img.shape[1]
    if cfg.n_meta_tokens:
        B = x.shape[0]
        meta = jnp.broadcast_to(params["meta_tokens"][None].astype(x.dtype),
                                (B, cfg.n_meta_tokens, cfg.d_model))
        x = jnp.concatenate([meta, x], axis=1)
        prefix += cfg.n_meta_tokens
    if sh is not None:
        x = sh.c(x, ("act_batch", "act_seq_res", "act_embed"))
    return x, prefix


def apply_groups(cfg: ModelConfig, params, x, *, positions, sh=None,
                 caches=None, mode="train", cur_pos=None, decode_active=None,
                 page_table=None, page_tokens=None):
    """Run every scan group. Returns (x, new_caches, aux_total)."""
    groups = cfg.scan_groups()
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for gi, g in enumerate(groups):
        gp = params["groups"][gi]
        gc = caches[gi] if caches is not None else None

        def body(carry, xs, _g=g):
            xx, aux = carry
            if caches is not None:
                params_t, caches_t = xs
            else:
                params_t, caches_t = xs, tuple(None for _ in _g.unit)
            outs = []
            for u, spec in enumerate(_g.unit):
                xx, c_new, aux_u = apply_block(
                    cfg, spec, params_t[u], xx, positions=positions, sh=sh,
                    cache=caches_t[u], mode=mode, cur_pos=cur_pos,
                    decode_active=decode_active, page_table=page_table,
                    page_tokens=page_tokens)
                outs.append(c_new)
                aux = aux + aux_u
            return (xx, aux), (tuple(outs) if caches is not None else None)

        if mode == "train" and cfg.remat != "none":
            if cfg.remat == "dots":
                body = jax.checkpoint(body, policy=jax.checkpoint_policies.checkpoint_dots)
            else:
                body = jax.checkpoint(body)

        xs = (gp, gc) if caches is not None else gp
        (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), xs)
        if caches is not None:
            new_caches.append(ys)
    return x, (tuple(new_caches) if caches is not None else None), aux_total


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------


def loss_and_metrics(cfg: ModelConfig, params, batch: dict, sh=None,
                     loss_chunk: int = 1024) -> Tuple[jax.Array, dict]:
    """Causal-LM loss. batch: tokens (B,S[,K]) int32, labels (B,S[,K]) int32
    with -100 = masked. Frontend/meta prefix positions never contribute."""
    x, prefix = _embed_inputs(cfg, params, batch, sh)
    B, S_tot = x.shape[0], x.shape[1]
    positions = jnp.arange(S_tot)
    x, _, aux = apply_groups(cfg, params, x, positions=positions, sh=sh, mode="train")
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if prefix:
        x = x[:, prefix:]
    labels = batch["labels"]

    S = x.shape[1]
    chunk = min(loss_chunk, S)
    while S % chunk:
        chunk //= 2
    n = S // chunk

    def ce_chunk(carry, idx):
        tot, cnt, zsum = carry
        xs = jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        logits = lm_logits(cfg, params["embed"], xs).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ls, 0)[..., None].astype(jnp.int32), axis=-1)[..., 0]
        mask = (ls >= 0).astype(jnp.float32)
        nll = (lse - gold) * mask
        tot = tot + nll.sum()
        cnt = cnt + mask.sum()
        zsum = zsum + (jnp.square(lse) * mask).sum()
        return (tot, cnt, zsum), None

    (tot, cnt, zsum), _ = jax.lax.scan(
        jax.checkpoint(ce_chunk), (jnp.zeros((), jnp.float32),) * 3, jnp.arange(n))
    cnt = jnp.maximum(cnt, 1.0)
    ce = tot / cnt
    z_loss = 1e-4 * zsum / cnt
    loss = ce + z_loss + aux
    return loss, {"ce": ce, "z_loss": z_loss, "aux": aux, "tokens": cnt}


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params, batch: dict, sh=None,
            max_cache_len: Optional[int] = None):
    """The maximal *first chunk* of the one unpadded prompt path
    (DESIGN.md §5): tokens are never padded — token ``i`` (after the
    meta/frontend prefix) sits at absolute position ``prefix + i``, so
    causal masking is exact and the produced caches are position-aligned
    with every later ``extend`` chunk. Returns (last_logits (B, V[, K]),
    caches); the caches cover the chunk (+ meta/frontend prefix),
    ring-truncated to each layer's window."""
    x, prefix = _embed_inputs(cfg, params, batch, sh)
    B, S_tot = x.shape[0], x.shape[1]
    positions = jnp.arange(S_tot)
    max_len = max_cache_len or S_tot

    # build zero caches, run in prefill mode (blocks fill them)
    caches = init_caches(cfg, B, max_len, jnp.dtype(cfg.dtype))
    x, new_caches, _ = apply_groups(cfg, params, x, positions=positions, sh=sh,
                                    caches=caches, mode="prefill")
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    last = x[:, -1]
    logits = lm_logits(cfg, params["embed"], last)
    return logits, new_caches


def decode(cfg: ModelConfig, params, caches, last_tokens, cur_pos, sh=None,
           active=None):
    """One decode step. last_tokens: (B, 1[, K]); cur_pos: scalar absolute
    position (incl. meta/frontend prefix); active: optional (B,) bool — rows
    where False leave their caches untouched (continuous batching with
    chunked prefill in flight). Returns (logits (B, V[, K]), caches)."""
    x = embed(cfg, params["embed"], last_tokens)
    if sh is not None:
        x = sh.c(x, ("act_batch", None, "act_embed"))
    cp = jnp.asarray(cur_pos, jnp.int32)
    positions = cp if cp.ndim == 0 else cp[:, None]  # (B,) -> (B, 1) for rope
    x, new_caches, _ = apply_groups(cfg, params, x, positions=positions, sh=sh,
                                    caches=caches, mode="decode", cur_pos=cp,
                                    decode_active=active)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params["embed"], x[:, 0])
    return logits, new_caches


def supports_extend(cfg: ModelConfig) -> bool:
    """Chunked prefill (``extend``) is implemented for every mixer family
    — attention resumes against its position-masked ring cache, MLA
    against the compressed latent cache, and SSM (incl. the hybrid union)
    continues its recurrence from the carried state (DESIGN.md §3, §8).
    Kept as a capability probe for API stability."""
    return all(spec.kind in ("attn", "mla", "ssm", "hybrid")
               for spec in cfg.layer_specs())


def snapshot_kind(cfg: ModelConfig) -> str:
    """How a published prefix compute snapshot of this stack may be
    reused (DESIGN.md §8):

    - ``"positional"`` — the cache is a position-masked ring (attention
      KV, MLA compressed latents): one snapshot serves *any* shorter
      page-aligned match boundary, because entries beyond the boundary
      stay masked (``cache_pos <= cur``) until overwritten.
    - ``"point"`` — the cache integrates the whole prefix (SSM conv
      left-context + SSD state, and therefore the hybrid attention+SSM
      union): a snapshot is valid only at the *exact* token boundary it
      was captured at.
    """
    if any(spec.kind in ("ssm", "hybrid") for spec in cfg.layer_specs()):
        return "point"
    return "positional"


def extend(cfg: ModelConfig, params, caches, tokens, offset, sh=None):
    """Chunked-prefill continuation: process ``tokens`` (B, S[, K]) at
    absolute positions ``offset + [0, S)`` against existing caches (which
    already hold every earlier chunk — ring entries for attention/MLA,
    recurrent state for SSM/hybrid). ``offset`` may be traced, so one
    compiled executable serves every chunk of a given length.
    Returns (last-position logits (B, V[, K]), updated caches)."""
    x = embed(cfg, params["embed"], tokens)
    if sh is not None:
        x = sh.c(x, ("act_batch", "act_seq_res", "act_embed"))
    S = x.shape[1]
    positions = jnp.asarray(offset, jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    x, new_caches, _ = apply_groups(cfg, params, x, positions=positions, sh=sh,
                                    caches=caches, mode="extend")
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params["embed"], x[:, -1])
    return logits, new_caches


# ---------------------------------------------------------------------------
# Paged serving steps (DESIGN.md §10): compute in place on the page pool
# ---------------------------------------------------------------------------


def paged_prefill(cfg: ModelConfig, params, batch: dict, caches, page_table,
                  sh=None, page_tokens=None):
    """First chunk on the paged plane: embeds the meta/frontend prefix +
    prompt at absolute positions 0..S-1 and writes KV straight into the
    pool pages named by ``page_table`` (B, W). Unlike ring ``prefill``
    there is no per-slot cache to build — the pool is the cache — so this
    is just ``extend`` from offset 0 with the prefix embedded.
    Returns (last_logits, caches)."""
    x, _ = _embed_inputs(cfg, params, batch, sh)
    S_tot = x.shape[1]
    positions = jnp.arange(S_tot, dtype=jnp.int32)
    x, new_caches, _ = apply_groups(cfg, params, x, positions=positions,
                                    sh=sh, caches=caches, mode="extend",
                                    page_table=page_table,
                                    page_tokens=page_tokens)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params["embed"], x[:, -1])
    return logits, new_caches


def paged_extend(cfg: ModelConfig, params, caches, tokens, offset, page_table,
                 sh=None, page_tokens=None):
    """Later chunks on the paged plane: ``tokens`` (B, S[, K]) at absolute
    positions ``offset + [0, S)``; earlier context is whatever the pages
    in ``page_table`` hold — including pages spliced in from a radix or
    migrated prefix hit at zero copy cost. Point stacks read their state
    page for the slot preceding ``offset`` (the engine chunks them so a
    chunk never crosses a page boundary)."""
    x = embed(cfg, params["embed"], tokens)
    if sh is not None:
        x = sh.c(x, ("act_batch", "act_seq_res", "act_embed"))
    S = x.shape[1]
    positions = jnp.asarray(offset, jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    x, new_caches, _ = apply_groups(cfg, params, x, positions=positions,
                                    sh=sh, caches=caches, mode="extend",
                                    page_table=page_table,
                                    page_tokens=page_tokens)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params["embed"], x[:, -1])
    return logits, new_caches


def paged_decode(cfg: ModelConfig, params, caches, last_tokens, cur_pos,
                 page_table, sh=None, active=None, page_tokens=None):
    """One batched decode step on the paged plane. cur_pos: (B,) absolute
    positions; rows where ``active`` is False neither write their pages
    nor advance (their page-table row may be all null pages)."""
    x = embed(cfg, params["embed"], last_tokens)
    if sh is not None:
        x = sh.c(x, ("act_batch", None, "act_embed"))
    cp = jnp.asarray(cur_pos, jnp.int32)
    positions = cp if cp.ndim == 0 else cp[:, None]  # (B,) -> (B, 1) for rope
    x, new_caches, _ = apply_groups(cfg, params, x, positions=positions,
                                    sh=sh, caches=caches, mode="decode",
                                    cur_pos=cp, decode_active=active,
                                    page_table=page_table,
                                    page_tokens=page_tokens)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params["embed"], x[:, 0])
    return logits, new_caches
