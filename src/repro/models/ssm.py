"""Mamba2 SSD (state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: within-chunk "attention"
with cumulative decay masks + an inter-chunk state recurrence carried by
lax.scan (so the materialized decay mask is (B, H, chunk, chunk), never
(B, H, S, S)). Decode is the O(1) per-token recurrence over the
(H, headdim, state) SSM state — the arch that makes `long_500k` trivial.

The Pallas twin of the chunk computation lives in
`repro.kernels.ssd_scan`; this pure-XLA path is the dry-run/oracle path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamDef


def ssm_defs(cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    ng, ns, nh, cw = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_conv
    conv_dim = di + 2 * ng * ns
    return {
        "in_proj": ParamDef((d, 2 * di + 2 * ng * ns + nh), ("embed", "ssm_inner")),
        "conv_w": ParamDef((cw, conv_dim), ("conv", "ssm_inner")),
        "conv_b": ParamDef((conv_dim,), ("ssm_inner",), init="zeros"),
        "a_log": ParamDef((nh,), ("ssm_heads",), init="a_log", dtype="float32"),
        "d_skip": ParamDef((nh,), ("ssm_heads",), init="ones", dtype="float32"),
        "dt_bias": ParamDef((nh,), ("ssm_heads",), init="dt_bias", dtype="float32"),
        "norm_g": ParamDef((di,), ("ssm_inner",), init="zeros"),
        "out_proj": ParamDef((di, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, ng, ns, nh = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    b = zxbcdt[..., 2 * di:2 * di + ng * ns]
    c = zxbcdt[..., 2 * di + ng * ns:2 * di + 2 * ng * ns]
    dt = zxbcdt[..., 2 * di + 2 * ng * ns:]
    return z, x, b, c, dt


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv via shifted adds. xbc: (B, S, C); w: (W, C).
    state: (B, W-1, C) left context for decode/streaming; returns (y, new_state)."""
    W = w.shape[0]
    Bsz, S, C = xbc.shape
    if state is None:
        state = jnp.zeros((Bsz, W - 1, C), xbc.dtype)
    ext = jnp.concatenate([state, xbc], axis=1)  # (B, S+W-1, C)
    y = jnp.zeros((Bsz, S, C), jnp.float32)
    for i in range(W):
        y = y + ext[:, i:i + S, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    new_state = ext[:, S:, :] if S >= W - 1 else ext[:, -(W - 1):, :]
    return jax.nn.silu(y).astype(xbc.dtype), new_state


def _segsum(a):
    """log-space segment sums: a (..., L) -> (..., L, L) lower-triangular.
    S(i,j) = sum_{t=j+1..i} a_t = cs_i - cs_j for i >= j, else -inf."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    s = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, s, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, chunk: int, init_state=None):
    """SSD over a full sequence, optionally continuing from a carried state.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); a: (H,) negative;
    b, c: (B, S, G, N) with H % G == 0; ``init_state`` (B, H, P, N) is the
    recurrent state after every earlier token (zeros when starting from
    scratch) — this is what makes chunked prefill / prefix-snapshot
    resumption possible for SSM stacks (DESIGN.md §8: the state is a
    *point* snapshot, only valid at the exact boundary it was taken at).
    Returns (y (B,S,H,P), final state (B, H, P, N)).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    L = min(chunk, S)
    while S % L:
        L //= 2
    nc = S // L

    xr = x.reshape(B, nc, L, H, P)
    dtr = dt.reshape(B, nc, L, H)
    br = b.reshape(B, nc, L, G, N)
    cr = c.reshape(B, nc, L, G, N)
    # broadcast groups to heads
    bh = jnp.repeat(br, rep, axis=3)  # (B, nc, L, H, N)
    ch = jnp.repeat(cr, rep, axis=3)

    da = dtr * a[None, None, None, :]           # (B, nc, L, H) log-decay
    da_cs = jnp.cumsum(da, axis=2)              # cumulative within chunk
    seg = _segsum(da.transpose(0, 1, 3, 2))     # (B, nc, H, L, L)
    decay_mask = jnp.exp(seg)

    x_dt = xr * dtr[..., None]

    def chunk_step(state, xs):
        # state: (B, H, P, N)
        xc, bc, cc, dmask, dacs = xs  # per-chunk slices; xc is x*dt
        # intra-chunk (the "attention" form)
        cb = jnp.einsum("blhn,bshn->bhls", cc, bc, preferred_element_type=jnp.float32)
        y_in = jnp.einsum("bhls,bshp->blhp", cb * dmask, xc,
                          preferred_element_type=jnp.float32)
        # contribution from carried-in state
        state_decay = jnp.exp(dacs)  # (B, L, H)
        y_off = jnp.einsum("blhn,bhpn,blh->blhp", cc, state, state_decay,
                           preferred_element_type=jnp.float32)
        # update state: state' = decay_total * state + sum_s decay_tail_s * B_s x_s
        tail = jnp.exp(dacs[:, -1:, :] - dacs)  # (B, L, H)
        new_state = jnp.einsum("bshn,bshp,bsh->bhpn", bc, xc, tail,
                               preferred_element_type=jnp.float32)
        total = jnp.exp(dacs[:, -1, :])  # (B, H)
        state = state * total[..., None, None] + new_state
        return state, (y_in + y_off)

    xs = (
        x_dt.transpose(1, 0, 2, 3, 4),
        bh.transpose(1, 0, 2, 3, 4),
        ch.transpose(1, 0, 2, 3, 4),
        decay_mask.transpose(1, 0, 2, 3, 4),
        da_cs.transpose(1, 0, 2, 3),
    )
    if init_state is None:
        state0 = jnp.zeros((B, H, P, N), jnp.float32)
    else:
        state0 = init_state.astype(jnp.float32)
    state, ys = jax.lax.scan(jax.checkpoint(chunk_step), state0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y.astype(x.dtype), state


def ssd_decode(x1, dt1, a, b1, c1, state):
    """One-token recurrence. x1: (B, H, P); dt1: (B, H); b1/c1: (B, G, N);
    state: (B, H, P, N) -> (y (B, H, P), new state)."""
    B, H, P = x1.shape
    G, N = b1.shape[1], b1.shape[2]
    rep = H // G
    bh = jnp.repeat(b1, rep, axis=1)  # (B, H, N)
    ch = jnp.repeat(c1, rep, axis=1)
    decay = jnp.exp(dt1 * a[None, :])  # (B, H)
    state = state * decay[..., None, None] + jnp.einsum(
        "bhn,bhp,bh->bhpn", bh, x1.astype(jnp.float32), dt1, preferred_element_type=jnp.float32)
    y = jnp.einsum("bhpn,bhn->bhp", state, ch, preferred_element_type=jnp.float32)
    return y.astype(x1.dtype), state


def ssm_cache_init(cfg: ModelConfig, batch: int, dtype, abstract=False) -> dict:
    di, ng, ns = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    nh, hd, cw = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_conv
    conv_dim = di + 2 * ng * ns
    shapes = {
        "conv": ((batch, cw - 1, conv_dim), dtype),
        "state": ((batch, nh, hd, ns), jnp.float32),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def ssm_paged_init(cfg: ModelConfig, n_pages: int, dtype) -> dict:
    """Pooled *state pages* for the paged compute plane (DESIGN.md §10):
    slot j of a session's page table maps to the page holding the conv
    left-context and SSD recurrent state *after the last written token of
    page j* (a sealed page holds the exact page-boundary state; the open
    page holds the running state). Page 0 is the reserved null page — all
    zeros, never written — so an empty table entry reads as the
    empty-history init state, exactly like a fresh ring cache."""
    di, ng, ns = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    nh, hd, cw = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_conv
    conv_dim = di + 2 * ng * ns
    return {
        "conv_pages": jnp.zeros((n_pages, cw - 1, conv_dim), dtype),
        "state_pages": jnp.zeros((n_pages, nh, hd, ns), jnp.float32),
    }


def _paged_state_slots(page_table, q0, page_tokens: int):
    """Resolve the state-page slots for a chunk/step whose first query sits
    at absolute position ``q0`` ((B,) or scalar). Reads the state after
    token ``q0 - 1``: slot ``j0 - 1`` when q0 opens page ``j0 = q0 // pt``
    (the previous page's sealed boundary state), else slot ``j0`` (the open
    page's running state); an empty history maps to null page 0, whose
    zeros ARE the zero init state. Writes always land in slot ``j0`` —
    ``ok`` masks rows whose table entry is null (inactive decode rows carry
    all-null tables; writing page 0 would corrupt the null page)."""
    B, W = page_table.shape
    q0 = jnp.broadcast_to(jnp.asarray(q0, jnp.int32), (B,))
    j0 = q0 // page_tokens
    rs = jnp.where(q0 % page_tokens == 0, j0 - 1, j0)
    rd = jnp.take_along_axis(page_table, jnp.clip(rs, 0, W - 1)[:, None],
                             axis=1)[:, 0]
    pid_read = jnp.where(rs >= 0, rd, 0)
    pid_write = jnp.take_along_axis(page_table, jnp.clip(j0, 0, W - 1)[:, None],
                                    axis=1)[:, 0]
    ok = (j0 < W) & (pid_write != 0)
    return pid_read, pid_write, ok


def ssm_sublayer(
    cfg: ModelConfig,
    p: dict,
    x,
    *,
    sh=None,
    cache: Optional[dict] = None,
    mode: str = "train",
    decode_active=None,
    positions=None,
    cur_pos=None,
    page_table=None,
    page_tokens: Optional[int] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B, S, d_model) -> (out, updated cache or None).

    Modes: ``train`` (no cache), ``prefill``/``extend`` (one code path on
    the unpadded prompt layout, DESIGN.md §5: the cache carries the conv
    left-context and SSD state after every earlier chunk, and a *fresh*
    zero cache IS the empty-history state — a maximal first chunk and a
    mid-prompt continuation are the same recurrence), ``decode`` (O(1)
    per-token step). There is no pad handling anywhere: a padded prompt
    would integrate the pad tokens into the state, which is exactly the
    masking caveat the single-path refactor deleted.
    ``decode_active`` ((B,) bool, decode only): rows where False keep
    their cache untouched — a batched decode round must not clobber the
    recurrent state of a slot whose prompt is still streaming in.

    Paged mode (cache holds ``state_pages``, DESIGN.md §10): the conv
    left-context and SSD state live in pooled pages indexed through
    ``page_table``; the read slot is resolved from the first query
    position (``positions[0]`` for prefill/extend — the engine chunks
    point stacks so every chunk lies within exactly one page — and
    ``cur_pos`` per row for decode), and the updated state is scattered
    back to the page owning that position. Null page 0's zeros are the
    empty-history init, so a cold start and a chunk resumed at a page
    boundary run the identical recurrence."""
    from repro.models.layers import rmsnorm  # avoid cycle

    B, S, d = x.shape
    di, nh, hd = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    ng, ns = cfg.ssm_ngroups, cfg.ssm_state
    paged = cache is not None and "state_pages" in cache
    if paged:
        q0 = cur_pos if mode == "decode" else positions[0]
        pid_read, pid_write, ok = _paged_state_slots(page_table, q0,
                                                     page_tokens)
    zxbcdt = x @ p["in_proj"]
    z, xi, b, c, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    xbc = jnp.concatenate([xi, b, c], axis=-1)
    if paged:
        conv_state = jnp.take(cache["conv_pages"], pid_read, axis=0)
    else:
        conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xi, b, c = xbc[..., :di], xbc[..., di:di + ng * ns], xbc[..., di + ng * ns:]
    xh = xi.reshape(B, S, nh, hd)
    if sh is not None:
        xh = sh.c(xh, ("act_batch", None, "act_heads", None))
    bg = b.reshape(B, S, ng, ns)
    cg = c.reshape(B, S, ng, ns)

    new_cache = None
    if mode == "decode":
        assert cache is not None
        state = (jnp.take(cache["state_pages"], pid_read, axis=0) if paged
                 else cache["state"])
        y1, new_state = ssd_decode(xh[:, 0], dt[:, 0], a, bg[:, 0], cg[:, 0], state)
        y = y1[:, None]
        if decode_active is not None:
            act = jnp.asarray(decode_active, bool)
            if paged:
                ok = ok & act
            else:
                new_state = jnp.where(act[:, None, None, None], new_state, cache["state"])
                new_conv = jnp.where(act[:, None, None], new_conv, cache["conv"])
    else:
        # prefill starts from the zero-initialized cache state; extend
        # continues the recurrence from the carried state (same code path —
        # a fresh cache IS the zero state, and null page 0 IS the zero state
        # in paged mode)
        if paged:
            init = jnp.take(cache["state_pages"], pid_read, axis=0)
        else:
            init = cache["state"] if cache is not None else None
        y, final_state = ssd_chunked(xh, dt, a, bg, cg, cfg.ssm_chunk,
                                     init_state=init)
        new_state = final_state
    y = y + xh.astype(jnp.float32).astype(y.dtype) * p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm_g"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if cache is not None:
        if paged:
            # out-of-range / null rows drop (page 0 is never written)
            P = cache["state_pages"].shape[0]
            pw = jnp.where(ok, pid_write, P)
            new_cache = {
                "conv_pages": cache["conv_pages"].at[pw].set(
                    new_conv.astype(cache["conv_pages"].dtype), mode="drop"),
                "state_pages": cache["state_pages"].at[pw].set(
                    new_state, mode="drop"),
            }
        else:
            new_cache = {"conv": new_conv, "state": new_state}
    return out, new_cache
