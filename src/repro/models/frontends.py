"""Modality-frontend STUBS (per the assignment: `[audio]`/`[vlm]` entries
specify the transformer BACKBONE only; `input_specs()` provides precomputed
frame/patch embeddings).

`input_specs` builds the exact abstract inputs each (arch x shape) dry-run
cell lowers with; `sample_batch` builds small concrete inputs for smoke
tests and examples.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig


def token_shape(cfg: ModelConfig, batch: int, seq: int):
    if cfg.n_codebooks > 1:
        return (batch, seq, cfg.n_codebooks)
    return (batch, seq)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for train/prefill steps (ShapeDtypeStruct only)."""
    B, S = shape.global_batch, shape.seq_len
    text_len = S - (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    specs = {
        "tokens": jax.ShapeDtypeStruct(token_shape(cfg, B, text_len), jnp.int32),
    }
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct(token_shape(cfg, B, text_len), jnp.int32)
    if cfg.frontend == "vision":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for one serve/decode step (token + position)."""
    B = shape.global_batch
    return {
        "last_tokens": jax.ShapeDtypeStruct(token_shape(cfg, B, 1), jnp.int32),
        "cur_pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def sample_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                 with_labels: bool = True) -> Dict[str, jax.Array]:
    """Small concrete batch for smoke tests (deterministic)."""
    rng = np.random.default_rng(seed)
    text_len = seq - (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    tshape = token_shape(cfg, batch, text_len)
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, tshape), jnp.int32)}
    if with_labels:
        out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, tshape), jnp.int32)
    if cfg.frontend == "vision":
        out["image_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (batch, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    return out
