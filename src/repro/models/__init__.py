"""Model stack: composable transformer/SSM/MoE/MLA/hybrid families."""
from repro.models.transformer import (abstract_params, decode, init_caches,
                                      init_params, loss_and_metrics, model_defs,
                                      prefill)
from repro.models.frontends import decode_input_specs, input_specs, sample_batch

__all__ = [
    "abstract_params", "decode", "init_caches", "init_params",
    "loss_and_metrics", "model_defs", "prefill",
    "decode_input_specs", "input_specs", "sample_batch",
]
