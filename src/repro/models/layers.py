"""Shared layer primitives: RMSNorm, RoPE, activations, (gated) MLPs,
embeddings and LM heads. Pure functions over ParamDef-described params."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamDef

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_def(dim: int, axis_name: str = "embed") -> ParamDef:
    return ParamDef((dim,), (axis_name,), init="zeros")  # (1+g) parameterization


def rmsnorm(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + gamma.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE (NeoX half-rotation style)
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: scalar, (S,), or (B, S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (d/2,)
    pos = jnp.asarray(positions, jnp.float32)
    ang = pos[..., None] * inv  # (..., d/2)
    # normalize to broadcast against (B, S, H, d/2)
    if pos.ndim == 0:
        ang = ang.reshape(1, 1, 1, -1)
    elif pos.ndim == 1:  # (S,)
        ang = ang[None, :, None, :]
    elif pos.ndim == 2:  # (B, S)
        ang = ang[:, :, None, :]
    else:
        raise ValueError(f"positions rank {pos.ndim}")
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / softcap
# ---------------------------------------------------------------------------


def activation(name: str):
    if name in ("silu",):
        return jax.nn.silu
    if name in ("gelu", "gelu_plain"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain)
# ---------------------------------------------------------------------------


def mlp_defs(cfg: ModelConfig, d_ff: int) -> dict:
    d = cfg.d_model
    gated = cfg.act in ("silu", "gelu")
    defs = {
        "w_up": ParamDef((d, d_ff), ("embed", "ff")),
        "w_down": ParamDef((d_ff, d), ("ff", "embed")),
    }
    if gated:
        defs["w_gate"] = ParamDef((d, d_ff), ("embed", "ff"))
    return defs


def mlp(cfg: ModelConfig, p: dict, x, constrain=None):
    act = activation(cfg.act)
    up = x @ p["w_up"]
    if "w_gate" in p:
        h = act(x @ p["w_gate"]) * up
    else:
        h = act(up)
    if constrain is not None:
        h = constrain(h, ("act_batch", "act_seq", "act_ff"))
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------


def embed_defs(cfg: ModelConfig) -> dict:
    v, d, k = cfg.padded_vocab, cfg.d_model, cfg.n_codebooks
    shape = (k, v, d) if k > 1 else (v, d)
    axes = ("codebooks", "vocab", "embed") if k > 1 else ("vocab", "embed")
    emb_std = d ** -0.5 if cfg.tie_embeddings else 1.0
    defs = {"embedding": ParamDef(shape, axes, std=emb_std)}
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef(shape, axes)
    return defs


def embed(cfg: ModelConfig, p: dict, tokens):
    """tokens: (B, S) int32 or (B, S, K) for multi-codebook audio."""
    e = p["embedding"]
    if cfg.n_codebooks > 1:
        # sum codebook embeddings: e (K, V, D), tokens (B, S, K)
        out = jnp.zeros(tokens.shape[:2] + (cfg.d_model,), e.dtype)
        for k in range(cfg.n_codebooks):
            out = out + jnp.take(e[k], tokens[..., k], axis=0)
    else:
        out = jnp.take(e, tokens, axis=0)
    if cfg.scale_embeddings:
        out = out * jnp.asarray(math.sqrt(cfg.d_model), out.dtype)
    return out


def lm_logits(cfg: ModelConfig, p: dict, x):
    """x: (..., D) -> logits (..., V) or (..., K, V) for multi-codebook."""
    table = p["embedding"] if cfg.tie_embeddings else p["lm_head"]
    if cfg.n_codebooks > 1:
        logits = jnp.einsum("...d,kvd->...kv", x, table)
    else:
        logits = x @ table.T
    return softcap(logits, cfg.final_softcap)
