"""Hymba-style hybrid block: parallel attention + SSM heads [arXiv:2411.13676].

Both branches read the same normed input; their outputs are RMS-normalized,
averaged, then passed through the block's output. Sliding-window attention
everywhere except cfg.global_layers; 128 learnable meta tokens are prepended
by the transformer assembly (they live in the KV cache / SSM state like any
other token). Cross-layer KV sharing is not modelled (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import attention_sublayer, attn_defs
from repro.models.layers import rmsnorm
from repro.models.param import ParamDef
from repro.models.ssm import ssm_defs, ssm_sublayer


def hybrid_defs(cfg: ModelConfig) -> dict:
    defs = {
        "attn": attn_defs(cfg),
        "ssm": ssm_defs(cfg),
        "attn_out_norm": ParamDef((cfg.d_model,), ("embed",), init="zeros"),
        "ssm_out_norm": ParamDef((cfg.d_model,), ("embed",), init="zeros"),
    }
    return defs


def hybrid_sublayer(
    cfg: ModelConfig,
    p: dict,
    x,
    *,
    positions,
    window: Optional[int],
    sh=None,
    cache: Optional[dict] = None,
    mode: str = "train",
    cur_pos=None,
    decode_active=None,
    page_table=None,
    page_tokens=None,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Both branches run in every mode (incl. ``extend``: the attention
    half resumes against its ring cache positionally while the SSM half
    continues its recurrence from the carried state — the union cache is
    what makes hybrid prefix snapshots *point* snapshots, DESIGN.md §8).
    Prompts are always unpadded (DESIGN.md §5): both halves see token i
    at absolute position i, so neither needs pad masking — the SSM half
    could not mask pads at all (they would integrate into the state),
    which is why the padded whole-prompt path had to go. ``decode_active``
    masks both halves' cache writes for inactive rows. With ``page_table``
    the union cache is paged (DESIGN.md §10): KV pages for the attention
    half, conv/state pages for the SSM half, one table for both."""
    attn_cache = cache["attn"] if cache is not None else None
    ssm_cache = cache["ssm"] if cache is not None else None
    a_out, a_cache = attention_sublayer(
        cfg, p["attn"], x, positions=positions, window=window, sh=sh,
        cache=attn_cache, mode=mode, cur_pos=cur_pos,
        decode_active=decode_active, page_table=page_table)
    s_out, s_cache = ssm_sublayer(cfg, p["ssm"], x, sh=sh, cache=ssm_cache,
                                  mode=mode, decode_active=decode_active,
                                  positions=positions, cur_pos=cur_pos,
                                  page_table=page_table,
                                  page_tokens=page_tokens)
    out = 0.5 * (rmsnorm(a_out, p["attn_out_norm"], cfg.norm_eps)
                 + rmsnorm(s_out, p["ssm_out_norm"], cfg.norm_eps))
    new_cache = None
    if cache is not None:
        new_cache = {"attn": a_cache, "ssm": s_cache}
    return out, new_cache
