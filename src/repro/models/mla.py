"""Multi-head Latent Attention (DeepSeek-V2).

KV is cached in *compressed* form — the latent c_kv (kv_lora_rank) plus a
shared rope key (qk_rope_dim) per token — which is the KV-read-bandwidth
optimization that makes this the most paper-representative architecture
(DESIGN.md §3): the decode read stream per token shrinks ~an order of
magnitude vs materialized GQA KV.

Two decode paths:
- baseline (``mla_absorb=False``): expand the cached latents to per-head
  k/v every step (faithful naive formulation);
- absorbed (``mla_absorb=True``): fold W_UK into the query and W_UV into
  the output so attention runs directly over the compressed cache — the
  §Perf hillclimb lever for deepseek-v2-lite decode.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention import (
    paged_attention_rows,
    write_tokens_to_pages,
)
from repro.models.attention import NEG_INF, chunked_attention
from repro.models.layers import apply_rope, rmsnorm
from repro.models.param import ParamDef


def mla_defs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    defs = {
        "w_dkv": ParamDef((d, r + dr), ("embed", "lora")),
        "kv_norm": ParamDef((r,), ("lora",), init="zeros"),
        "w_uk": ParamDef((r, h, dn), ("lora", "heads", "head_dim")),
        "w_uv": ParamDef((r, h, dv), ("lora", "heads", "head_dim")),
        "wo": ParamDef((h, dv, d), ("heads", "head_dim", "embed")),
    }
    if cfg.q_lora_rank:
        defs["w_dq"] = ParamDef((d, cfg.q_lora_rank), ("embed", "lora"))
        defs["q_norm"] = ParamDef((cfg.q_lora_rank,), ("lora",), init="zeros")
        defs["w_uq"] = ParamDef((cfg.q_lora_rank, h, dn + dr), ("lora", "heads", "head_dim"))
    else:
        defs["wq"] = ParamDef((d, h, dn + dr), ("embed", "heads", "head_dim"))
    return defs


def _project_q(cfg: ModelConfig, p: dict, x, positions):
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        cq = rmsnorm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    qn, qr = q[..., :dn], q[..., dn:]
    qr = apply_rope(qr, positions, cfg.rope_theta)
    return qn, qr


def _compress_kv(cfg: ModelConfig, p: dict, x, positions):
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    ckv = x @ p["w_dkv"]  # (B, S, r+dr)
    c, kr = ckv[..., :r], ckv[..., r:]
    c = rmsnorm(c, p["kv_norm"], cfg.norm_eps)
    kr = apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c, kr


def mla_cache_init(cfg: ModelConfig, batch: int, cache_len: int, dtype, abstract=False) -> dict:
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    mk = jax.ShapeDtypeStruct if abstract else (lambda s, d: jnp.zeros(s, d))
    cache = {
        "c": mk((batch, cache_len, r), dtype),
        "kr": mk((batch, cache_len, dr), dtype),
    }
    if abstract:
        cache["pos"] = jax.ShapeDtypeStruct((batch, cache_len), jnp.int32)
    else:
        cache["pos"] = jnp.full((batch, cache_len), -1, jnp.int32)
    return cache


def _expand(cfg: ModelConfig, p: dict, c):
    """latents (B, C, r) -> k_nope (B, C, H, dn), v (B, C, H, dv)."""
    kn = jnp.einsum("bcr,rhk->bchk", c, p["w_uk"])
    v = jnp.einsum("bcr,rhk->bchk", c, p["w_uv"])
    return kn, v


def _cache_attention(cfg: ModelConfig, p: dict, qn, qr, cache_c, cache_kr,
                     mask, scale, out_dtype):
    """Queries (B, S, H, ·) against the compressed latent cache (B, C, ·)
    under ``mask`` (B, S, C) — the one cache-attention kernel decode
    (S=1) and extend (a whole chunk) share, in both the absorbed and the
    naive-expansion formulation."""
    if cfg.mla_absorb:
        # fold W_UK into q, W_UV into out: attention over compressed cache
        qc = jnp.einsum("bshk,rhk->bshr", qn, p["w_uk"])
        s = jnp.einsum("bshr,bcr->bshc", qc, cache_c,
                       preferred_element_type=jnp.float32)
        s += jnp.einsum("bshk,bck->bshc", qr, cache_kr,
                        preferred_element_type=jnp.float32)
        s = jnp.where(mask[:, :, None, :], s * scale, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        oc = jnp.einsum("bshc,bcr->bshr", pr.astype(out_dtype), cache_c)
        return jnp.einsum("bshr,rhk->bshk", oc, p["w_uv"])
    kn_e, v_e = _expand(cfg, p, cache_c)  # (B,C,H,*) every step
    s = jnp.einsum("bshk,bchk->bshc", qn, kn_e,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("bshk,bck->bshc", qr, cache_kr,
                    preferred_element_type=jnp.float32)
    s = jnp.where(mask[:, :, None, :], s * scale, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bshc,bchk->bshk", pr.astype(out_dtype), v_e)


def mla_sublayer(
    cfg: ModelConfig,
    p: dict,
    x,
    *,
    positions,
    sh=None,
    cache: Optional[dict] = None,
    mode: str = "train",
    cur_pos=None,
    decode_active=None,
    page_table=None,  # (B, W) int32: paged compute plane (DESIGN.md §10)
) -> Tuple[jax.Array, Optional[dict]]:
    """Modes: ``train``/``prefill`` (full-sequence chunked attention over
    the *unpadded* layout — token i at absolute position i, so causal
    masking is exact and the cached latent positions are truthful;
    DESIGN.md §5), ``extend`` (chunked-prefill continuation: the chunk's
    compressed latents are written into the ring cache at their absolute
    positions, then each query attends the whole cache under position
    masking — the latent cache is *positional*, exactly like attention
    KV, so a prefix snapshot seeds any page-aligned boundary and, with a
    sub-page tail copy, the exact mid-page token boundary; DESIGN.md §8,
    §9), and ``decode`` (one token). ``decode_active`` ((B,) bool, decode
    only): rows where False keep their cached latents untouched."""
    B, S, d = x.shape
    dn, dr, dv, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    scale = (dn + dr) ** -0.5
    qn, qr = _project_q(cfg, p, x, positions)
    c, kr = _compress_kv(cfg, p, x, positions)
    new_cache = None

    if cache is not None and "kv_pages" in cache:
        # paged compute plane, always absorbed: the fused page row stores
        # K' = [c, kr] and V' = [c, 0] (one Hkv=1 head of width r+dr), so
        # q' = [qn·W_UK, qr] gives q'·K' = qc·c + qr·kr — the absorbed
        # score exactly — and p@V' carries the latent context in its
        # first r lanes, expanded through W_UV after the kernel.
        assert page_table is not None
        k_f = jnp.concatenate([c, kr], axis=-1)               # (B, S, r+dr)
        v_f = jnp.concatenate([c, jnp.zeros_like(kr)], axis=-1)
        kv_new = jnp.stack([k_f, v_f], axis=2)                # (B, S, 2, r+dr)
        if mode == "decode":
            cur = jnp.asarray(cur_pos, jnp.int32)
            pos2d = (cur.reshape(-1, 1) if cur.ndim
                     else jnp.full((B, 1), cur, jnp.int32))
            act = decode_active
        else:
            pos2d = jnp.broadcast_to(
                jnp.asarray(positions, jnp.int32).reshape(1, S), (B, S))
            act = None
        kvp = write_tokens_to_pages(cache["kv_pages"], kv_new, pos2d,
                                    page_table, active=act)
        qc = jnp.einsum("bshk,rhk->bshr", qn, p["w_uk"])
        q_f = jnp.concatenate([qc, qr], axis=-1)              # (B, S, H, r+dr)
        H = q_f.shape[2]
        o = paged_attention_rows(
            q_f.reshape(B * S, H, r + dr), kvp,
            jnp.repeat(page_table, S, axis=0), pos2d.reshape(B * S),
            scale=scale).reshape(B, S, H, r + dr)
        out = jnp.einsum("bshr,rhk->bshk", o[..., :r].astype(x.dtype),
                         p["w_uv"])
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return out, {"kv_pages": kvp}
    if mode == "decode":
        assert cache is not None
        C = cache["c"].shape[1]
        cur = jnp.asarray(cur_pos, jnp.int32)
        if cur.ndim == 0:
            # masked write (not DUS): keeps seq-sharded caches local under
            # GSPMD — see models/attention.py append_to_cache
            slot = cur % C
            hit = (jnp.arange(C) == slot)[None, :, None]
            c_new = jnp.where(hit, c.astype(cache["c"].dtype), cache["c"])
            kr_new = jnp.where(hit, kr.astype(cache["kr"].dtype), cache["kr"])
            pos_new = jnp.where(hit[:, :, 0], cur, cache["pos"])
        else:  # (B,) per-sequence positions (continuous batching)
            slot = cur % C
            rows = jnp.arange(B)
            c_new = cache["c"].at[rows, slot].set(c[:, 0].astype(cache["c"].dtype))
            kr_new = cache["kr"].at[rows, slot].set(kr[:, 0].astype(cache["kr"].dtype))
            pos_new = cache["pos"].at[rows, slot].set(cur)
        if decode_active is not None:
            act = jnp.asarray(decode_active, bool)
            c_new = jnp.where(act[:, None, None], c_new, cache["c"])
            kr_new = jnp.where(act[:, None, None], kr_new, cache["kr"])
            pos_new = jnp.where(act[:, None], pos_new, cache["pos"])
        new_cache = {"c": c_new, "kr": kr_new, "pos": pos_new}
        if sh is not None:
            # latents shard over (batch, cache-seq) — must match the input
            # cache sharding or GSPMD reshards the cache every layer
            new_cache = {k: sh.c(v, ("act_batch", "act_kv_seq", None)[: v.ndim])
                         for k, v in new_cache.items()}
        cur_b = cur if cur.ndim else cur[None]
        mask = (new_cache["pos"] >= 0) & (new_cache["pos"] <= cur_b[:, None])
        out = _cache_attention(cfg, p, qn, qr, new_cache["c"],
                               new_cache["kr"], mask[:, None, :], scale,
                               x.dtype)
    elif mode == "extend":
        # chunked-prefill continuation: write the chunk's compressed
        # latents into the ring cache at their absolute positions, then
        # attend against the whole cache (earlier chunks + this chunk)
        # under the same position masking decode uses — stale entries
        # beyond a seeded prefix boundary stay masked until overwritten.
        assert cache is not None
        C = cache["c"].shape[1]
        qpos = jnp.asarray(positions, jnp.int32)  # (S,) absolute positions
        slots = qpos % C
        new_cache = {
            "c": cache["c"].at[:, slots].set(c.astype(cache["c"].dtype)),
            "kr": cache["kr"].at[:, slots].set(kr.astype(cache["kr"].dtype)),
            "pos": cache["pos"].at[:, slots].set(qpos[None, :]),
        }
        if sh is not None:
            new_cache = {k: sh.c(v, ("act_batch", "act_kv_seq", None)[: v.ndim])
                         for k, v in new_cache.items()}
        mask = ((new_cache["pos"][:, None, :] >= 0)
                & (new_cache["pos"][:, None, :] <= qpos[None, :, None]))
        out = _cache_attention(cfg, p, qn, qr, new_cache["c"],
                               new_cache["kr"], mask, scale, x.dtype)
    else:
        kn, v = _expand(cfg, p, c)
        k_full = jnp.concatenate([kn, jnp.broadcast_to(kr[:, :, None, :], kn.shape[:3] + (dr,))], -1)
        q_full = jnp.concatenate([qn, qr], -1)
        out = chunked_attention(q_full, k_full, v, scale=scale,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        if mode == "prefill":
            assert cache is not None
            C = cache["c"].shape[1]
            take = min(S, C)
            pos = jnp.arange(S - take, S, dtype=jnp.int32)
            new_cache = {
                "c": cache["c"].at[:, pos % C].set(
                    jax.lax.slice_in_dim(c, S - take, S, axis=1).astype(cache["c"].dtype)),
                "kr": cache["kr"].at[:, pos % C].set(
                    jax.lax.slice_in_dim(kr, S - take, S, axis=1).astype(cache["kr"].dtype)),
                "pos": cache["pos"].at[:, pos % C].set(pos[None, :]),
            }
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, new_cache
