"""Parameter definition pytrees.

Modules describe their parameters as pytrees of :class:`ParamDef` (shape +
logical axes + initializer). The same definition tree serves three uses:

- ``materialize``   — real arrays for smoke tests / examples / training;
- ``abstract``      — ``jax.ShapeDtypeStruct`` stand-ins for the dry-run
                      (no allocation; the pattern the assignment requires);
- ``partition_specs`` — ``PartitionSpec`` per param from logical-axis rules
                      with divisibility fallback (runtime/sharding.py).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | a_log | dt_bias
    std: Optional[float] = None  # None => fan-in 1/sqrt(shape[-2 or -1])
    dtype: Optional[str] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (self.shape, self.logical_axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def stack_defs(defs, repeats: int):
    """Add a leading scanned-layers axis to every ParamDef in a tree."""
    def add(d: ParamDef) -> ParamDef:
        return dataclasses.replace(
            d, shape=(repeats,) + d.shape, logical_axes=("layers",) + d.logical_axes)
    return jax.tree.map(add, defs, is_leaf=is_def)


def _fan_in_std(d: ParamDef) -> float:
    if d.std is not None:
        return d.std
    if len(d.shape) >= 2:
        fan_in = d.shape[-2]
    else:
        fan_in = d.shape[-1]
    return 1.0 / math.sqrt(max(fan_in, 1))


def materialize(defs, key: jax.Array, default_dtype: str = "bfloat16"):
    """Initialize real parameter arrays from a def tree."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, max(len(leaves), 1))

    def one(d: ParamDef, k):
        dt = jnp.dtype(d.dtype or default_dtype)
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        if d.init == "a_log":  # mamba2: A in [1, 16), stored as log
            u = jax.random.uniform(k, d.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(dt)
        if d.init == "dt_bias":  # softplus^-1 of dt ~ U[1e-3, 1e-1]
            u = jax.random.uniform(k, d.shape, jnp.float32, 1e-3, 1e-1)
            return jnp.log(jnp.expm1(u)).astype(dt)
        std = _fan_in_std(d)
        return (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dt)

    return jax.tree.unflatten(treedef, [one(d, k) for d, k in zip(leaves, keys)])


def abstract(defs, default_dtype: str = "bfloat16", shardings=None):
    """ShapeDtypeStruct tree (optionally with shardings attached)."""
    if shardings is None:
        return jax.tree.map(
            lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or default_dtype)),
            defs, is_leaf=is_def)
    return jax.tree.map(
        lambda d, s: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or default_dtype), sharding=s),
        defs, shardings, is_leaf=is_def)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return int(sum(int(np.prod(d.shape)) for d in leaves))
