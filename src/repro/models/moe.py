"""Mixture-of-Experts MLP with expert parallelism.

Dispatch is the GShard/Switch capacity-slot formulation: tokens are split
into fixed-size *groups*; within a group, top-k routing builds a one-hot
(group, experts, capacity) dispatch tensor contracted with token activations
(einsum dispatch is the portable TPU pattern under pjit — it produces the
expected all-to-all/all-gather collectives for the roofline, and its FLOP
overhead is g*cf/(3*d_ff) per pass, kept small by the group-size knob).
Groups are processed under lax.scan so dispatch temporaries stay bounded.

Experts are sharded over the ``model`` mesh axis (EP); shared experts
(DeepSeek-V2) run densely for every token.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import activation
from repro.models.param import ParamDef


def moe_defs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    gated = cfg.act in ("silu", "gelu")
    defs = {
        "router": ParamDef((d, e), ("embed", "experts")),
        "w_up": ParamDef((e, d, f), ("experts", "embed", "ff")),
        "w_down": ParamDef((e, f, d), ("experts", "ff", "embed")),
    }
    if gated:
        defs["w_gate"] = ParamDef((e, d, f), ("experts", "embed", "ff"))
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        defs["shared_up"] = ParamDef((d, fs), ("embed", "ff"))
        defs["shared_down"] = ParamDef((fs, d), ("ff", "embed"))
        if gated:
            defs["shared_gate"] = ParamDef((d, fs), ("embed", "ff"))
    return defs


def _group_size(cfg: ModelConfig, seq_len: int) -> int:
    # groups are chunks of the SEQUENCE dim (batch stays a sharded batch dim
    # — see moe_sublayer); keep dispatch-FLOP overhead ~ g*cf/(3*f) small
    # but groups big enough for stable capacity utilization
    g = 256
    while g * 2 <= min(seq_len, 4096) and (g * 2 * cfg.capacity_factor) / (3 * cfg.expert_d_ff) < 0.03:
        g *= 2
    while seq_len % g:
        g //= 2
    return max(g, 1)


def route(cfg: ModelConfig, router_w, tokens):
    """tokens: (T, d) -> (weights (T, k), idx (T, k), aux_loss scalar)."""
    logits = (tokens @ router_w).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    e = cfg.n_experts
    me = probs.mean(axis=0)  # mean router prob per expert
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / idx.size
    aux = e * jnp.sum(me * ce) * cfg.router_aux_coef
    return w.astype(tokens.dtype), idx, aux


def _expert_ffn(cfg: ModelConfig, p: dict, xe, sh=None):
    """xe: (B, E, C, d) -> (B, E, C, d). Experts shard over the model axis
    (EP); when n_experts doesn't divide it (mixtral: 8e vs 16-way), the
    constraint on h falls back to sharding d_ff (TP inside each expert)."""
    act = activation(cfg.act)
    up = jnp.einsum("becd,edf->becf", xe, p["w_up"])
    if "w_gate" in p:
        h = act(jnp.einsum("becd,edf->becf", xe, p["w_gate"])) * up
    else:
        h = act(up)
    if sh is not None:
        h = sh.c(h, ("act_batch", "act_experts", None, "act_ff"))
    return jnp.einsum("becf,efd->becd", h, p["w_down"])


def moe_sublayer(cfg: ModelConfig, p: dict, x, sh=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux loss scalar).

    Groups are chunks of the SEQUENCE dim; the batch dim rides through the
    group scan as a batched (data-sharded) dim. (Grouping flattened B*S
    tokens would put the sharded batch axis on the scan's xs leading dim,
    which GSPMD must replicate — 16x redundant expert compute. Found via
    the roofline useful-FLOPs ratio; see EXPERIMENTS.md §Perf.)
    """
    B, S, d = x.shape
    k, E = cfg.moe_top_k, cfg.n_experts
    g = _group_size(cfg, S)
    n_groups = S // g
    cap = max(4, int(round(g / E * k * cfg.capacity_factor)))
    cap = min(cap, g)

    w_all, idx_all, aux = route(cfg, p["router"], x.reshape(B * S, d))
    # (n_groups, B, g, ...) — scan axis leading, batch stays sharded inside
    tok_g = x.reshape(B, n_groups, g, d).transpose(1, 0, 2, 3)
    w_g = w_all.reshape(B, n_groups, g, k).transpose(1, 0, 2, 3)
    idx_g = idx_all.reshape(B, n_groups, g, k).transpose(1, 0, 2, 3)

    def per_group(carry, xs):
        tg, wg, ig = xs  # (B,g,d), (B,g,k), (B,g,k)
        oh = jax.nn.one_hot(ig, E, dtype=jnp.float32)      # (B,g,k,E)
        flat = oh.reshape(B, g * k, E)
        # priority: earlier tokens / earlier choices claim capacity first
        pos = jnp.cumsum(flat, axis=1) - flat              # slot within expert
        slot_idx = (pos * flat).sum(-1)                    # (B, g*k)
        keep = (slot_idx < cap)[..., None]
        slot = jax.nn.one_hot(slot_idx, cap, dtype=jnp.float32)  # (B,g*k,cap)
        disp = (flat * keep)[..., :, None] * slot[..., None, :]  # (B,g*k,E,cap)
        disp = disp.reshape(B, g, k, E, cap)
        combine = disp * wg[..., None, None].astype(jnp.float32)
        disp_tok = disp.sum(2)                             # (B,g,E,cap)
        if sh is not None:
            disp_tok = sh.c(disp_tok, ("act_batch", None, "act_experts", None))
        xe = jnp.einsum("bgec,bgd->becd", disp_tok.astype(tg.dtype), tg)
        if sh is not None:
            xe = sh.c(xe, ("act_batch", "act_experts", None, None))
        ye = _expert_ffn(cfg, p, xe, sh=sh)
        out = jnp.einsum("bgkec,becd->bgd", combine.astype(ye.dtype), ye)
        return carry, out

    if n_groups == 1:
        _, out_g = per_group(0.0, (tok_g[0], w_g[0], idx_g[0]))
        outs = out_g[None]
    else:
        _, outs = jax.lax.scan(jax.checkpoint(per_group), 0.0,
                               (tok_g, w_g, idx_g))
    out = outs.transpose(1, 0, 2, 3).reshape(B, S, d)

    if cfg.n_shared_experts:
        act = activation(cfg.act)
        up = x @ p["shared_up"]
        h = act(x @ p["shared_gate"]) * up if "shared_gate" in p else act(up)
        if sh is not None:
            h = sh.c(h, ("act_batch", "act_seq", "act_ff"))
        out = out + h @ p["shared_down"]
    return out, aux
