"""Self-attention: chunked (flash-style) prefill/train attention and
single-token decode attention over (ring-buffer) KV caches.

The pure-XLA chunked path is what the dry-run lowers (Mosaic kernels cannot
lower on the CPU backend — DESIGN.md §4); `repro.kernels.flash_attention`
is the Pallas TPU twin validated against `chunked_attention` in tests.

Design notes
- q-chunks are a static python loop so each chunk's KV range is *exact*
  (causal work ~ S^2/2, not S^2; windowed work ~ S*W) — this keeps the
  HLO-derived roofline honest. KV within a range is processed by lax.scan
  with a running (m, l, acc) online softmax in fp32.
- GQA/MQA via a (B, S, Hkv, G, Dh) query layout; MHA is G=1... Hkv=H.
- KV caches are ring buffers of size min(total, window) with an explicit
  stored-position array; masking is position-based so ring order is
  irrelevant (RoPE is applied before caching).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention import (
    interleave_kv,
    paged_attention_rows,
    write_tokens_to_pages,
)
from repro.models.layers import apply_rope, rmsnorm, softcap
from repro.models.param import ParamDef

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------


def attn_defs(cfg: ModelConfig) -> dict:
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    defs = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, hk, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, hk, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), ("head_dim",), init="zeros")
        defs["k_norm"] = ParamDef((hd,), ("head_dim",), init="zeros")
    return defs


def _q_scale(cfg: ModelConfig) -> float:
    return cfg.q_scale if cfg.q_scale is not None else cfg.resolved_head_dim ** -0.5


# ---------------------------------------------------------------------------
# Chunked flash-style attention (training / prefill)
# ---------------------------------------------------------------------------


def _block_attn(q, k, v, qpos, kpos, *, scale, cap, window):
    """One (q-chunk x kv-chunk) online-softmax block.

    q: (B, Hkv, G, Q, D); k/v: (B, K, Hkv, D); qpos: (Q,), kpos: (K,)
    returns scores-post-mask partial (p, m, l-terms) pieces. Masking is
    purely positional (causality + window): prompts are never padded
    (DESIGN.md §5), so there is no pad-validity special case.
    """
    s = jnp.einsum("bhgqd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    return s


def _flash_scan(q_i, k_b, v_b, qpos, kpos_b, sc):
    """Online-softmax over kv chunks. q_i: (B,Hkv,G,Q,Dk); k_b/v_b:
    (nkv,B,K,Hkv,D*); returns (out_unnormalized-normalized fp32, m, l)."""
    scale, cap, window = sc
    B, Hkv, G, Q, Dk = q_i.shape
    Dv = v_b.shape[-1]
    m0 = jnp.full((B, Hkv, G, Q), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Q), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Q, Dv), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, kp = xs
        s = _block_attn(q_i, kc, vc, qpos, kp, scale=scale, cap=cap,
                        window=window)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (k_b, v_b, kpos_b))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    return acc / l_safe[..., None], m, l_safe


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _flash_chunk(q_i, k_b, v_b, qpos, kpos_b, sc):
    out, _, _ = _flash_scan(q_i, k_b, v_b, qpos, kpos_b, sc)
    return out


def _flash_chunk_fwd(q_i, k_b, v_b, qpos, kpos_b, sc):
    out, m, l = _flash_scan(q_i, k_b, v_b, qpos, kpos_b, sc)
    return out, (q_i, k_b, v_b, qpos, kpos_b, out, m, l)


def _flash_chunk_bwd(sc, res, g):
    """Flash-attention backward: recompute each block's probabilities from
    the saved (m, l) stats; O(block) live memory instead of O(S^2)."""
    scale, cap, window = sc
    q_i, k_b, v_b, qpos, kpos_b, out, m, l = res
    g = g.astype(jnp.float32)
    delta = jnp.sum(g * out, axis=-1)  # (B,Hkv,G,Q)
    dq0 = jnp.zeros(q_i.shape, jnp.float32)

    def body(dq, xs):
        kc, vc, kp = xs
        s_scaled = jnp.einsum("bhgqd,bkhd->bhgqk", q_i, kc,
                              preferred_element_type=jnp.float32) * scale
        if cap is not None:
            t = jnp.tanh(s_scaled / cap)
            s_post = t * cap
        else:
            s_post = s_scaled
        mask = kp[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kp[None, :] > (qpos[:, None] - window)
        s_post = jnp.where(mask[None, None, None], s_post, NEG_INF)
        p = jnp.exp(s_post - m[..., None]) / l[..., None]  # (B,Hkv,G,Q,K)
        dv_c = jnp.einsum("bhgqk,bhgqd->bkhd", p, g,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", g, vc,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])  # wrt post-softcap logits
        if cap is not None:
            ds = ds * (1.0 - jnp.square(t))  # through tanh softcap
        ds = ds * scale
        dq = dq + jnp.einsum("bhgqk,bkhd->bhgqd", ds, kc,
                             preferred_element_type=jnp.float32)
        dk_c = jnp.einsum("bhgqk,bhgqd->bkhd", ds, q_i,
                          preferred_element_type=jnp.float32)
        return dq, (dk_c, dv_c)

    dq, (dk_b, dv_b) = jax.lax.scan(body, dq0, (k_b, v_b, kpos_b))
    return (dq.astype(q_i.dtype), dk_b.astype(k_b.dtype),
            dv_b.astype(v_b.dtype), None, None)


_flash_chunk.defvjp(_flash_chunk_fwd, _flash_chunk_bwd)


def chunked_attention(
    q, k, v,
    *,
    window: Optional[int] = None,
    cap: Optional[float] = None,
    scale: float,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
):
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, H, D).

    Causal self-attention over an unpadded sequence: query i sits at
    position i against keys at positions 0..Skv-1. (The per-query offset
    and pad-validity parameters of the padded whole-prompt era are gone —
    chunk-internal KV zero-padding is masked by causality alone, since a
    padded key's position always exceeds every query's.)
    """
    B, Sq, H, Dk = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]  # MLA: value head dim may differ from key dim
    G = H // Hkv
    q = q.reshape(B, Sq, Hkv, G, Dk).transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,Sq,Dk)

    q_chunk = min(q_chunk, Sq)
    while Sq % q_chunk:
        q_chunk //= 2
    kv_chunk = min(kv_chunk, Skv)
    nq = Sq // q_chunk

    outs = []
    for i in range(nq):
        q_i = jax.lax.slice_in_dim(q, i * q_chunk, (i + 1) * q_chunk, axis=3)
        qpos = i * q_chunk + jnp.arange(q_chunk)
        hi = min(Skv, (i + 1) * q_chunk)  # causal end (static)
        lo = 0
        if window is not None:
            lo = max(0, i * q_chunk - window + 1)
            lo = (lo // kv_chunk) * kv_chunk  # align to chunk grid
        span = hi - lo
        nkv = max(1, -(-span // kv_chunk))
        span_pad = nkv * kv_chunk
        lo = max(0, min(lo, Skv - span_pad))  # keep the padded span in-bounds
        if lo + span_pad > Skv:  # Skv < span_pad: pad KV once below
            span_pad = ((Skv - lo + kv_chunk - 1) // kv_chunk) * kv_chunk
            nkv = span_pad // kv_chunk
        k_sl = jax.lax.slice_in_dim(k, lo, min(lo + span_pad, Skv), axis=1)
        v_sl = jax.lax.slice_in_dim(v, lo, min(lo + span_pad, Skv), axis=1)
        pad = lo + span_pad - Skv
        if pad > 0:
            k_sl = jnp.pad(k_sl, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_sl = jnp.pad(v_sl, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos0 = lo + jnp.arange(span_pad)

        k_b = k_sl.reshape(B, nkv, kv_chunk, Hkv, Dk).transpose(1, 0, 2, 3, 4)
        v_b = v_sl.reshape(B, nkv, kv_chunk, Hkv, Dv).transpose(1, 0, 2, 3, 4)
        kpos_b = kpos0.reshape(nkv, kv_chunk)

        out = _flash_chunk(q_i, k_b, v_b, qpos, kpos_b,
                           (scale, cap, window))
        outs.append(out)

    out = jnp.concatenate(outs, axis=3) if len(outs) > 1 else outs[0]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv)
    return out.astype(v.dtype)


# ---------------------------------------------------------------------------
# Decode attention (one token against a ring-buffer cache)
# ---------------------------------------------------------------------------


def decode_attention(q, k_cache, v_cache, cache_pos, cur_pos, *,
                     window: Optional[int] = None, cap: Optional[float] = None,
                     scale: float):
    """q: (B, 1, H, D); caches: (B, C, Hkv, D); cache_pos: (B, C) stored
    absolute positions (-1 = empty); cur_pos: () or (B,). -> (B, 1, H, D)."""
    B, _, H, D = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    qq = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qq, k_cache, preferred_element_type=jnp.float32)
    s = s * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    cur = jnp.asarray(cur_pos)
    cur = cur[:, None] if cur.ndim == 1 else cur[None, None]
    mask = (cache_pos >= 0) & (cache_pos <= cur)
    if window is not None:
        mask &= cache_pos > (cur - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgk,bkhd->bhgd", (p / l).astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# Extend attention (a chunk of new tokens against a ring-buffer cache) —
# the compute half of chunked prefill: queries at absolute positions `qpos`
# attend to everything already resident in the cache (earlier chunks) plus
# the chunk itself, with the same position-based masking as decode.
# ---------------------------------------------------------------------------


def extend_attention(q, k_cache, v_cache, cache_pos, qpos, *,
                     window: Optional[int] = None, cap: Optional[float] = None,
                     scale: float):
    """q: (B, S, H, D); caches: (B, C, Hkv, D); cache_pos: (B, C) stored
    absolute positions (-1 = empty); qpos: (S,) absolute query positions.
    The chunk's own keys must already be written into the cache.
    -> (B, S, H, Dv)."""
    B, S, H, D = q.shape
    Hkv = k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = H // Hkv
    qq = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,S,D)
    s = jnp.einsum("bhgqd,bkhd->bhgqk", qq, k_cache,
                   preferred_element_type=jnp.float32)
    s = s * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    qpos = jnp.asarray(qpos, jnp.int32)
    mask = (cache_pos[:, None, :] >= 0) & (cache_pos[:, None, :] <= qpos[None, :, None])
    if window is not None:
        mask &= cache_pos[:, None, :] > (qpos[None, :, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)  # (B,Hkv,G,S,C)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    l = jnp.where(l == 0.0, 1.0, l)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", (p / l).astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dv)
    return out.astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# Cache plumbing
# ---------------------------------------------------------------------------


def cache_len_for(window: Optional[int], max_len: int) -> int:
    return min(max_len, window) if window is not None else max_len


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> dict:
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, cache_len, hk, hd), dtype),
        "v": jnp.zeros((batch, cache_len, hk, hd), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> dict:
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, cache_len, hk, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, cache_len, hk, hd), dtype),
        "pos": jax.ShapeDtypeStruct((batch, cache_len), jnp.int32),
    }


def fill_cache_from_prefill(cache: dict, k, v) -> dict:
    """Write a maximal first chunk's keys/values (B, S, Hkv, D) — always
    unpadded, token i at position i — into a (possibly smaller, windowed)
    cache. Keeps the last `cache_len` tokens."""
    S = k.shape[1]
    C = cache["k"].shape[1]
    take = min(S, C)
    ksl = jax.lax.slice_in_dim(k, S - take, S, axis=1)
    vsl = jax.lax.slice_in_dim(v, S - take, S, axis=1)
    pos = jnp.arange(S - take, S, dtype=jnp.int32)
    # ring placement: slot = pos % C
    slots = pos % C
    k_new = cache["k"].at[:, slots].set(ksl.astype(cache["k"].dtype))
    v_new = cache["v"].at[:, slots].set(vsl.astype(cache["v"].dtype))
    pos_new = cache["pos"].at[:, slots].set(pos[None, :])
    return {"k": k_new, "v": v_new, "pos": pos_new}


def write_chunk_to_cache(cache: dict, k, v, positions) -> dict:
    """Write a chunk's keys/values (B, S, Hkv, D) at absolute positions
    `positions` (S,) into the ring cache (slot = pos % C). Chunks must not
    exceed the cache length, or intra-chunk ring slots would collide."""
    C = cache["k"].shape[1]
    positions = jnp.asarray(positions, jnp.int32)
    slots = positions % C
    k_new = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
    v_new = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
    pos_new = cache["pos"].at[:, slots].set(positions[None, :])
    return {"k": k_new, "v": v_new, "pos": pos_new}


def append_to_cache(cache: dict, k1, v1, pos, active=None) -> dict:
    """Append one token (B, 1, Hkv, D) at absolute position(s) `pos` —
    a scalar (dry-run fast path: one dynamic_update_slice) or (B,) per-
    sequence positions (continuous batching: scatter per row).

    ``active`` ((B,) bool, optional): rows where False keep their cache
    untouched — required when decode rounds interleave with chunked
    prefill, so a mid-prefill slot's ring entries aren't clobbered by the
    batched decode write."""
    C = cache["k"].shape[1]
    B = cache["pos"].shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        # masked elementwise write, NOT dynamic_update_slice: a DUS at a
        # traced index on a sharded cache-sequence dim makes GSPMD
        # all-gather + re-shard the whole cache every layer; the masked
        # write stays local on every shard (found via the §Perf byte
        # breakdown of the decode cells).
        slot = pos % C
        hit = (jnp.arange(C) == slot)[None, :, None, None]
        k_new = jnp.where(hit, k1.astype(cache["k"].dtype), cache["k"])
        v_new = jnp.where(hit, v1.astype(cache["v"].dtype), cache["v"])
        pos_new = jnp.where(hit[:, :, 0, 0], pos, cache["pos"])
    else:
        slot = pos % C  # (B,)
        rows = jnp.arange(B)
        k_new = cache["k"].at[rows, slot].set(k1[:, 0].astype(cache["k"].dtype))
        v_new = cache["v"].at[rows, slot].set(v1[:, 0].astype(cache["v"].dtype))
        pos_new = cache["pos"].at[rows, slot].set(pos)
    if active is not None:
        act = jnp.asarray(active, bool)
        k_new = jnp.where(act[:, None, None, None], k_new, cache["k"])
        v_new = jnp.where(act[:, None, None, None], v_new, cache["v"])
        pos_new = jnp.where(act[:, None], pos_new, cache["pos"])
    return {"k": k_new, "v": v_new, "pos": pos_new}


# ---------------------------------------------------------------------------
# Full attention sublayer (projections + rope + attention + out-proj)
# ---------------------------------------------------------------------------


def attention_sublayer(
    cfg: ModelConfig,
    p: dict,
    x,
    *,
    positions,
    window: Optional[int],
    sh=None,
    cache: Optional[dict] = None,
    mode: str = "train",  # train | prefill | extend | decode
    cur_pos=None,
    decode_active=None,   # (B,) bool: rows whose cache the decode may touch
    page_table=None,      # (B, W) int32: paged compute plane (DESIGN.md §10)
) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B, S, d) -> (attn_out (B, S, d), updated cache or None)."""
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if sh is not None:
        q = sh.c(q, ("act_batch", None, "act_heads", None))

    scale = _q_scale(cfg)
    new_cache = None
    if cache is not None and "kv_pages" in cache:
        # paged compute plane: write this step's KV straight into the
        # shared page pool and attend page-by-page — extend and decode
        # are the same rows-form call, only the positions differ.
        assert page_table is not None
        if mode == "decode":
            cur = jnp.asarray(cur_pos, jnp.int32)
            pos2d = (cur.reshape(-1, 1) if cur.ndim
                     else jnp.full((B, 1), cur, jnp.int32))
            act = decode_active
        else:
            pos2d = jnp.broadcast_to(
                jnp.asarray(positions, jnp.int32).reshape(1, S), (B, S))
            act = None
        kvp = write_tokens_to_pages(cache["kv_pages"], interleave_kv(k, v),
                                    pos2d, page_table, active=act)
        Hq, hd = q.shape[2], q.shape[3]
        out = paged_attention_rows(
            q.reshape(B * S, Hq, hd), kvp,
            jnp.repeat(page_table, S, axis=0), pos2d.reshape(B * S),
            scale=scale, cap=cfg.attn_softcap, window=window,
        ).reshape(B, S, Hq, hd)
        out = jnp.einsum("bshk,hkd->bsd", out.astype(q.dtype), p["wo"])
        return out, {"kv_pages": kvp}
    if mode == "decode":
        assert cache is not None
        new_cache = append_to_cache(cache, k, v, cur_pos, active=decode_active)
        if sh is not None:
            new_cache = sh.kv(cfg, new_cache)
        out = decode_attention(q, new_cache["k"], new_cache["v"], new_cache["pos"],
                               cur_pos, window=window, cap=cfg.attn_softcap, scale=scale)
    elif mode == "extend":
        # chunked prefill: `positions` are the chunk's absolute positions;
        # write the chunk's KV into the ring cache, then attend against the
        # whole cache (earlier chunks + this one) with position masking.
        assert cache is not None
        new_cache = write_chunk_to_cache(cache, k, v, positions)
        if sh is not None:
            new_cache = sh.kv(cfg, new_cache)
        out = extend_attention(q, new_cache["k"], new_cache["v"],
                               new_cache["pos"], positions, window=window,
                               cap=cfg.attn_softcap, scale=scale)
    else:
        # train and first-chunk prefill share the full-sequence flash
        # path; a prefill additionally fills the fresh ring cache. Inputs
        # are always unpadded (DESIGN.md §5), so causal masking is exact.
        out = chunked_attention(q, k, v, window=window,
                                cap=cfg.attn_softcap, scale=scale,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        if mode == "prefill":
            assert cache is not None
            new_cache = fill_cache_from_prefill(cache, k, v)
            if sh is not None:
                new_cache = sh.kv(cfg, new_cache)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, new_cache
