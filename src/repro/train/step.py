"""Training step: value_and_grad over the chunked-CE loss + sharded AdamW.

``make_train_step`` builds the pjit-able function used by both the real
trainer (launch/train.py) and the dry-run (launch/dryrun.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import loss_and_metrics
from repro.optim.adamw import OptConfig, adamw_update
from repro.runtime.sharding import ShardCtx


def make_train_step(cfg: ModelConfig, oc: OptConfig, sh: Optional[ShardCtx] = None):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = loss_and_metrics(cfg, p, batch, sh)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_opt, stats = adamw_update(oc, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **stats)
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, sh: Optional[ShardCtx] = None):
    def eval_step(params, batch):
        loss, metrics = loss_and_metrics(cfg, params, batch, sh)
        return dict(metrics, loss=loss)
    return eval_step
