"""Serving driver: a reduced model computes real tokens while the MRM
control plane meters the deployment-size memory system. With --replicas N
a :class:`ClusterFrontend` fans requests across N engine replicas
(fleet prefix-directory routing, shared simulated clock, aggregated fleet
report). --shared-prefix-tokens K makes the generated traffic share a
K-token prompt head, exercising radix prefix reuse end to end;
--migrate-prefixes additionally lets the directory *move* a hot prefix
(pages + compute snapshot) to a less-loaded replica at --interconnect-gbps
instead of queueing every match on its owner.

Every architecture in the pool serves — attention, MLA, SSM and hybrid —
including chunked prefill and prefix *compute* reuse (positional ring
snapshots vs page-boundary point snapshots of recurrent state;
DESIGN.md §8). Try --arch mamba2-2.7b or --arch hymba-1.5b with
--shared-prefix-tokens 32.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
      --requests 8 --max-new 16 --kv-tier mrm_rram --weight-tier mrm_rram \
      --replicas 2 --chunk-tokens 32 --kv-policy evict-lru \
      --shared-prefix-tokens 32 --radix-hot-tier auto
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np


def build_engine(args, cfg, full, params):
    from repro.core.memclass import get_technology
    from repro.core.simulator import MemorySystem
    from repro.serving import EngineConfig, ServeEngine

    tiers = {"hbm": (get_technology("hbm3e"), int(args.hbm_gb * 2**30))}
    for t in {args.weight_tier, args.kv_tier} - {"hbm"}:
        tiers[t] = (get_technology(t), int(args.mrm_gb * 2**30))
    if args.spill_tier and args.spill_tier not in tiers:
        tiers[args.spill_tier] = (get_technology(args.spill_tier),
                                  int(args.mrm_gb * 2**30))
    mem = MemorySystem(tiers, ecc_profile=args.ecc_profile,
                       service_refresh=not args.no_refresh)
    return ServeEngine(
        cfg, params, mem,
        EngineConfig(max_slots=args.slots, max_cache_len=128,
                     weight_tier=args.weight_tier, kv_tier=args.kv_tier,
                     page_tokens=args.page_tokens,
                     expected_session_s=args.session_s,
                     chunk_tokens=args.chunk_tokens,
                     kv_pressure_policy=args.kv_policy,
                     kv_spill_tier=args.spill_tier,
                     prefix_caching=not args.no_prefix_caching,
                     tail_copy=args.tail_copy == "on",
                     paged_kernel=args.paged_kernel == "on",
                     kernel_block_q=args.kernel_block_q,
                     kernel_block_kv=args.kernel_block_kv,
                     kernel_buffers=args.kernel_buffers,
                     radix_hot_threshold=args.radix_hot_threshold,
                     radix_hot_tier=args.radix_hot_tier,
                     radix_cold_ttl_s=args.radix_cold_ttl,
                     demote_on_pressure=args.demote_on_pressure,
                     inject_rber=args.inject_rber,
                     inject_seed=args.seed,
                     abandon_after_s=args.abandon_after),
        account_cfg=full)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--weight-tier", default="mrm_rram")
    ap.add_argument("--kv-tier", default="mrm_rram")
    ap.add_argument("--hbm-gb", type=float, default=64)
    ap.add_argument("--mrm-gb", type=float, default=512)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--session-s", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="chunked prefill piece size (None = one maximal "
                         "chunk per prompt on the same unpadded path; "
                         "every mixer family supports chunking)")
    ap.add_argument("--kv-policy", default="evict-lru",
                    choices=("none", "evict-lru", "spill", "recompute"))
    ap.add_argument("--spill-tier", default=None,
                    help="colder tier for the 'spill' pressure policy")
    ap.add_argument("--sessions", type=int, default=3,
                    help="distinct session keys for affinity routing")
    ap.add_argument("--page-tokens", type=int, default=32,
                    help="KV page size in tokens (radix match granularity)")
    ap.add_argument("--no-prefix-caching", action="store_true",
                    help="disable the radix prefix tree (cold baseline; "
                         "the prompt layout is unpadded either way)")
    ap.add_argument("--paged-kernel", choices=("on", "off"), default="on",
                    help="run extend+decode in place on the paged compute "
                         "plane — universal across families: attention/MLA "
                         "on KV pages, SSM/hybrid on pooled point-state "
                         "pages (zero-copy prefix hits, kernel-metered "
                         "tier reads; DESIGN.md §10)")
    ap.add_argument("--kernel-block-q", type=int, default=None,
                    help="paged-attention kernel: query rows per tile "
                         "(None = autotuned best config for the page "
                         "geometry; kernels/paged_attention/tune.py)")
    ap.add_argument("--kernel-block-kv", type=int, default=None,
                    help="paged-attention kernel: page-table slots per "
                         "kv block (None = autotuned)")
    ap.add_argument("--kernel-buffers", type=int, default=None,
                    help="paged-attention kernel: DMA pipeline depth "
                         "2-4 (None = autotuned)")
    ap.add_argument("--tail-copy", choices=("on", "off"), default="on",
                    help="sub-page tail reuse: copy the shared mid-page "
                         "tail into the borrower's page and resume prefill "
                         "from the exact token boundary (DESIGN.md §9)")
    ap.add_argument("--demote-on-pressure", action="store_true",
                    help="under eviction pressure, demote hot prefixes "
                         "back to short retention (metered reprogram) "
                         "before leaf eviction may reach them")
    ap.add_argument("--shared-prefix-tokens", type=int, default=0,
                    help="generated prompts share a head of this many "
                         "tokens (shared system prompt traffic)")
    ap.add_argument("--radix-hot-threshold", type=int, default=4,
                    help="reuse count promoting a prefix to long retention")
    ap.add_argument("--radix-hot-tier", default=None,
                    help="tier for hot prefixes ('auto' = placement solve)")
    ap.add_argument("--radix-cold-ttl", type=float, default=None,
                    help="idle seconds before a cold prefix leaf decays")
    ap.add_argument("--migrate-prefixes", action="store_true",
                    help="fleet prefix directory migrates a hot prefix to "
                         "a less-loaded replica instead of queueing on the "
                         "owner (metered inter-replica transfer)")
    ap.add_argument("--ecc-profile", choices=("off", "uniform", "domain"),
                    default="off",
                    help="reliability plane (DESIGN.md §11): meter ECC "
                         "check bits per tier — 'uniform' sizes one strict "
                         "code per retention point, 'domain' additionally "
                         "lets KV/state pages use the exponent-protected / "
                         "mantissa-relaxed split codeword (denser on "
                         "demoted/cold pages); 'off' meters nothing")
    ap.add_argument("--inject-rber", type=float, default=None,
                    help="inject age-driven bit flips into paged KV/state "
                         "pages: a page exactly at its programmed retention "
                         "sees this raw bit error rate; correction/scrub "
                         "behavior follows --ecc-profile (DESIGN.md §11)")
    ap.add_argument("--no-refresh", action="store_true",
                    help="disable retention-deadline servicing (pages age "
                         "past retention unrefreshed) — the reliability "
                         "gate's degradation A/B arm")
    ap.add_argument("--clock", choices=("lockstep", "event"),
                    default="lockstep",
                    help="cluster clock discipline (DESIGN.md §12): "
                         "'lockstep' advances every replica together each "
                         "frontend step (the PR 3-8 compat driver); 'event' "
                         "drains a priority event queue so replicas advance "
                         "independently and idle ones jump their clocks")
    ap.add_argument("--abandon-after", type=float, default=None,
                    help="seconds a request may wait queued before the "
                         "scheduler abandons it (None = wait forever)")
    ap.add_argument("--interconnect-gbps", type=float, default=50.0,
                    help="per-replica NIC link bandwidth in GBYTES/s — "
                         "the same unit as the memclass tier "
                         "read_bw_gbps/write_bw_gbps fields (the "
                         "prefix-migration cost model)")
    ap.add_argument("--fabric-gbps", type=float, default=None,
                    help="shared-fabric bisection bandwidth in GBYTES/s "
                         "(DESIGN.md §13); transfers queue on donor "
                         "up-links, receiver down-links and "
                         "floor(fabric/link) core channels (default: "
                         "half-bisection, link * replicas//2)")
    ap.add_argument("--replicate-threshold", type=int, default=None,
                    help="fleet-wide directory hits after which a prefix "
                         "is speculatively pushed to the least-loaded "
                         "non-owners (DESIGN.md §13; default: reactive "
                         "demand migration only)")
    ap.add_argument("--replicate-copies", type=int, default=1,
                    help="extra owners the predictive replicator "
                         "maintains for a hot prefix")
    ap.add_argument("--directory-shards", type=int, default=8,
                    help="hash shards the fleet prefix directory spreads "
                         "its digest keys across (load-balance counters "
                         "land in the report)")
    args = ap.parse_args(argv)

    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.serving import ClusterFrontend

    full = get_config(args.arch)
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(args.seed))

    engines = [build_engine(args, cfg, full, params)
               for _ in range(max(args.replicas, 1))]
    rng = np.random.default_rng(args.seed)

    if cfg.n_codebooks > 1:
        shared_head = [list(rng.integers(0, cfg.vocab_size, cfg.n_codebooks))
                       for _ in range(args.shared_prefix_tokens)]
    else:
        shared_head = list(rng.integers(2, cfg.vocab_size,
                                        args.shared_prefix_tokens))

    def gen_prompt():
        n = rng.integers(8, 48)
        if cfg.n_codebooks > 1:
            tail = [list(rng.integers(0, cfg.vocab_size, cfg.n_codebooks))
                    for _ in range(n)]
        else:
            tail = list(rng.integers(2, cfg.vocab_size, n))
        return shared_head + tail

    if len(engines) == 1:
        eng = engines[0]
        for _ in range(args.requests):
            eng.submit(gen_prompt(), max_new_tokens=args.max_new)
        rep = eng.run_until_idle()
    else:
        fe = ClusterFrontend(engines,
                             migrate_prefixes=args.migrate_prefixes,
                             interconnect_gbps=args.interconnect_gbps,
                             clock_mode=args.clock,
                             fabric_bisection_gbps=args.fabric_gbps,
                             replicate_threshold=args.replicate_threshold,
                             replicate_copies=args.replicate_copies,
                             directory_shards=args.directory_shards)
        for i in range(args.requests):
            fe.submit(gen_prompt(), max_new_tokens=args.max_new,
                      session_key=f"session-{i % max(args.sessions, 1)}")
        rep = fe.run_until_idle()
    print(json.dumps(rep, indent=1, default=float))
    return rep


if __name__ == "__main__":
    main()
