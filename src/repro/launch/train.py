"""Training driver: data pipeline -> jitted train_step -> checkpoint/restart,
with the fault-tolerance control loop (heartbeats, elastic re-mesh planning,
straggler policy) wired in.

On this CPU container it trains *reduced* configs for real (examples/
train_tiny_lm.py drives it); on hardware the same driver runs the full
configs — the only difference is the mesh and the --reduced flag.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
      --steps 50 --seq-len 128 --batch 8 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--remat", default=None, choices=[None, "none", "dots", "full"])
    ap.add_argument("--compress", default="none", choices=["none", "int8", "topk"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="simulate a worker failure at this step (FT test)")
    args = ap.parse_args(argv)

    from repro import ckpt as ckpt_lib
    from repro.configs import get_config, reduced as reduce_cfg
    from repro.data import DataConfig, SyntheticPipeline
    from repro.models import init_params
    from repro.optim import OptConfig, init_opt_state
    from repro.optim.compress import compress_decompress, init_state as comp_init
    from repro.runtime.fault_tolerance import ClusterState
    from repro.train import make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if args.remat:
        cfg = dataclasses.replace(cfg, remat=args.remat)
    oc = OptConfig(lr=args.lr, warmup_steps=args.warmup, total_steps=args.steps)

    pipeline = SyntheticPipeline(cfg, DataConfig(
        seq_len=args.seq_len, global_batch=args.batch, seed=args.seed))

    params = init_params(cfg, jax.random.key(args.seed))
    opt_state = init_opt_state(params)
    start_step = 0
    if args.resume and args.ckpt_dir:
        last = ckpt_lib.latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt_state), extra = ckpt_lib.restore(
                args.ckpt_dir, last, (params, opt_state))
            start_step = extra.get("step", last)
            print(f"[train] resumed from step {start_step}")

    base_step = make_train_step(cfg, oc)
    comp_state = comp_init(params) if args.compress != "none" else None

    if args.compress != "none":
        from repro.models.transformer import loss_and_metrics
        from repro.optim.adamw import adamw_update

        def step_fn(params, opt_state, comp_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_and_metrics(cfg, p, batch), has_aux=True)(params)
            grads, comp_state = compress_decompress(grads, comp_state, args.compress)
            new_p, new_o, stats = adamw_update(oc, params, grads, opt_state)
            return new_p, new_o, comp_state, dict(metrics, loss=loss, **stats)

        jstep = jax.jit(step_fn, donate_argnums=(0, 1, 2))
    else:
        jstep = jax.jit(base_step, donate_argnums=(0, 1))

    cluster = ClusterState(workers=[f"w{i}" for i in range(4)], chips_per_worker=1)
    history = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipeline.batch_at(step).items()}
        ts = time.time()
        if args.compress != "none":
            params, opt_state, comp_state, metrics = jstep(params, opt_state,
                                                           comp_state, batch)
        else:
            params, opt_state, metrics = jstep(params, opt_state, batch)
        dt = time.time() - ts

        # fault-tolerance control loop (simulated single-host: all workers
        # report the measured step time; failure injection drops one)
        now = time.time() - t0
        times = {w: dt for w in cluster.workers}
        if args.inject_failure_at >= 0 and step == args.inject_failure_at:
            print(f"[ft] injecting failure of w0 at step {step}")
            if "w0" not in cluster.evicted:
                cluster.evicted.append("w0")
            for w in cluster.workers[1:]:
                cluster.monitor.beat(w, now)
        else:
            for w in cluster.workers:
                if w not in cluster.evicted:
                    cluster.monitor.beat(w, now)
        plan = cluster.handle_step(now, times)
        if plan is not None:
            print(f"[ft] re-mesh plan: {plan}")
            if args.ckpt_dir:
                ckpt_lib.save(args.ckpt_dir, step, (params, opt_state),
                              extra={"step": step})
                print(f"[ft] checkpointed at step {step} for elastic restart")

        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            history.append({"step": step, "loss": loss, "dt_s": dt})
            print(f"step {step:5d} loss {loss:.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt*1e3:.0f} ms)")
        if args.ckpt_dir and step > 0 and step % args.ckpt_every == 0:
            ckpt_lib.save_async(args.ckpt_dir, step, (params, opt_state),
                                extra={"step": step})
    print(json.dumps({"final_loss": history[-1]["loss"] if history else None,
                      "steps": args.steps, "wall_s": time.time() - t0}))
    return history


if __name__ == "__main__":
    main()
