import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell
against the production mesh with ShapeDtypeStruct inputs (no allocation),
then extract memory analysis, cost analysis, and trip-count-aware roofline
terms (launch/hlo_analysis.py).

The XLA_FLAGS line above MUST precede any jax import (jax locks the device
count at first init). Do not import this module from code that needs real
single-device semantics — the orchestrator (--all) runs each cell in its
own subprocess for exactly this reason.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both]
  python -m repro.launch.dryrun --arch ... --set mla_absorb=True --variant absorb
"""
__doc__ = _DOC

import argparse
import dataclasses
import json
import pathlib
import subprocess
import sys
import time

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _parse_set(kvs):
    out = {}
    for kv in kvs or []:
        k, v = kv.split("=", 1)
        try:
            out[k] = json.loads(v)
        except json.JSONDecodeError:
            out[k] = v
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, variant: str = "base",
             overrides=None, rules_name: str = "default", zero1: bool = False,
             fsdp: bool = False, verbose: bool = True) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_shape
    from repro.launch import mesh as meshmod
    from repro.launch.hlo_analysis import analyze, roofline_terms
    from repro.models import transformer as tfm
    from repro.models.frontends import decode_input_specs, input_specs
    from repro.models import param as prm
    from repro.optim import OptConfig, opt_state_defs
    from repro.runtime import sharding as shd
    from repro.train import make_train_step

    t0 = time.time()
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    mesh = meshmod.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    rules = shd.SEQUENCE_PARALLEL_RULES if rules_name == "sp" else shd.DEFAULT_RULES
    sh = shd.ShardCtx(mesh, rules)

    defs = tfm.model_defs(cfg)
    pspecs = shd.param_partition_specs(defs, mesh, rules)
    if fsdp:
        pspecs = _zero1(pspecs, defs, mesh)  # 2D (model x data) weight sharding
    p_shardings = jax.tree.map(
        lambda sp: jax.sharding.NamedSharding(mesh, sp), pspecs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    params_abs = prm.abstract(defs, cfg.param_dtype, p_shardings)

    B, S = shape.global_batch, shape.seq_len

    def batch_abs_of(specs: dict) -> dict:
        out = {}
        for k, sds in specs.items():
            axes = ("act_batch",) + (None,) * (len(sds.shape) - 1)
            out[k] = jax.ShapeDtypeStruct(
                sds.shape, sds.dtype,
                sharding=shd.sharding_for(axes, sds.shape, mesh, rules))
        return out

    if shape.kind == "train":
        oc = OptConfig()
        odefs = opt_state_defs(defs)
        orules = dict(rules)
        ospecs = shd.param_partition_specs(odefs, mesh, orules)
        if zero1 or fsdp:
            ospecs = _zero1(ospecs, odefs, mesh)
        oshard = jax.tree.map(
            lambda sp: jax.sharding.NamedSharding(mesh, sp), ospecs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        opt_abs = prm.abstract(odefs, "float32", oshard)
        batch_abs = batch_abs_of(input_specs(cfg, shape))
        step = make_train_step(cfg, oc, sh)
        lowered = jax.jit(step, donate_argnums=(0, 1)).lower(params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        batch_abs = batch_abs_of(input_specs(cfg, shape))

        def step(params, batch):
            return tfm.prefill(cfg, params, batch, sh)

        lowered = jax.jit(step).lower(params_abs, batch_abs)
    else:  # decode
        caches_abs = _abstract_caches(cfg, sh, mesh, rules, B, S)
        dspecs = decode_input_specs(cfg, shape)
        tok = dspecs["last_tokens"]
        tok_axes = ("act_batch",) + (None,) * (len(tok.shape) - 1)
        tok_abs = jax.ShapeDtypeStruct(
            tok.shape, tok.dtype,
            sharding=shd.sharding_for(tok_axes, tok.shape, mesh, rules))
        pos_abs = jax.ShapeDtypeStruct(
            (), jnp.int32, sharding=jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()))

        def step(params, caches, last_tokens, cur_pos):
            return tfm.decode(cfg, params, caches, last_tokens, cur_pos, sh)

        lowered = jax.jit(step, donate_argnums=(1,)).lower(
            params_abs, caches_abs, tok_abs, pos_abs)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    ana = analyze(text, num_devices=n_dev)
    rt = roofline_terms(ana, peak_flops=meshmod.PEAK_FLOPS_BF16,
                        hbm_bw=meshmod.HBM_BW, ici_bw=meshmod.ICI_BW)

    counts = cfg.param_counts()
    tokens = B * S if shape.kind in ("train", "prefill") else B
    model_flops = (6 if shape.kind == "train" else 2) * counts["active"] * tokens
    hlo_flops_global = ana["flops"] * n_dev
    mem_gib = (ma.argument_size_in_bytes + ma.output_size_in_bytes +
               ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "variant": variant,
        "n_devices": n_dev, "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_gib": ma.argument_size_in_bytes / 2**30,
            "output_gib": ma.output_size_in_bytes / 2**30,
            "temp_gib": ma.temp_size_in_bytes / 2**30,
            "alias_gib": ma.alias_size_in_bytes / 2**30,
            "per_device_gib": mem_gib,
            "fits_16gib": bool(mem_gib <= 16.0),
        },
        "xla_cost_analysis": {k: cost.get(k) for k in
                              ("flops", "bytes accessed", "transcendentals")},
        "hlo_analysis": {k: ana[k] for k in
                         ("flops", "dot_flops", "elementwise_flops",
                          "transcendentals", "bytes_accessed",
                          "collective_operand_bytes", "collective_wire_bytes")},
        "collectives": ana["collectives"],
        "roofline": rt,
        "model_flops": {
            "params_total": counts["total"],
            "params_active": counts["active"],
            "tokens_per_step": tokens,
            "model_flops": model_flops,
            "hlo_flops_global": hlo_flops_global,
            "useful_ratio": model_flops / hlo_flops_global if hlo_flops_global else None,
        },
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind} ({variant}): "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
              f"mem/dev {mem_gib:.2f} GiB "
              f"terms c/m/x = {rt['compute_s']:.2e}/{rt['memory_s']:.2e}/"
              f"{rt['collective_s']:.2e}s dom={rt['dominant']}")
        print("memory_analysis:", ma)
        print("cost_analysis (raw, per-device, loop bodies counted once):",
              {k: cost.get(k) for k in ("flops", "bytes accessed")})
    return result


def _zero1(ospecs, odefs, mesh):
    """Extend optimizer-state specs: shard the first unsharded divisible dim
    over the data axis (ZeRO-1)."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.models.param import is_def

    dsz = mesh.shape.get("data", 1)

    def extend(spec, d):
        if not hasattr(d, "shape") or not d.shape:
            return spec
        parts = list(spec) + [None] * (len(d.shape) - len(spec))
        for i, dim in enumerate(d.shape):
            if parts[i] is None and dim % dsz == 0 and dsz > 1:
                parts[i] = "data"
                return P(*parts)
        return spec

    return jax.tree.map(extend, ospecs, odefs,
                        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def _abstract_caches(cfg, sh, mesh, rules, batch: int, max_len: int):
    """Abstract decode caches with shardings attached."""
    import jax
    from repro.models import transformer as tfm
    from repro.runtime import sharding as shd

    caches = tfm.init_caches(cfg, batch, max_len, abstract=True)
    kvx = sh.kv_axes(cfg)

    def axes_for(path_keys, arr):
        nd = len(arr.shape)
        # leading dim is the scanned-layers stack
        name = path_keys[-1]
        if name in ("k", "v"):
            return ("layers",) + kvx
        if name == "pos":
            return ("layers",) + kvx[:2]
        if name == "c":  # MLA latents: shard the cache sequence over model
            return ("layers", "act_batch", "act_kv_seq", None)
        if name == "kr":
            return ("layers", "act_batch", "act_kv_seq", None)
        if name == "conv":
            return ("layers", "act_batch", None, "ssm_inner")
        if name == "state":
            return ("layers", "act_batch", "act_heads", None, None)
        return ("layers",) + (None,) * (nd - 1)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, tuple):
            return tuple(walk(v, path) for v in tree)
        axes = axes_for(path, tree)
        shardng = shd.sharding_for(axes[: len(tree.shape)], tree.shape, mesh, rules)
        return jax.ShapeDtypeStruct(tree.shape, tree.dtype, sharding=shardng)

    return walk(caches)


# ---------------------------------------------------------------------------
# Orchestrator
# ---------------------------------------------------------------------------


def all_cells(mesh_kinds):
    from repro.configs import ASSIGNED_ARCHS, cells

    for mesh_kind in mesh_kinds:
        for arch, shape_name in cells(ASSIGNED_ARCHS):
            yield arch, shape_name, mesh_kind


def orchestrate(mesh_kinds, skip_existing=True, timeout=7200, archs=None, shapes=None):
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    results = []
    todo = [c for c in all_cells(mesh_kinds)
            if (archs is None or c[0] in archs) and (shapes is None or c[1] in shapes)]
    for i, (arch, shape_name, mesh_kind) in enumerate(todo):
        out = ARTIFACTS / f"{arch}__{shape_name}__{mesh_kind}__base.json"
        if skip_existing and out.exists():
            print(f"[{i+1}/{len(todo)}] skip (exists): {out.name}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape_name, "--mesh", mesh_kind, "--out", str(out)]
        print(f"[{i+1}/{len(todo)}] {' '.join(cmd[2:])}", flush=True)
        t0 = time.time()
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                           env=dict(os.environ, PYTHONPATH="src"))
        dt = time.time() - t0
        if r.returncode != 0:
            fail = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "ok": False, "error": r.stderr[-4000:], "wall_s": dt}
            out.write_text(json.dumps(fail, indent=1))
            print(f"  FAILED after {dt:.0f}s; tail:\n{r.stderr[-1500:]}", flush=True)
        else:
            print(f"  ok in {dt:.0f}s", flush=True)
        results.append(out)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="base")
    ap.add_argument("--set", nargs="*", help="config overrides key=value")
    ap.add_argument("--rules", default="default", choices=["default", "sp"])
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--fsdp", action="store_true",
                    help="2D (model x data) weight sharding (ZeRO-3-style)")
    ap.add_argument("--out")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", nargs="*")
    ap.add_argument("--shapes", nargs="*")
    ap.add_argument("--no-skip", action="store_true")
    args = ap.parse_args()

    if args.all:
        kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        orchestrate(kinds, skip_existing=not args.no_skip,
                    archs=args.archs, shapes=args.shapes)
        return

    res = run_cell(args.arch, args.shape, args.mesh, variant=args.variant,
                   overrides=_parse_set(args.set), rules_name=args.rules,
                   zero1=args.zero1, fsdp=args.fsdp)
    if args.out:
        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        pathlib.Path(args.out).write_text(json.dumps(res, indent=1))


if __name__ == "__main__":
    main()
