"""Trip-count-aware HLO cost/collective analyzer.

``compiled.cost_analysis()`` visits each while-loop (lax.scan) body ONCE —
verified empirically — so for a layer-scanned model it undercounts FLOPs,
bytes, and collective traffic by the layer count. This module parses the
post-SPMD ``compiled.as_text()`` and:

1. builds a per-computation symbol table (instruction -> shape/bytes);
2. computes execution multipliers by walking the call graph (ENTRY = 1;
   `while` bodies x trip count parsed from the condition's loop-bound
   constant; fusion/call/to_apply edges x 1);
3. counts, per executed instruction: dot FLOPs (from contracting/batch
   dims), elementwise FLOPs, transcendentals, a bytes-accessed model
   (result + operands, fusion-collapsed, like XLA's own model), and
   collective bytes for all-gather / all-reduce / reduce-scatter /
   all-to-all / collective-permute (operand bytes, result bytes, and a
   wire-corrected estimate from the replica-group size).

All sizes are PER DEVICE (the SPMD module is the per-device program).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(" + "|".join(sorted(DTYPE_BYTES, key=len, reverse=True)) + r")\[([0-9,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

ELEMENTWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "clamp", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "sign",
}
TRANSCENDENTAL = {"exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                  "logistic", "expm1", "log1p", "cosine", "sine", "atan2",
                  "cbrt", "erf"}


def shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_dims(shape_str: str) -> List[int]:
    """Dims of a non-tuple shape string (first array shape found)."""
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: List[str]
    attrs: str
    args_raw: str = ""
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)


_OPERAND = re.compile(r"%[\w\.\-~]+")


def _comp_header(line: str):
    """Computation headers look like
    `[ENTRY ]%name (args...) -> result_shape {` (args may nest parens).
    Returns (name, is_entry) or None."""
    s = line.strip()
    if not s.endswith("{") or " -> " not in s or " = " in s:
        return None
    is_entry = s.startswith("ENTRY ")
    if is_entry:
        s = s[len("ENTRY "):]
    name = s.split(" ", 1)[0].split("(", 1)[0].lstrip("%")
    if not name:
        return None
    return name, is_entry


def _parse_instr(line: str) -> Optional[Instr]:
    line = line.strip().rstrip(",")
    is_root = line.startswith("ROOT ")
    if is_root:
        line = line[5:]
    eq = line.find(" = ")
    if eq < 0:
        return None
    name = line[:eq].strip()
    rest = line[eq + 3:]
    # shape: balanced-paren tuple or single token
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        shape = rest[:i + 1]
        rest = rest[i + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape = rest[:sp]
        rest = rest[sp + 1:].strip()
    par = rest.find("(")
    if par < 0:
        return None
    op = rest[:par].strip()
    # operand list: balanced parens
    depth = 0
    for i in range(par, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                break
    args = rest[par + 1:i]
    attrs = rest[i + 1:]
    operands = _OPERAND.findall(args)
    return Instr(name=name, shape=shape, op=op, operands=operands, attrs=attrs,
                 args_raw=args, is_root=is_root)


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _comp_header(line)
            if m:
                name, is_entry = m
                cur = Computation(name=name)
                comps[name] = cur
                if is_entry:
                    entry = name
        else:
            s = line.strip()
            if s == "}":
                cur = None
                continue
            ins = _parse_instr(s)
            if ins is not None:
                cur.instrs.append(ins)
    return comps, entry


_CALL_ATTRS = ("calls=", "to_apply=", "body=", "condition=", "branch_computations=")
_COMP_REF = re.compile(r"%?([\w\.\-~]+)")


def _called_comps(ins: Instr) -> List[Tuple[str, str]]:
    """[(kind, computation_name)] referenced by an instruction."""
    out = []
    for key in _CALL_ATTRS:
        idx = ins.attrs.find(key)
        while idx >= 0:
            rest = ins.attrs[idx + len(key):]
            if rest.startswith("{"):
                inner = rest[1:rest.find("}")]
                for m in _COMP_REF.finditer(inner):
                    out.append((key[:-1], m.group(1)))
            else:
                m = _COMP_REF.match(rest)
                if m:
                    out.append((key[:-1], m.group(1)))
            idx = ins.attrs.find(key, idx + 1)
    return out


_INT_CONST = re.compile(r"constant\((\d+)\)")


def _trip_count(cond: Computation) -> int:
    """Loop bound: the largest integer constant in the condition computation
    (scan-generated conditions are `lt(induction_var, constant(N))`)."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant" and re.fullmatch(r"\d+", ins.args_raw.strip()):
            best = max(best, int(ins.args_raw.strip()))
        for mm in _INT_CONST.finditer(ins.attrs):
            best = max(best, int(mm.group(1)))
    return best


def exec_counts(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    counts: Dict[str, float] = defaultdict(float)
    fused: Dict[str, bool] = {}

    def visit(name: str, mult: float):
        if name not in comps:
            return
        counts[name] += mult
        comp = comps[name]
        for ins in comp.instrs:
            refs = _called_comps(ins)
            if ins.op == "while":
                body = cond = None
                for kind, cname in refs:
                    if kind == "body":
                        body = cname
                    elif kind == "condition":
                        cond = cname
                trips = _trip_count(comps[cond]) if cond and cond in comps else 1
                if body:
                    visit(body, mult * trips)
                if cond:
                    visit(cond, mult * (trips + 1))
            else:
                for kind, cname in refs:
                    visit(cname, mult)

    visit(entry, 1.0)
    return counts


def _dot_flops(ins: Instr, table: Dict[str, str]) -> float:
    lhs_shape = table.get(ins.operands[0], "") if ins.operands else ""
    rhs_shape = table.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
    ld, rd = shape_dims(lhs_shape), shape_dims(rhs_shape)
    if not ld or not rd:
        return 0.0

    def dims_of(key):
        m = re.search(key + r"=\{([0-9,]*)\}", ins.attrs)
        return [int(x) for x in m.group(1).split(",") if x] if m and m.group(1) else []

    lb, lc = dims_of("lhs_batch_dims"), dims_of("lhs_contracting_dims")
    rb, rc = dims_of("rhs_batch_dims"), dims_of("rhs_contracting_dims")
    batch = math.prod(ld[i] for i in lb) if lb else 1
    k = math.prod(ld[i] for i in lc) if lc else 1
    m_ = math.prod(d for i, d in enumerate(ld) if i not in lb + lc)
    n_ = math.prod(d for i, d in enumerate(rd) if i not in rb + rc)
    return 2.0 * batch * m_ * n_ * k


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
               "bitcast-convert", "after-all", "opt-barrier", "partition-id",
               "replica-id"}


def inlined_comps(comps: Dict[str, Computation]) -> set:
    """Computations reached via fusion `calls=` / `to_apply=` edges — their
    internals are fused/inlined, so they contribute FLOPs but not memory
    traffic (XLA's fusion bytes model: only the fusion's boundary IO)."""
    out = set()
    for comp in comps.values():
        for ins in comp.instrs:
            for kind, cname in _called_comps(ins):
                if kind in ("calls", "to_apply"):
                    out.add(cname)
    return out


_PASS_THROUGH = {"bitcast", "copy", "reshape", "transpose", "convert"}


def _fusion_bytes(fcomp: Computation, result_shape: str,
                  local: Dict[str, str]) -> float:
    """Boundary-IO bytes for a fusion, recognizing the two scan patterns:
    - a parameter consumed only by dynamic-slice ops -> charge slice bytes
      (stacked layer weights read one layer per iteration);
    - a parameter that is the in-place-updated buffer of a (root)
      dynamic-update-slice -> charge the update bytes, not the buffer.
    Pass-through ops (bitcast/copy/reshape/transpose) are looked through
    when matching either pattern.
    """
    prod: Dict[str, Instr] = {i.name: i for i in fcomp.instrs}

    def resolve(name: str) -> str:
        for _ in range(16):
            ins = prod.get(name)
            if ins is not None and ins.op in _PASS_THROUGH and ins.operands:
                name = ins.operands[0]
            else:
                return name
        return name

    consumers: Dict[str, List[Instr]] = defaultdict(list)
    for ins in fcomp.instrs:
        if ins.op in _PASS_THROUGH:
            continue  # their consumers are attributed via resolve()
        for o in ins.operands:
            consumers[resolve(o)].append(ins)

    root = next((i for i in fcomp.instrs if i.is_root),
                fcomp.instrs[-1] if fcomp.instrs else None)
    root_eff = prod.get(resolve(root.name)) if root is not None else None

    reads = 0.0
    for ins in fcomp.instrs:
        if ins.op != "parameter":
            continue
        psize = shape_bytes(ins.shape)
        cons = consumers.get(ins.name, [])
        if cons and all(c.op == "dynamic-slice" for c in cons):
            reads += sum(shape_bytes(c.shape) for c in cons)
        elif cons and all(c.op == "dynamic-update-slice" and c.operands
                          and resolve(c.operands[0]) == ins.name for c in cons):
            reads += 0.0  # aliased in-place buffer
        else:
            reads += psize
    if (root_eff is not None and root_eff.op == "dynamic-update-slice"
            and len(root_eff.operands) > 1):
        upd = resolve(root_eff.operands[1])
        write = shape_bytes(prod[upd].shape if upd in prod else
                            local.get(upd, root_eff.shape))
    else:
        write = shape_bytes(result_shape)
    return reads + write


def _replica_group_size(attrs: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)  # iota format [n,m]
    if m:
        return int(m.group(2))
    return default


def analyze(text: str, num_devices: int = 1) -> dict:
    """Full-module analysis. Returns totals (per device) and collectives."""
    comps, entry = parse_module(text)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps else None
    counts = exec_counts(comps, entry) if entry else {}

    # global symbol table name -> shape string
    table: Dict[str, str] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            table[ins.name] = ins.shape

    flops = 0.0
    ew_flops = 0.0
    trans = 0.0
    bytes_acc = 0.0
    coll = defaultdict(lambda: {"operand_bytes": 0.0, "result_bytes": 0.0,
                                "wire_bytes": 0.0, "count": 0.0})
    inlined = inlined_comps(comps)

    for cname, comp in comps.items():
        mult = counts.get(cname, 0.0)
        if mult <= 0:
            continue
        for ins in comp.instrs:
            rb = shape_bytes(ins.shape)
            if ins.op not in _SKIP_BYTES and cname not in inlined:
                if ins.op == "fusion":
                    fname = next((c for k, c in _called_comps(ins)
                                  if k == "calls" and c in comps), None)
                    if fname:
                        bytes_acc += mult * _fusion_bytes(
                            comps[fname], ins.shape, table)
                    else:
                        bytes_acc += mult * (rb + sum(
                            shape_bytes(table.get(o, "")) for o in ins.operands))
                elif ins.op == "dynamic-slice":
                    bytes_acc += mult * 2 * rb
                elif ins.op == "dynamic-update-slice":
                    upd = shape_bytes(table.get(ins.operands[1], "")) if len(ins.operands) > 1 else rb
                    bytes_acc += mult * 2 * upd
                else:
                    ob = sum(shape_bytes(table.get(o, "")) for o in ins.operands)
                    bytes_acc += mult * (rb + ob)
            if ins.op == "dot":
                flops += mult * _dot_flops(ins, table)
            elif ins.op in ELEMENTWISE_1FLOP:
                n = math.prod(shape_dims(ins.shape)) if shape_dims(ins.shape) else 0
                ew_flops += mult * n
            elif ins.op in TRANSCENDENTAL:
                n = math.prod(shape_dims(ins.shape)) if shape_dims(ins.shape) else 0
                trans += mult * n
            base = ins.op.split(".")[0]
            if base.endswith("-start"):
                base = base[:-6]
            if base in COLLECTIVES:
                ob = sum(shape_bytes(table.get(o, "")) for o in ins.operands)
                g = _replica_group_size(ins.attrs, num_devices)
                if base == "all-gather":
                    wire = max(rb - ob, 0)
                elif base == "all-reduce":
                    wire = 2.0 * ob * (g - 1) / max(g, 1)
                elif base == "reduce-scatter":
                    wire = max(ob - rb, 0)
                elif base == "all-to-all":
                    wire = ob * (g - 1) / max(g, 1)
                else:  # collective-permute
                    wire = ob
                c = coll[base]
                c["operand_bytes"] += mult * ob
                c["result_bytes"] += mult * rb
                c["wire_bytes"] += mult * wire
                c["count"] += mult
    total_coll_operand = sum(c["operand_bytes"] for c in coll.values())
    total_coll_wire = sum(c["wire_bytes"] for c in coll.values())
    return {
        "dot_flops": flops,
        "elementwise_flops": ew_flops,
        "transcendentals": trans,
        "flops": flops + ew_flops,
        "bytes_accessed": bytes_acc,
        "collectives": {k: dict(v) for k, v in coll.items()},
        "collective_operand_bytes": total_coll_operand,
        "collective_wire_bytes": total_coll_wire,
        "n_computations": len(comps),
    }


def roofline_terms(analysis: dict, *, peak_flops: float, hbm_bw: float,
                   ici_bw: float) -> dict:
    """Three roofline terms in seconds (per-device program)."""
    compute_s = analysis["flops"] / peak_flops
    memory_s = analysis["bytes_accessed"] / hbm_bw
    collective_s = analysis["collective_operand_bytes"] / ici_bw
    collective_wire_s = analysis["collective_wire_bytes"] / ici_bw
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", collective_s), key=lambda kv: kv[1])
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "collective_wire_s": collective_wire_s,
        "dominant": dom[0],
        "bound_s": dom[1],
    }
