"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before jax
initializes devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod'
    axis. Axes are Auto so GSPMD propagates shardings from constraints."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Single-device mesh for CPU smoke tests and examples."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants for the roofline (assignment-specified)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
