"""Retention-aware block error correction (paper §4).

The block interface permits large codewords, which buy correction capability
per parity bit (the paper cites the block-size/performance relation [8]).
RBER grows as a stored block ages toward its programmed retention; the
control plane picks a code (or a refresh deadline) so the uncorrectable
block error rate stays under target *at the scheduled refresh age*, not at
10-year retirement — that is what "retention-aware" buys.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.memclass import MemTechnology


def rber_at_age(tech: MemTechnology, age_s: float, retention_s: float,
                rber0: float = 1e-9, rber_at_retention: float = 1e-4) -> float:
    """Raw bit error rate vs age. Retention is defined as the age where
    RBER reaches `rber_at_retention`; growth is exponential in age/retention
    (thermal-activation loss model, matching the RRAM retention studies
    [22, 31])."""
    frac = min(max(age_s, 0.0) / max(retention_s, 1e-9), 4.0)
    k = math.log(rber_at_retention / rber0)
    return min(rber0 * math.exp(k * frac), 0.5)


def _log_binom_tail(n: int, t: int, p: float) -> float:
    """log10 P[#errors > t] for Bin(n, p), via the dominant term + union
    bound (adequate for p*n << t regimes used here)."""
    if p <= 0:
        return -300.0
    if p >= 0.5:
        return 0.0  # certain failure regime
    # dominant term: exactly t+1 errors
    k = t + 1
    logc = (math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1))
    logp = logc + k * math.log(p) + (n - k) * math.log1p(-p)
    return logp / math.log(10)


@dataclass(frozen=True)
class BlockCode:
    """BCH-like block code over an MRM block."""
    data_bits: int
    parity_bits: int
    correctable: int  # t

    @property
    def n_bits(self) -> int:
        return self.data_bits + self.parity_bits

    @property
    def overhead(self) -> float:
        return self.parity_bits / self.data_bits


def design_code(block_bytes: int, rber: float, uber_target: float = 1e-15,
                m_bits: int = 15) -> BlockCode:
    """Smallest-t BCH-style code for a block at the given RBER.

    BCH over GF(2^m): t errors cost ~ m*t parity bits. Large blocks
    (>= 4 KiB) amortize parity better than 512 B sectors — the §4 claim.
    """
    data_bits = block_bytes * 8
    for t in range(1, 257):
        n = data_bits + m_bits * t
        if _log_binom_tail(n, t, rber) < math.log10(uber_target):
            return BlockCode(data_bits=data_bits, parity_bits=m_bits * t,
                             correctable=t)
    raise ValueError(f"no code with t<=256 reaches UBER {uber_target} at RBER {rber}")


def max_safe_age(tech: MemTechnology, code: BlockCode, retention_s: float,
                 uber_target: float = 1e-15) -> float:
    """Largest age at which the code still meets the UBER target — the
    refresh scheduler's deadline input."""
    lo, hi = 0.0, 4.0 * retention_s
    for _ in range(60):
        mid = (lo + hi) / 2
        p = rber_at_age(tech, mid, retention_s)
        if _log_binom_tail(code.n_bits, code.correctable, p) < math.log10(uber_target):
            lo = mid
        else:
            hi = mid
    return lo
