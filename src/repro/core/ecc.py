"""Retention-aware block error correction (paper §4).

The block interface permits large codewords, which buy correction capability
per parity bit (the paper cites the block-size/performance relation [8]).
RBER grows as a stored block ages toward its programmed retention; the
control plane picks a code (or a refresh deadline) so the uncorrectable
block error rate stays under target *at the scheduled refresh age*, not at
10-year retirement — that is what "retention-aware" buys.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.memclass import MemTechnology


def rber_at_age(tech: MemTechnology, age_s: float, retention_s: float,
                rber0: float = 1e-9, rber_at_retention: float = 1e-4) -> float:
    """Raw bit error rate vs age. Retention is defined as the age where
    RBER reaches `rber_at_retention`; growth is exponential in age/retention
    (thermal-activation loss model, matching the RRAM retention studies
    [22, 31])."""
    frac = min(max(age_s, 0.0) / max(retention_s, 1e-9), 4.0)
    k = math.log(rber_at_retention / rber0)
    return min(rber0 * math.exp(k * frac), 0.5)


def _log_binom_tail(n: int, t: int, p: float) -> float:
    """log10 P[#errors > t] for Bin(n, p), via the dominant term + union
    bound (adequate for p*n << t regimes used here). Below the
    distribution's mode the dominant term at exactly t+1 errors
    *under*-estimates the tail (the mass sits at ~n*p errors, far above
    t), so that regime is reported as certain failure — without the
    guard, `design_code` would happily return t=1 codes at RBERs where
    every block fails."""
    if p <= 0:
        return -300.0
    if p >= 0.5:
        return 0.0  # certain failure regime
    if t < n * p:
        return math.log10(0.5)  # t below the mode: tail >= ~1/2
    # dominant term: exactly t+1 errors
    k = t + 1
    logc = (math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1))
    logp = logc + k * math.log(p) + (n - k) * math.log1p(-p)
    return logp / math.log(10)


@dataclass(frozen=True)
class BlockCode:
    """BCH-like block code over an MRM block."""
    data_bits: int
    parity_bits: int
    correctable: int  # t

    @property
    def n_bits(self) -> int:
        return self.data_bits + self.parity_bits

    @property
    def overhead(self) -> float:
        return self.parity_bits / self.data_bits


def design_code(block_bytes: int, rber: float, uber_target: float = 1e-15,
                m_bits: int = 15) -> BlockCode:
    """Smallest-t BCH-style code for a block at the given RBER.

    BCH over GF(2^m): t errors cost ~ m*t parity bits. Large blocks
    (>= 4 KiB) amortize parity better than 512 B sectors — the §4 claim.
    """
    data_bits = block_bytes * 8
    for t in range(1, 257):
        n = data_bits + m_bits * t
        if _log_binom_tail(n, t, rber) < math.log10(uber_target):
            return BlockCode(data_bits=data_bits, parity_bits=m_bits * t,
                             correctable=t)
    raise ValueError(f"no code with t<=256 reaches UBER {uber_target} at RBER {rber}")


def max_safe_age(tech: MemTechnology, code: BlockCode, retention_s: float,
                 uber_target: float = 1e-15) -> float:
    """Largest age at which the code still meets the UBER target — the
    refresh scheduler's deadline input."""
    lo, hi = 0.0, 4.0 * retention_s
    for _ in range(60):
        mid = (lo + hi) / 2
        p = rber_at_age(tech, mid, retention_s)
        if _log_binom_tail(code.n_bits, code.correctable, p) < math.log10(uber_target):
            lo = mid
        else:
            hi = mid
    return lo


# ---------------------------------------------------------------------------
# Reliability plane (DESIGN.md §11): lower-margin cells + domain-specific ECC
# ---------------------------------------------------------------------------

#: fraction of a bf16 word that inference *cannot* tolerate flipping:
#: sign + 8 exponent bits of 16 (the domain-specific-ECC argument — an
#: exponent flip rescales an activation by up to 2^127, a mantissa flip
#: adds bounded relative noise; PAPERS.md "Breaking the HBM Bit Cost
#: Barrier").
CRIT_FRAC_BF16 = 9.0 / 16.0

#: RBER growth exponent for lower-margin (denser/cheaper) cells: a write
#: programmed at retention r runs cells whose refresh-age RBER is the
#: nominal-margin value scaled by (retention_nominal / r) ** MARGIN_GAMMA
#: — the density lever MRM trades on (paper §4): short-lived data accepts
#: leakier cells, and ECC + refresh absorb the difference.
MARGIN_GAMMA = 1.5

#: designable ceiling for the derated RBER (t <= 256 over a 4 KiB block)
MARGIN_RBER_CAP = 2e-3

#: the serving lifecycle's retention ladder as fractions of a tier's
#: nominal retention — the operating points the TCO/roofline sweeps
#: evaluate ECC overhead at (hot prefix / session page / spill-tier page /
#: over-provisioned spill; DESIGN.md §9, §11)
STATE_RETENTION_FRAC = {
    "hot": 1.0 / 24.0,
    "demoted": 1.0 / 144.0,
    "cold": 1.0 / 288.0,
    "spilled": 1.0 / 1152.0,
}

#: ECC metering profiles accepted by MemorySystem / TierEcc
ECC_PROFILES = ("off", "uniform", "domain")


def margin_derate(tech: MemTechnology, retention_s: float,
                  gamma: float = MARGIN_GAMMA) -> float:
    """RBER multiplier for the lower-margin cells a short-retention write
    runs on (>= 1; 1 at nominal retention)."""
    r = max(min(retention_s, tech.retention_s), 1.0)
    return (tech.retention_s / r) ** gamma


def derated_rber_at_age(tech: MemTechnology, age_s: float, retention_s: float,
                        rber0: float = 1e-9,
                        rber_at_retention: float = 1e-4,
                        gamma: float = MARGIN_GAMMA) -> float:
    """`rber_at_age` on lower-margin cells: both anchor points scale with
    the margin derate, capped at the designable ceiling."""
    d = margin_derate(tech, retention_s, gamma)
    return min(rber_at_age(tech, age_s, retention_s,
                           rber0=min(rber0 * d, MARGIN_RBER_CAP),
                           rber_at_retention=min(rber_at_retention * d,
                                                 MARGIN_RBER_CAP)), 0.5)


def cell_cost_factor(tech: MemTechnology, retention_s: float) -> float:
    """Relative $/GB of the lower-margin cells a short-retention write may
    use (< 1 below nominal retention): relaxed write margin buys density.
    A mild power law floored at 0.65 — the economics coefficient the TCO
    sweep trades against the ECC check-bit overhead."""
    r = max(min(retention_s, tech.retention_s), 1.0)
    return max(0.65, (r / tech.retention_s) ** 0.06)


@dataclass(frozen=True)
class SplitCode:
    """Domain-specific codeword over one block: sign+exponent bits under a
    strict code, mantissa bits under a fixed light code (t=1: flips beyond
    it pass through as bounded activation noise rather than corruption —
    the exponent-protected / mantissa-relaxed trade for KV pages)."""
    crit: BlockCode   # sign + exponent region, strict UBER target
    bulk: BlockCode   # mantissa region, fixed light correction

    @property
    def data_bits(self) -> int:
        return self.crit.data_bits + self.bulk.data_bits

    @property
    def parity_bits(self) -> int:
        return self.crit.parity_bits + self.bulk.parity_bits

    @property
    def n_bits(self) -> int:
        return self.data_bits + self.parity_bits

    @property
    def correctable(self) -> int:
        return self.crit.correctable

    @property
    def overhead(self) -> float:
        return self.parity_bits / self.data_bits


def design_split_code(block_bytes: int, rber: float,
                      uber_target: float = 1e-15,
                      crit_frac: float = CRIT_FRAC_BF16,
                      bulk_correctable: int = 1,
                      m_bits: int = 15) -> SplitCode:
    """Exponent-protected / mantissa-relaxed codeword for a KV block: the
    critical `crit_frac` of the bits gets a strict `design_code`, the
    mantissa remainder a fixed t=`bulk_correctable` code. Beats the
    uniform-strict code exactly where the density lever operates (derated
    RBER >= ~1e-5); at nominal-margin RBER the two are equivalent and the
    caller should prefer whichever is smaller."""
    crit_bytes = max(1, round(block_bytes * crit_frac))
    bulk_bits = block_bytes * 8 - crit_bytes * 8
    crit = design_code(crit_bytes, rber, uber_target, m_bits)
    bulk = BlockCode(data_bits=bulk_bits,
                     parity_bits=m_bits * bulk_correctable,
                     correctable=bulk_correctable)
    return SplitCode(crit=crit, bulk=bulk)


def uncorrectable_log10(code: BlockCode, rber: float) -> float:
    """log10 P[one codeword fails to correct] at the given RBER."""
    return _log_binom_tail(code.n_bits, code.correctable, rber)


class TierEcc:
    """Per-retention-state, per-data-class code selection for one tier.

    The policy of DESIGN.md §11: weights always carry the strict uniform
    code (an exponent *or* mantissa flip in a weight replays into every
    token until redeploy); KV/state pages under the ``domain`` profile
    carry the split exponent-protected / mantissa-relaxed codeword when it
    is cheaper at the write's derated RBER. Codes are sized at the
    *scheduled refresh age* (retention / margin at service time ~
    retention/2) on the lower-margin cells the write's retention admits,
    and cached per (data class, quantized retention).
    """

    def __init__(self, tech: MemTechnology, profile: str,
                 uber_target: float = 1e-15,
                 crit_frac: float = CRIT_FRAC_BF16,
                 gamma: float = MARGIN_GAMMA):
        if profile not in ECC_PROFILES:
            raise ValueError(f"ecc profile {profile!r} not in {ECC_PROFILES}")
        self.tech = tech
        self.profile = profile
        self.uber_target = uber_target
        self.crit_frac = crit_frac
        self.gamma = gamma
        self._cache: dict = {}

    def design_rber(self, retention_s: float) -> float:
        """RBER the code must cover: refresh age (retention/2) on the
        lower-margin cells this retention admits."""
        r = max(min(retention_s, self.tech.retention_s), 1.0)
        return derated_rber_at_age(self.tech, r / 2.0, r, gamma=self.gamma)

    def code_for(self, data_class: str, retention_s: float):
        """BlockCode (weights / uniform profile) or SplitCode (KV under
        ``domain``) for a write programmed at ``retention_s``."""
        if self.profile == "off":
            return None
        # quantize retention to 1/8-decade buckets: one designed code per
        # operating point, not per write
        r = max(min(retention_s, self.tech.retention_s), 1.0)
        key = (data_class, round(8 * math.log10(r)))
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        rber = self.design_rber(r)
        uniform = design_code(self.tech.block_bytes, rber, self.uber_target)
        code = uniform
        if self.profile == "domain" and data_class != "weights":
            split = design_split_code(self.tech.block_bytes, rber,
                                      self.uber_target, self.crit_frac)
            if split.overhead < uniform.overhead:
                code = split
        self._cache[key] = code
        return code

    def overhead_for(self, data_class: str, retention_s: float) -> float:
        """Check-bit bytes per data byte — the capacity/traffic multiplier
        every metering point charges (0 when the profile is off)."""
        code = self.code_for(data_class, retention_s)
        return 0.0 if code is None else code.overhead

    def summary(self) -> dict:
        """Per-state overheads for reporting (kv class, lifecycle ladder)."""
        if self.profile == "off":
            return {"profile": "off"}
        out = {"profile": self.profile}
        for state, frac in STATE_RETENTION_FRAC.items():
            out[state] = self.overhead_for("kv", self.tech.retention_s * frac)
        out["weights"] = self.overhead_for("weights", self.tech.retention_s)
        return out
