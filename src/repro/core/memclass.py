"""Memory-technology models: the quantitative substrate of the paper.

Each :class:`MemTechnology` captures the metrics the paper's §2-§3 argue
over: read/write bandwidth, energy per bit, *retention time*, *endurance*
(device-demonstrated vs technology-potential), density/cost, and the access
granularity. Constants are order-of-magnitude, sourced from the paper's own
citations (documented inline); the benchmarks validate orders of magnitude,
not point values (DESIGN.md §5).

The MRM_* entries are the paper's proposal: SCM technologies re-operated at
relaxed retention (hours-days instead of 10+ years), trading retention for
endurance / write energy via the DCM model in `repro.core.dcm`.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional

HOUR = 3600.0
DAY = 24 * HOUR
YEAR = 365.25 * DAY


@dataclass(frozen=True)
class MemTechnology:
    name: str
    kind: str  # "volatile" | "nonvolatile" | "managed"
    # bandwidth per device/stack (GB/s)
    read_bw_gbps: float
    write_bw_gbps: float
    # energy (pJ/bit) — paper §2.1: ~1/3 of accelerator energy is memory
    read_energy_pj_bit: float
    write_energy_pj_bit: float
    # endurance: writes per cell
    endurance_device: float      # demonstrated in shipping devices
    endurance_potential: float   # technology potential (paper Fig. 1 sources)
    # retention at the nominal operating point (seconds)
    retention_s: float
    # DRAM-style refresh period (None = no refresh needed at this retention)
    refresh_interval_s: Optional[float]
    # density / economics
    cost_usd_per_gb: float
    # access granularity (the paper: block-level access is fine for inference)
    byte_addressable: bool
    block_bytes: int
    # DCM trade-off coefficients (see repro.core.dcm)
    dcm_alpha: float = 0.0   # write-energy vs retention slope
    dcm_beta: float = 0.0    # endurance vs retention exponent


# ---------------------------------------------------------------------------
# Technology table. Sources (paper citations):
#  [5]  Optane DIMM endurance (blocksandfiles 2019)
#  [27] Meena et al., Overview of emerging NVM technologies (potentials)
#  [29] Weebit ReRAM (embedded, 2x nm)
#  [37] Everspin STT-MRAM 2x nm GP-MCU arrays
#  [46] Sun, Memory-hierarchy design with emerging memories
#  [50] B200 HBM (8 TB/s, 192 GB)
#  HBM3e stack numbers: ~1.2 TB/s, 24-36 GB/stack.
# ---------------------------------------------------------------------------

TECHNOLOGIES: Dict[str, MemTechnology] = {}


def _reg(t: MemTechnology) -> MemTechnology:
    TECHNOLOGIES[t.name] = t
    return t


HBM3E = _reg(MemTechnology(
    name="hbm3e", kind="volatile",
    read_bw_gbps=1200.0, write_bw_gbps=1200.0,
    read_energy_pj_bit=3.5, write_energy_pj_bit=3.5,   # ~pJ/bit incl. PHY
    endurance_device=1e16, endurance_potential=1e16,   # DRAM: unlimited in practice
    retention_s=64e-3, refresh_interval_s=32e-3,       # ms-scale cell retention
    cost_usd_per_gb=12.0,                              # yield/stacking premium (§2.1)
    byte_addressable=True, block_bytes=32,
))

DDR5 = _reg(MemTechnology(
    name="ddr5", kind="volatile",
    read_bw_gbps=64.0, write_bw_gbps=64.0,
    read_energy_pj_bit=12.0, write_energy_pj_bit=12.0,  # off-package IO
    endurance_device=1e16, endurance_potential=1e16,
    retention_s=64e-3, refresh_interval_s=32e-3,
    cost_usd_per_gb=3.0,
    byte_addressable=True, block_bytes=64,
))

LPDDR5X = _reg(MemTechnology(
    name="lpddr5x", kind="volatile",
    read_bw_gbps=68.0, write_bw_gbps=68.0,
    read_energy_pj_bit=5.5, write_energy_pj_bit=5.5,
    endurance_device=1e16, endurance_potential=1e16,
    retention_s=64e-3, refresh_interval_s=32e-3,
    cost_usd_per_gb=4.0,                                # GB200's capacity tier [32]
    byte_addressable=True, block_bytes=64,
))

NAND_SLC = _reg(MemTechnology(
    name="nand_slc", kind="nonvolatile",
    read_bw_gbps=3.0, write_bw_gbps=0.5,
    read_energy_pj_bit=8.0, write_energy_pj_bit=60.0,
    endurance_device=1e5, endurance_potential=1e5,      # paper §3: not enough [7]
    retention_s=10 * YEAR, refresh_interval_s=None,
    cost_usd_per_gb=0.30,
    byte_addressable=False, block_bytes=16384,
))

OPTANE_PCM = _reg(MemTechnology(
    name="optane_pcm", kind="nonvolatile",
    read_bw_gbps=40.0, write_bw_gbps=10.0,
    read_energy_pj_bit=2.0, write_energy_pj_bit=50.0,   # RESET current dominates
    endurance_device=1e8, endurance_potential=1e12,     # [5] device; [27] potential
    retention_s=10 * YEAR, refresh_interval_s=None,
    cost_usd_per_gb=2.0,
    byte_addressable=True, block_bytes=256,
    dcm_alpha=0.35, dcm_beta=0.45,
))

RRAM_DEVICE = _reg(MemTechnology(
    name="rram", kind="nonvolatile",
    read_bw_gbps=20.0, write_bw_gbps=2.0,
    read_energy_pj_bit=1.5, write_energy_pj_bit=20.0,
    endurance_device=1e6, endurance_potential=1e12,     # [29] device; [27,31] potential
    retention_s=10 * YEAR, refresh_interval_s=None,
    cost_usd_per_gb=1.0,
    byte_addressable=True, block_bytes=256,
    dcm_alpha=0.4, dcm_beta=0.5,
))

STT_MRAM_DEVICE = _reg(MemTechnology(
    name="stt_mram", kind="nonvolatile",
    read_bw_gbps=60.0, write_bw_gbps=15.0,
    read_energy_pj_bit=1.0, write_energy_pj_bit=10.0,
    endurance_device=1e10, endurance_potential=1e15,    # [37] device; [27,46] potential
    retention_s=10 * YEAR, refresh_interval_s=None,
    cost_usd_per_gb=5.0,
    byte_addressable=True, block_bytes=64,
    dcm_alpha=0.5, dcm_beta=0.6,
))

# ---------------------------------------------------------------------------
# MRM operating points: the paper's proposal. Same physical technologies,
# re-operated at relaxed retention (days, managed by the software control
# plane) — endurance and write energy improve per the DCM model; read path
# engineered for bandwidth (wide block interface, no random-access overhead,
# lightweight controller).
# ---------------------------------------------------------------------------

MRM_PCM = _reg(MemTechnology(
    name="mrm_pcm", kind="managed",
    read_bw_gbps=900.0, write_bw_gbps=90.0,             # read-optimized stack
    read_energy_pj_bit=1.2, write_energy_pj_bit=18.0,   # relaxed-RESET writes
    endurance_device=3e10, endurance_potential=1e12,
    retention_s=2 * DAY, refresh_interval_s=1 * DAY,
    cost_usd_per_gb=2.5,
    byte_addressable=False, block_bytes=4096,
    dcm_alpha=0.35, dcm_beta=0.45,
))

MRM_RRAM = _reg(MemTechnology(
    name="mrm_rram", kind="managed",
    read_bw_gbps=800.0, write_bw_gbps=60.0,
    read_energy_pj_bit=0.9, write_energy_pj_bit=8.0,
    endurance_device=1e10, endurance_potential=1e12,
    retention_s=1 * DAY, refresh_interval_s=12 * HOUR,
    cost_usd_per_gb=1.5,
    byte_addressable=False, block_bytes=4096,
    dcm_alpha=0.4, dcm_beta=0.5,
))

MRM_MRAM = _reg(MemTechnology(
    name="mrm_mram", kind="managed",
    read_bw_gbps=1000.0, write_bw_gbps=200.0,
    read_energy_pj_bit=0.8, write_energy_pj_bit=4.0,    # low-barrier cells [41, 47]
    endurance_device=1e12, endurance_potential=1e15,
    retention_s=6 * HOUR, refresh_interval_s=3 * HOUR,
    cost_usd_per_gb=4.0,
    byte_addressable=False, block_bytes=1024,
    dcm_alpha=0.5, dcm_beta=0.6,
))


def get_technology(name: str) -> MemTechnology:
    if name not in TECHNOLOGIES:
        raise KeyError(f"unknown memory technology {name!r}; "
                       f"known: {sorted(TECHNOLOGIES)}")
    return TECHNOLOGIES[name]


def derated(tech: MemTechnology, **overrides) -> MemTechnology:
    return replace(tech, **overrides)
