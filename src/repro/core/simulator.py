"""MRM device + memory-system simulator.

The serving engine drives this with its *real* access stream (weight reads
per step, KV page writes/reads, activations) so the paper's workload claims
(read:write ratio, sequentiality, endurance requirements, energy) are
*measured from the running system*, not asserted.

Instruments per tier: bytes read/written (+ sequentiality), energy, wear
(via `repro.core.endurance`), refresh traffic (via `repro.core.refresh`),
and exports the tokens/J / TCO numbers for `benchmarks/mrm_tco.py`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import dcm
from repro.core.ecc import TierEcc, design_code, rber_at_age
from repro.core.endurance import WearLevelingAllocator, WearState
from repro.core.memclass import YEAR, MemTechnology
from repro.core.refresh import Action, RefreshScheduler, RetentionTracker


def data_class_of(owner: str) -> str:
    """Map a region owner tag to its ECC data class: ``weights*`` regions
    carry the strict uniform code, everything else (KV pages, state
    snapshots, activations) is inference cache and may take the relaxed
    mantissa protection under the ``domain`` profile (DESIGN.md §11)."""
    return "weights" if owner.startswith("weights") else "kv"


@dataclass
class IOStats:
    read_bytes: float = 0.0
    write_bytes: float = 0.0
    refresh_bytes: float = 0.0
    read_energy_j: float = 0.0
    write_energy_j: float = 0.0
    n_reads: int = 0
    n_writes: int = 0
    seq_read_bytes: float = 0.0  # reads declared sequential by the caller
    # ECC check-bit traffic rides in separate counters so data-plane
    # identities (kv tier reads == kernel page-gather bytes) survive any
    # profile: the step-latency model adds them in, read_bytes never
    # includes them (DESIGN.md §11)
    ecc_read_bytes: float = 0.0
    ecc_write_bytes: float = 0.0
    scrub_read_bytes: float = 0.0  # data+check bytes re-read by scrubs
    n_scrubs: int = 0

    @property
    def rw_ratio(self) -> float:
        return self.read_bytes / self.write_bytes if self.write_bytes else float("inf")

    @property
    def seq_fraction(self) -> float:
        return self.seq_read_bytes / self.read_bytes if self.read_bytes else 0.0


class MemDevice:
    """One tier: a technology + capacity with wear, retention and ECC."""

    def __init__(self, tech: MemTechnology, capacity_bytes: int,
                 uber_target: float = 1e-15, ecc_profile: str = "off"):
        self.tech = tech
        self.capacity = capacity_bytes
        self.ecc = TierEcc(tech, ecc_profile, uber_target)
        # wear-tracking granularity: cap the array at ~1M entries so huge
        # simulated devices stay cheap to track (a tracking block may span
        # several physical blocks; wear stats are per tracking block)
        self.track_block_bytes = max(tech.block_bytes,
                                     -(-capacity_bytes // (1 << 20)))
        self.n_blocks = max(1, capacity_bytes // self.track_block_bytes)
        self.wear = WearState(self.n_blocks, self.track_block_bytes,
                              tech.endurance_device)
        self.alloc = WearLevelingAllocator(self.wear)
        self.stats = IOStats()
        # retention-aware ECC: size the code for the RBER at refresh age
        if tech.kind == "managed":
            ref_age = tech.retention_s / 2
            self.code = design_code(tech.block_bytes,
                                    rber_at_age(tech, ref_age, tech.retention_s),
                                    uber_target)
        else:
            self.code = design_code(tech.block_bytes, 1e-9, uber_target)

    # -- IO ---------------------------------------------------------------
    def read(self, nbytes: float, sequential: bool = True,
             data_class: str = "kv",
             retention_s: Optional[float] = None) -> None:
        """Meter a data read. Invariant: ``read_bytes`` counts *data* bytes
        only — the check bits that ride along under an active ECC profile
        land in ``ecc_read_bytes`` (energy charged, latency charged via
        :meth:`MemorySystem.step_latency_since`), so data-plane byte
        identities are profile-independent."""
        s = self.stats
        s.read_bytes += nbytes
        s.n_reads += 1
        if sequential:
            s.seq_read_bytes += nbytes
        eb = nbytes * self.ecc.overhead_for(
            data_class, retention_s if retention_s is not None
            else self.tech.retention_s)
        s.ecc_read_bytes += eb
        s.read_energy_j += (nbytes + eb) * 8 * self.tech.read_energy_pj_bit * 1e-12

    def write(self, nbytes: float, expected_lifetime_s: Optional[float] = None,
              refresh: bool = False, data_class: str = "kv") -> dcm.WriteOp:
        """Meter a data write (or refresh rewrite). Same ECC invariant as
        :meth:`read`: check bits for the write's programmed retention land
        in ``ecc_write_bytes``, never in ``write_bytes``/``refresh_bytes``."""
        if expected_lifetime_s is None:
            expected_lifetime_s = self.tech.retention_s / 2.0
        op = dcm.plan_write(self.tech, expected_lifetime_s)
        s = self.stats
        if refresh:
            s.refresh_bytes += nbytes
        else:
            s.write_bytes += nbytes
            s.n_writes += 1
        eb = nbytes * self.ecc.overhead_for(data_class, op.retention_s)
        s.ecc_write_bytes += eb
        s.write_energy_j += (nbytes + eb) * 8 * op.energy_pj_bit * 1e-12
        return op

    def blocks_for(self, nbytes: float) -> int:
        return max(1, int(-(-nbytes // self.track_block_bytes)))

    def blocks_for_stored(self, nbytes: float, data_class: str,
                          retention_s: float) -> int:
        """Capacity-ledger tenant rule (DESIGN.md §11): a stored region
        occupies blocks for its data bytes *plus* the check bits its code
        requires at this retention — ECC overhead is charged into the same
        per-tier block ledger as the data it protects."""
        ov = self.ecc.overhead_for(data_class, retention_s)
        return self.blocks_for(nbytes * (1.0 + ov))

    @property
    def energy_j(self) -> float:
        return self.stats.read_energy_j + self.stats.write_energy_j

    def report(self) -> dict:
        s = self.stats
        return {
            "tech": self.tech.name,
            "capacity_gb": self.capacity / 1e9,
            "read_gb": s.read_bytes / 1e9,
            "write_gb": s.write_bytes / 1e9,
            "refresh_gb": s.refresh_bytes / 1e9,
            "rw_ratio": s.rw_ratio,
            "seq_fraction": s.seq_fraction,
            "energy_j": self.energy_j,
            "wear_max": self.wear.max_wear,
            "wear_ratio": self.wear.wear_ratio,
            "life_used": self.wear.life_used(),
            "ecc_overhead": self.code.overhead,
            "ecc_profile": self.ecc.profile,
            "ecc_read_gb": s.ecc_read_bytes / 1e9,
            "ecc_write_gb": s.ecc_write_bytes / 1e9,
            "scrub_read_gb": s.scrub_read_bytes / 1e9,
            "n_scrubs": s.n_scrubs,
            "utilization": self.alloc.utilization,
        }


class MemorySystem:
    """Tiers + retention tracker + refresh scheduler, as one control plane."""

    def __init__(self, tiers: Dict[str, Tuple[MemTechnology, int]],
                 margin: float = 2.0, ecc_profile: str = "off",
                 service_refresh: bool = True):
        self.devices: Dict[str, MemDevice] = {
            name: MemDevice(tech, cap, ecc_profile=ecc_profile)
            for name, (tech, cap) in tiers.items()}
        self.ecc_profile = ecc_profile
        #: A/B switch for the reliability gate: with ``service_refresh``
        #: off, retention deadlines are never serviced, so regions age past
        #: their programmed retention and the fault injector sees the
        #: over-aged RBER (CI asserts decode degrades; DESIGN.md §11).
        self.service_refresh = service_refresh
        self.tracker = RetentionTracker(margin=margin)
        self.scheduler = RefreshScheduler(self.tracker)
        self.now = 0.0
        self._regions: Dict[int, Tuple[str, List[int]]] = {}

    def advance(self, dt: float) -> List:
        """Advance simulation time; service refresh deadlines."""
        self.now += dt
        if not self.service_refresh:
            return []
        actions = self.scheduler.tick(self.now)
        for a in actions:
            dev = self.devices[a.region.tier]
            if a.action == Action.REFRESH:
                dev.write(a.region.bytes,
                          expected_lifetime_s=a.region.retention_s / self.tracker.margin,
                          refresh=True, data_class=data_class_of(a.region.owner))
                blocks = self._regions.get(a.region.region_id, (None, []))[1]
                if blocks:
                    dev.alloc.rewrite_in_place(blocks)
            else:
                _, blocks = self._regions.pop(a.region.region_id, (None, []))
                if blocks:
                    dev.alloc.free_blocks(blocks)
        return actions

    def write_region(self, tier: str, owner: str, nbytes: float,
                     expected_lifetime_s: float, sequential: bool = True) -> Optional[int]:
        """Allocate + write a region with DCM-programmed retention.
        Returns a region id (None = allocation failure)."""
        dev = self.devices[tier]
        dc = data_class_of(owner)
        # size the block claim at the *programmed* retention's code so the
        # capacity ledger carries the check-bit tenant from allocation on
        ret = dcm.plan_write(dev.tech, expected_lifetime_s).retention_s
        nblocks = dev.blocks_for_stored(nbytes, dc, ret)
        blocks = dev.alloc.alloc(nblocks)
        if blocks is None:
            return None
        op = dev.write(nbytes, expected_lifetime_s=expected_lifetime_s,
                       data_class=dc)
        rid = self.tracker.track(owner, tier, nblocks, nbytes, self.now,
                                 op.retention_s)
        self._regions[rid] = (tier, blocks)
        return rid

    def read_region(self, rid: int, nbytes: Optional[float] = None,
                    sequential: bool = True) -> None:
        r = self.tracker.get(rid)  # O(1): hottest call in the serving loop
        if r is None:
            return
        self.devices[r.tier].read(nbytes if nbytes is not None else r.bytes,
                                  sequential, data_class=data_class_of(r.owner),
                                  retention_s=r.retention_s)
        self.tracker.touch(rid, self.now)

    def scrub_region(self, rid: int) -> bool:
        """Scrub-on-read: re-read the region's data + check bits, correct,
        and rewrite in place at the same retention point.

        Metering invariant ("scrub-charged-as-refresh", DESIGN.md §11):
        the read side lands in ``scrub_read_bytes`` (data + check bits,
        read energy charged), the corrective rewrite is charged exactly
        like a scheduled refresh — ``refresh_bytes`` + ECC check bits +
        in-place wear — and the retention clock re-arms, so a scrubbed
        page needs no separate refresh this deadline. Returns False for
        unknown/released regions."""
        r = self.tracker.get(rid)
        if r is None:
            return False
        dev = self.devices[r.tier]
        dc = data_class_of(r.owner)
        ov = dev.ecc.overhead_for(dc, r.retention_s)
        s = dev.stats
        s.scrub_read_bytes += r.bytes * (1.0 + ov)
        s.read_energy_j += r.bytes * (1.0 + ov) * 8 * dev.tech.read_energy_pj_bit * 1e-12
        s.n_scrubs += 1
        dev.write(r.bytes, expected_lifetime_s=r.retention_s / self.tracker.margin,
                  refresh=True, data_class=dc)
        blocks = self._regions.get(rid, (None, []))[1]
        if blocks:
            dev.alloc.scrub_in_place(blocks)
        self.tracker.rearm(r, self.now)
        return True

    def region(self, rid: int):
        """O(1) region metadata lookup (tier, bytes, deadlines)."""
        return self.tracker.get(rid)

    def utilization(self, tier: str) -> float:
        """Fraction of the tier's tracked blocks currently allocated."""
        return self.devices[tier].alloc.utilization

    # -- per-tier step-latency model -----------------------------------
    def snapshot(self) -> Dict[str, Tuple[float, float]]:
        """Per-tier (read_bytes, write+refresh_bytes) counters; pair with
        :meth:`step_latency_since` to time an engine step. ECC check-bit
        and scrub traffic is folded into the totals here (the wire moves
        those bits, so the step-latency model must charge them) while the
        per-class data counters stay ECC-free."""
        return {n: (d.stats.read_bytes + d.stats.ecc_read_bytes
                    + d.stats.scrub_read_bytes,
                    d.stats.write_bytes + d.stats.refresh_bytes
                    + d.stats.ecc_write_bytes)
                for n, d in self.devices.items()}

    def step_latency_since(self, snap: Dict[str, Tuple[float, float]],
                           floor_s: float = 1e-4) -> Tuple[float, Dict[str, dict]]:
        """Model the wall time of the traffic since ``snap``: each tier
        serves its own reads at its read bandwidth and its writes at its
        write bandwidth; tiers run in parallel, so the step takes as long
        as the slowest tier (not all bytes charged to one tier's read BW).
        Returns (step_seconds, per-tier byte/latency breakdown)."""
        step_s = floor_s
        per_tier: Dict[str, dict] = {}
        for n, d in self.devices.items():
            r0, w0 = snap.get(n, (0.0, 0.0))
            dr = (d.stats.read_bytes + d.stats.ecc_read_bytes
                  + d.stats.scrub_read_bytes) - r0
            dw = (d.stats.write_bytes + d.stats.refresh_bytes
                  + d.stats.ecc_write_bytes) - w0
            lat = (dr / (d.tech.read_bw_gbps * 1e9) +
                   dw / (d.tech.write_bw_gbps * 1e9))
            per_tier[n] = {"read_bytes": dr, "write_bytes": dw,
                           "latency_s": lat}
            step_s = max(step_s, lat)
        return step_s, per_tier

    def release_region(self, rid: int) -> None:
        self.tracker.release(rid)
        entry = self._regions.pop(rid, None)
        if entry:
            tier, blocks = entry
            self.devices[tier].alloc.free_blocks(blocks)

    def report(self) -> dict:
        return {
            "now_s": self.now,
            "ecc_profile": self.ecc_profile,
            "tiers": {n: d.report() for n, d in self.devices.items()},
            "refresh_stats": dict(self.tracker.stats),
            "total_energy_j": sum(d.energy_j for d in self.devices.values()),
        }
