"""Endurance accounting and software wear-levelling (paper §4:
"lightweight memory controllers" — refresh and wear-levelling lifted out of
the device into the control plane).

Also hosts the Figure-1 arithmetic: writes/cell over a device lifetime for
the weight-update and KV-cache-append workloads.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.memclass import YEAR, MemTechnology


# ---------------------------------------------------------------------------
# Figure-1 arithmetic
# ---------------------------------------------------------------------------


def writes_per_cell(write_bytes_per_s: float, capacity_bytes: float,
                    lifetime_s: float = 5 * YEAR,
                    leveling_efficiency: float = 1.0) -> float:
    """Average writes per cell over the device lifetime.

    Perfect wear-levelling spreads the write stream uniformly; a real
    software leveller achieves `leveling_efficiency` (<= 1) of that.
    """
    total_writes = write_bytes_per_s * lifetime_s
    return total_writes / capacity_bytes / max(leveling_efficiency, 1e-9)


def weight_update_writes(update_period_s: float, lifetime_s: float = 5 * YEAR) -> float:
    """Paper §3: weights are bulk-overwritten when the model is replaced —
    each update writes every cell of the weight region exactly once."""
    return lifetime_s / update_period_s


# ---------------------------------------------------------------------------
# Block wear state + software wear-levelling allocator
# ---------------------------------------------------------------------------


@dataclass
class WearState:
    """Per-block write counters for one MRM device/region."""
    n_blocks: int
    block_bytes: int
    endurance: float
    writes: np.ndarray = field(default=None)  # type: ignore
    scrub_rewrites: int = 0  # corrective rewrites (DESIGN.md §11 scrubs)

    def __post_init__(self):
        if self.writes is None:
            self.writes = np.zeros(self.n_blocks, dtype=np.float32)

    def record_write(self, block_ids) -> None:
        self.writes[np.asarray(block_ids)] += 1.0

    def record_scrub(self, block_ids) -> None:
        """Scrub-on-read rewrite: same wear as a refresh rewrite, counted
        separately so the endurance budget attributes reliability traffic."""
        self.scrub_rewrites += len(block_ids)
        self.record_write(block_ids)

    @property
    def max_wear(self) -> float:
        return float(self.writes.max(initial=0.0))

    @property
    def mean_wear(self) -> float:
        return float(self.writes.mean()) if self.n_blocks else 0.0

    @property
    def wear_ratio(self) -> float:
        """max/mean — 1.0 is perfect levelling."""
        m = self.mean_wear
        return self.max_wear / m if m > 0 else 1.0

    def life_used(self) -> float:
        return self.max_wear / self.endurance

    def project_lifetime_s(self, write_bytes_per_s: float, now_s: float) -> float:
        """Remaining seconds until the most-worn block hits endurance,
        extrapolating the current write rate with the current wear ratio."""
        if write_bytes_per_s <= 0:
            return float("inf")
        mean_rate = write_bytes_per_s / (self.n_blocks * self.block_bytes)
        max_rate = mean_rate * self.wear_ratio
        remaining = self.endurance - self.max_wear
        return remaining / max(max_rate, 1e-30)


class WearLevelingAllocator:
    """Least-worn-first free-block allocator, O(log n) per op.

    The control plane owns allocation, so levelling is a policy, not device
    firmware. Never-written blocks (wear 0) are handed out from a sequential
    frontier (also giving new allocations *physically sequential* block
    runs — the paper's sequential-IO property); freed blocks re-enter via a
    min-heap keyed by wear, so reuse prefers the least-worn.
    """

    def __init__(self, wear: WearState):
        import heapq
        self.wear = wear
        self._frontier = 0                      # next never-used block
        self._freed: list = []                  # heap of (wear, block)
        self._heapq = heapq
        self._n_free = wear.n_blocks

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > self._n_free:
            return None
        picked: List[int] = []
        fresh = min(n, self.wear.n_blocks - self._frontier)
        if fresh > 0:
            picked.extend(range(self._frontier, self._frontier + fresh))
            self._frontier += fresh
        while len(picked) < n:
            _, b = self._heapq.heappop(self._freed)
            picked.append(b)
        self._n_free -= n
        self.wear.record_write(picked)
        return picked

    def free_blocks(self, block_ids) -> None:
        for b in block_ids:
            self._heapq.heappush(self._freed, (float(self.wear.writes[int(b)]), int(b)))
        self._n_free += len(block_ids)

    def rewrite_in_place(self, block_ids) -> None:
        """A refresh rewrite (costs wear, keeps placement)."""
        self.wear.record_write(block_ids)

    def scrub_in_place(self, block_ids) -> None:
        """A scrub's corrective rewrite — refresh wear, scrub-attributed."""
        self.wear.record_scrub(block_ids)

    @property
    def utilization(self) -> float:
        return 1.0 - self._n_free / max(self.wear.n_blocks, 1)
