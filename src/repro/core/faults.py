"""Fault injection for the reliability plane (DESIGN.md §11).

Flips bits in paged KV/state compute arrays according to each page's age
and retention state, so CI can measure — not assert — that the ECC plane
holds decode together at the target RBER and that an over-aged page
without refresh degrades.

The injector works at two scales, mirroring how the repo meters memory:

- **accounting scale** — the region's deployment-size byte count, where
  uncorrectable-block *events* are sampled (``Poisson(n_blocks * P[block
  uncorrectable at this RBER])``); a tier's ECC either corrects a block
  or it doesn't, and that probability depends on the real block
  population, not the reduced model's array sizes;
- **compute scale** — the actual (reduced-model) page array, where raw
  flips land (``Poisson(array_bits * rber)``) so corruption propagates
  through real decode math.

Contract with the ECC profile (engine ``--inject-rber`` plumbing):

- profile ``off``: every sampled raw flip lands — no correction, no scrub;
- profile ``uniform``/``domain``: critical (sign+exponent) flips land only
  when an accounting-scale block is uncorrectable; mantissa flips beyond
  the bulk code's per-block budget pass through as bounded activation
  noise (that *is* the relaxed-mantissa trade); pages whose age crosses
  ``scrub_age_frac`` of the refresh interval request a scrub-on-read
  instead, which corrects everything and re-arms the retention clock
  (metered by :meth:`repro.core.simulator.MemorySystem.scrub_region`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from repro.core.ecc import SplitCode, rber_at_age, uncorrectable_log10

#: sign+exponent ("critical") bit range per float dtype: (low bit, word bits)
CRIT_BIT_RANGE = {
    "bfloat16": (7, 16),
    "float16": (10, 16),
    "float32": (23, 32),
}

_UINT_FOR_ITEMSIZE = {2: np.uint16, 4: np.uint32}

#: hard cap on flips applied to one array per visit — keeps the clamped
#: RBER=0.5 regime (over-aged pages) linear in array size
MAX_FLIPS_PER_VISIT = 1 << 20


@dataclass
class FaultStats:
    """Counters surfaced in the engine report's ``reliability`` section."""
    pages_visited: int = 0
    scrubs_requested: int = 0
    crit_flips: int = 0
    bulk_flips: int = 0
    corrected_bits: int = 0
    uncorrectable_blocks: int = 0

    def as_dict(self) -> dict:
        return {
            "pages_visited": self.pages_visited,
            "scrubs_requested": self.scrubs_requested,
            "crit_flips": self.crit_flips,
            "bulk_flips": self.bulk_flips,
            "corrected_bits": self.corrected_bits,
            "uncorrectable_blocks": self.uncorrectable_blocks,
        }


def flip_bits(arr: np.ndarray, n_crit: int, n_bulk: int,
              rng: np.random.Generator) -> np.ndarray:
    """Return a copy of ``arr`` with ``n_crit`` sign/exponent flips and
    ``n_bulk`` mantissa flips at uniformly random positions (with
    replacement — colliding flips cancel, as real double errors do)."""
    if n_crit <= 0 and n_bulk <= 0:
        return arr
    name = arr.dtype.name
    lo, word_bits = CRIT_BIT_RANGE.get(name, CRIT_BIT_RANGE["float32"])
    uint = _UINT_FOR_ITEMSIZE[arr.dtype.itemsize]
    flat = np.ascontiguousarray(arr).view(uint).reshape(-1).copy()
    size = flat.size
    if size == 0:
        return arr
    if n_crit > 0:
        idx = rng.integers(0, size, int(n_crit))
        bit = rng.integers(lo, word_bits, int(n_crit))
        np.bitwise_xor.at(flat, idx, (np.ones(1, uint) << bit.astype(uint)))
    if n_bulk > 0:
        idx = rng.integers(0, size, int(n_bulk))
        bit = rng.integers(0, lo, int(n_bulk))
        np.bitwise_xor.at(flat, idx, (np.ones(1, uint) << bit.astype(uint)))
    return flat.view(arr.dtype).reshape(arr.shape)


class FaultInjector:
    """Age-driven bit-flip source for paged KV/state arrays.

    ``rber_at_retention`` (the ``--inject-rber`` value) anchors the error
    curve: a page exactly at its programmed retention sees that RBER; a
    freshly written page sees 1e-5 of it; growth between is exponential in
    age/retention (same law as :func:`repro.core.ecc.rber_at_age`), and a
    page at >= 4x its retention saturates at the 0.5 clamp — pure noise.
    """

    def __init__(self, mem, rber_at_retention: float, seed: int = 0,
                 scrub_age_frac: float = 0.75):
        self.mem = mem
        self.rber = float(rber_at_retention)
        self.scrub_age_frac = scrub_age_frac
        self.rng = np.random.default_rng(seed)
        self.stats = FaultStats()

    # -- error model ------------------------------------------------------
    def page_rber(self, region) -> float:
        """Raw bit error rate of a tracked region at the current sim time."""
        age = max(self.mem.now - region.written_at, 0.0)
        tech = self.mem.devices[region.tier].tech
        return rber_at_age(tech, age, region.retention_s,
                           rber0=self.rber * 1e-5,
                           rber_at_retention=self.rber)

    def wants_scrub(self, region) -> bool:
        """True when the page is old enough that a real controller would
        scrub on read (deterministic at ``scrub_age_frac`` of the refresh
        interval — the CI gate relies on this firing before the refresh
        deadline)."""
        age = self.mem.now - region.written_at
        interval = region.retention_s / self.mem.tracker.margin
        return age >= self.scrub_age_frac * interval

    # -- injection --------------------------------------------------------
    def corrupt(self, arr, region, protected: bool) -> Tuple[Optional[np.ndarray], int]:
        """Sample faults for one page visit; returns (corrupted array or
        None if nothing landed, uncorrectable block count this visit).

        ``protected`` states whether an ECC profile is active for the
        page's tier (engine passes ``ecc_profile != "off"``). Callers own
        the ``pages_visited`` counter — one page may span several cache
        leaves, each corrupted by its own call.
        """
        a = np.asarray(arr)
        if a.dtype.itemsize not in _UINT_FOR_ITEMSIZE:
            return None, 0
        p = self.page_rber(region)
        if p <= 0:
            return None, 0
        name = a.dtype.name
        lo, word_bits = CRIT_BIT_RANGE.get(name, CRIT_BIT_RANGE["float32"])
        crit_frac = (word_bits - lo) / word_bits
        bits = a.size * a.dtype.itemsize * 8
        n_crit_raw = int(self.rng.poisson(bits * crit_frac * p))
        n_bulk_raw = int(self.rng.poisson(bits * (1.0 - crit_frac) * p))
        n_bad = 0
        if protected:
            dev = self.mem.devices[region.tier]
            code = dev.ecc.code_for("kv", region.retention_s)
            crit_code = code.crit if isinstance(code, SplitCode) else code
            bulk_t = (code.bulk.correctable if isinstance(code, SplitCode)
                      else code.correctable)
            # accounting scale: does any real block fail to correct?
            n_blocks = max(1, int(region.bytes // dev.tech.block_bytes))
            p_fail = min(10.0 ** uncorrectable_log10(crit_code, p), 1.0)
            n_bad = int(min(self.rng.poisson(n_blocks * p_fail), n_blocks))
            frac_bad = n_bad / n_blocks
            n_crit = int(round(n_crit_raw * frac_bad))
            if n_bad > 0:
                n_crit = max(n_crit, 1)
            # bulk code corrects up to t per compute-scale block; the rest
            # passes through as activation noise
            blocks_compute = max(1, bits // (dev.tech.block_bytes * 8))
            budget = int(blocks_compute * bulk_t)
            n_bulk = max(0, n_bulk_raw - budget)
            self.stats.corrected_bits += (n_crit_raw - n_crit) + (n_bulk_raw - n_bulk)
        else:
            n_crit, n_bulk = n_crit_raw, n_bulk_raw
        n_crit = min(n_crit, MAX_FLIPS_PER_VISIT)
        n_bulk = min(n_bulk, MAX_FLIPS_PER_VISIT)
        self.stats.crit_flips += n_crit
        self.stats.bulk_flips += n_bulk
        self.stats.uncorrectable_blocks += n_bad
        if n_crit == 0 and n_bulk == 0:
            return None, n_bad
        return flip_bits(a, n_crit, n_bulk, self.rng), n_bad

    def note_scrub(self) -> None:
        self.stats.scrubs_requested += 1
