"""Benchmark trajectory persistence shared by the bench suites.

Every gated sweep appends its result to a ``BENCH_*.json`` file at the
repo root — the trajectory CI uploads as an artifact and later sessions
diff against. The sweeps are deterministic, so re-runs of identical code
must not grow the file: an entry whose metric fields match the last
persisted entry for the same key is dropped instead of appended (``at``
is tiebreak metadata, not a metric). Extracted from
``benchmarks/serving_sim.py`` when the fleet scenario zoo
(``experiments/``) became the third writer of this format.
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional


def persist_trajectory(filename: str, entry: dict, key: str = "arch",
                       root: Optional[str] = None,
                       ignore: tuple = ("at",)) -> bool:
    """Append ``entry`` to ``<repo root>/<filename>`` unless it duplicates
    the last entry with the same ``entry[key]`` on every field outside
    ``ignore`` (wall-clock fields like ``at`` or ``wall_s`` are metadata,
    not metrics). Returns True if the entry was written, False if
    deduplicated away."""
    if root is None:
        # src/repro/core/trajectory.py -> repo root is four levels up
        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
    path = os.path.join(root, filename)
    data = {"entries": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {"entries": []}
    entries = data.setdefault("entries", [])
    tag = entry.get(key)
    last = next((e for e in reversed(entries) if e.get(key) == tag), None)
    new = json.loads(json.dumps(entry, default=float))
    drop = set(ignore) | {"at"}
    if last is not None and \
            {k: v for k, v in last.items() if k not in drop} == \
            {k: v for k, v in new.items() if k not in drop}:
        return False
    entries.append({"at": time.time(), **new})
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)
        f.write("\n")
    return True
