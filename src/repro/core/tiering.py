"""Retention-aware data placement across memory tiers (paper §4).

The placement problem: assign inference data classes (weights, KV cache,
activations) to tiers (HBM / MRM / LPDDR) subject to hard constraints
(capacity, write bandwidth, endurance over device life, retention
serviceability) minimizing energy + amortized cost. Three classes x a
handful of tiers => exhaustive enumeration is exact and auditable.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import dcm
from repro.core.ecc import TierEcc
from repro.core.endurance import writes_per_cell
from repro.core.memclass import YEAR, MemTechnology


@dataclass(frozen=True)
class DataClassProfile:
    """IO profile of one inference data structure (paper §2 tables)."""
    name: str                 # weights | kv_cache | activations
    size_bytes: float
    read_bw_bytes_s: float    # sustained read demand
    write_bw_bytes_s: float   # sustained write demand
    lifetime_s: float         # how long a written byte stays useful
    soft_state: bool          # recomputable (KV) / re-loadable (weights)
    random_access: bool = False  # needs byte addressability (activations)


@dataclass(frozen=True)
class Tier:
    tech: MemTechnology
    capacity_bytes: float
    count: int = 1  # devices/stacks aggregated

    @property
    def read_bw(self) -> float:
        return self.tech.read_bw_gbps * 1e9 * self.count

    @property
    def write_bw(self) -> float:
        return self.tech.write_bw_gbps * 1e9 * self.count


@dataclass
class PlacementResult:
    assignment: Dict[str, str]            # data class -> tier tech name
    feasible: bool
    violations: List[str]
    energy_w: float                       # sustained memory energy (W)
    cost_usd: float                       # capacity cost
    refresh_overhead_bw: Dict[str, float]  # tier -> refresh write B/s
    per_tier_util: Dict[str, Dict[str, float]] = field(default_factory=dict)
    ecc_overhead: Dict[str, float] = field(default_factory=dict)  # class -> check bits/data bit


def _class_on_tier(dc: DataClassProfile, tier: Tier,
                   device_life_s: float,
                   ecc_profile: str = "off") -> Tuple[List[str], float, float, float]:
    """Check one (class, tier) pairing; returns (violations, energy_w,
    refresh_write_bw, ecc_overhead). Under an active ECC profile every
    byte the class stores or moves on a managed tier carries its code's
    check bits (DESIGN.md §11) — capacity, bandwidth and energy all scale
    by (1 + overhead), sized at the class's DCM-programmed retention."""
    v = []
    t = tier.tech
    if dc.random_access and not t.byte_addressable:
        # paper §2.2: byte addressability is NOT required for weights/KV
        # (large sequential IO) — but transient random-access data cannot
        # live behind a block interface
        v.append(f"{dc.name}: random access on block-interface tier {t.name}")
    # retention service: how often must this data be rewritten just to stay alive?
    refresh_bw = 0.0
    ecc_ov = 0.0
    if t.kind == "managed":
        op = dcm.plan_write(t, dc.lifetime_s)
        write_e = op.energy_pj_bit
        effective_endurance = op.endurance_at_point
        if dc.lifetime_s > op.retention_s:
            # must refresh ceil(lifetime/retention) - 1 times
            refresh_bw = dc.size_bytes / op.retention_s
        if ecc_profile != "off":
            klass = "weights" if dc.name == "weights" else "kv"
            ecc_ov = TierEcc(t, ecc_profile).overhead_for(klass, op.retention_s)
    elif t.refresh_interval_s is not None:
        # DRAM-family: refresh is on-die; modelled as constant energy below
        write_e = t.write_energy_pj_bit
        effective_endurance = t.endurance_device
    else:
        # true NVM at fixed 10y retention
        write_e = t.write_energy_pj_bit
        effective_endurance = t.endurance_device

    scale = 1.0 + ecc_ov
    total_write_bw = (dc.write_bw_bytes_s + refresh_bw) * scale
    stored = dc.size_bytes * scale
    read_bw = dc.read_bw_bytes_s * scale
    if stored > tier.capacity_bytes:
        v.append(f"{dc.name}: size {stored:.2e} > capacity {tier.capacity_bytes:.2e}")
    if read_bw > tier.read_bw:
        v.append(f"{dc.name}: read bw {read_bw:.2e} > {tier.read_bw:.2e}")
    if total_write_bw > tier.write_bw:
        v.append(f"{dc.name}: write bw {total_write_bw:.2e} > {tier.write_bw:.2e}")
    wpc = writes_per_cell(total_write_bw, stored, device_life_s)
    if wpc > effective_endurance:
        v.append(f"{dc.name}: {wpc:.2e} writes/cell > endurance {effective_endurance:.2e}")

    energy_w = (read_bw * 8 * t.read_energy_pj_bit
                + total_write_bw * 8 * write_e) * 1e-12
    if t.refresh_interval_s is not None and t.kind == "volatile":
        # DRAM refresh power ~ 1.5 mW/GB
        energy_w += dc.size_bytes / 1e9 * 1.5e-3
    return v, energy_w, refresh_bw, ecc_ov


def evaluate_placement(classes: Sequence[DataClassProfile], tiers: Sequence[Tier],
                       assignment: Dict[str, str],
                       device_life_s: float = 5 * YEAR,
                       ecc_profile: str = "off") -> PlacementResult:
    by_name = {t.tech.name: t for t in tiers}
    violations: List[str] = []
    energy = 0.0
    refresh: Dict[str, float] = {}
    ecc_ovs: Dict[str, float] = {}
    used: Dict[str, float] = {t.tech.name: 0.0 for t in tiers}
    wbw: Dict[str, float] = {t.tech.name: 0.0 for t in tiers}
    rbw: Dict[str, float] = {t.tech.name: 0.0 for t in tiers}
    for dc in classes:
        tier = by_name[assignment[dc.name]]
        v, e, rfr, ov = _class_on_tier(dc, tier, device_life_s, ecc_profile)
        violations += v
        energy += e
        refresh[tier.tech.name] = refresh.get(tier.tech.name, 0.0) + rfr
        ecc_ovs[dc.name] = ov
        used[tier.tech.name] += dc.size_bytes * (1.0 + ov)
        wbw[tier.tech.name] += (dc.write_bw_bytes_s + rfr) * (1.0 + ov)
        rbw[tier.tech.name] += dc.read_bw_bytes_s * (1.0 + ov)
    for t in tiers:
        n = t.tech.name
        if used[n] > t.capacity_bytes:
            violations.append(f"tier {n}: capacity over-subscribed "
                              f"({used[n]:.2e} > {t.capacity_bytes:.2e})")
        if wbw[n] > t.write_bw:
            violations.append(f"tier {n}: write bw over-subscribed")
        if rbw[n] > t.read_bw:
            violations.append(f"tier {n}: read bw over-subscribed")
    cost = sum(t.capacity_bytes / 1e9 * t.tech.cost_usd_per_gb for t in tiers
               if any(assignment[dc.name] == t.tech.name for dc in classes))
    util = {t.tech.name: {
        "capacity": used[t.tech.name] / t.capacity_bytes,
        "read_bw": rbw[t.tech.name] / t.read_bw,
        "write_bw": wbw[t.tech.name] / t.write_bw,
    } for t in tiers}
    return PlacementResult(assignment=dict(assignment),
                           feasible=not violations, violations=violations,
                           energy_w=energy, cost_usd=cost,
                           refresh_overhead_bw=refresh, per_tier_util=util,
                           ecc_overhead=ecc_ovs)


def solve_placement(classes: Sequence[DataClassProfile], tiers: Sequence[Tier],
                    device_life_s: float = 5 * YEAR,
                    objective: str = "energy",
                    ecc_profile: str = "off") -> PlacementResult:
    """Exhaustive exact solve (|classes|^|tiers| is tiny)."""
    names = [t.tech.name for t in tiers]
    best: Optional[PlacementResult] = None
    for combo in itertools.product(names, repeat=len(classes)):
        assignment = {dc.name: tn for dc, tn in zip(classes, combo)}
        res = evaluate_placement(classes, tiers, assignment, device_life_s,
                                 ecc_profile)
        key = (not res.feasible,
               res.energy_w if objective == "energy" else res.cost_usd,
               res.cost_usd)
        if best is None or key < (not best.feasible,
                                  best.energy_w if objective == "energy" else best.cost_usd,
                                  best.cost_usd):
            best = res
    assert best is not None
    return best
