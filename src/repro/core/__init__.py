"""Managed-Retention Memory (MRM): the paper's contribution as a library.

- memclass:  memory-technology models incl. MRM operating points
- dcm:       per-write programmable retention (energy/endurance trade-off)
- tiering:   retention-aware placement of weights / KV / activations
- refresh:   cluster-level retention tracking + refresh/migrate/drop
- endurance: Fig.-1 arithmetic, wear accounting, software wear-levelling
- ecc:       retention-aware large-block error correction + the domain-
             specific (exponent-protected / mantissa-relaxed) reliability
             plane of DESIGN.md §11
- faults:    age-driven bit-flip injection over paged KV/state arrays
- simulator: instrumented device/system simulator driven by the serving engine
"""
from repro.core.memclass import (TECHNOLOGIES, MemTechnology, get_technology,
                                 HOUR, DAY, YEAR)
from repro.core.dcm import WriteOp, endurance_at, plan_write, write_energy
from repro.core.endurance import (WearLevelingAllocator, WearState,
                                  weight_update_writes, writes_per_cell)
from repro.core.ecc import (BlockCode, ECC_PROFILES, STATE_RETENTION_FRAC,
                            SplitCode, TierEcc, cell_cost_factor,
                            derated_rber_at_age, design_code,
                            design_split_code, margin_derate, max_safe_age,
                            rber_at_age, uncorrectable_log10)
from repro.core.faults import FaultInjector, FaultStats, flip_bits
from repro.core.tiering import (DataClassProfile, PlacementResult, Tier,
                                evaluate_placement, solve_placement)
from repro.core.refresh import (Action, RefreshScheduler, RetentionTracker,
                                ScheduledAction, TrackedRegion)
from repro.core.simulator import (IOStats, MemDevice, MemorySystem,
                                  data_class_of)
