"""Dynamically Configurable Memory (paper §4): per-write programmable
retention, with the retention <-> write-energy <-> endurance trade-off.

Model (anchored on the STT-MRAM thermal-stability relation and the RRAM
retention/endurance studies the paper cites [14, 18, 31, 41, 47]):

- retention is exponential in the thermal stability factor Delta
  (t_ret ~ tau0 * exp(Delta)), and write energy is roughly linear in Delta
  => write_energy(r) = e_nom * (1 + alpha * ln(r / r_nom))
- endurance degrades with write stress, which scales with Delta
  => endurance(r) = E_nom * (r_nom / r)^beta

alpha/beta are per-technology coefficients on :class:`MemTechnology`.
The control plane (refresh scheduler) chooses the retention target from the
data's expected lifetime, "right-provisioning the MRM to the workload".
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.core.memclass import MemTechnology

_TAU0 = 1e-9  # attempt time; ln(r/tau0) ~ Delta


@dataclass(frozen=True)
class WriteOp:
    """Cost/effect of one block write at a programmed retention."""
    retention_s: float
    energy_pj_bit: float
    latency_scale: float      # relative to nominal write latency
    endurance_at_point: float  # cell endurance when always written like this


def clamp_retention(tech: MemTechnology, retention_s: float) -> float:
    """Programmable range: 1 second .. the technology's nominal retention."""
    return max(1.0, min(retention_s, tech.retention_s))


def write_energy(tech: MemTechnology, retention_s: float) -> float:
    """pJ/bit to program a cell for the given retention target.

    energy ~ e_nom * (Delta(r)/Delta(r_nom))^(1+2*alpha): the stability
    ratio enters superlinearly because both pulse amplitude and duration
    shrink with the barrier (fit to the relaxed-retention STT-RAM numbers
    in [41]: ~3-4x write-energy reduction at seconds-scale retention).
    """
    r = clamp_retention(tech, retention_s)
    if tech.dcm_alpha <= 0:
        return tech.write_energy_pj_bit
    ratio = math.log(r / _TAU0) / math.log(tech.retention_s / _TAU0)
    return tech.write_energy_pj_bit * max(0.12, ratio ** (1.0 + 2.0 * tech.dcm_alpha))


def endurance_at(tech: MemTechnology, retention_s: float) -> float:
    """Cell endurance when writes are programmed at the given retention."""
    r = clamp_retention(tech, retention_s)
    if tech.dcm_beta <= 0:
        return tech.endurance_device
    gain = (tech.retention_s / r) ** tech.dcm_beta
    return min(tech.endurance_device * gain, tech.endurance_potential)


def plan_write(tech: MemTechnology, expected_lifetime_s: float,
               margin: float = 2.0) -> WriteOp:
    """The DCM policy: program retention = margin x expected lifetime.

    margin > 1 keeps an ECC/refresh safety window (see repro.core.ecc);
    the refresh scheduler treats retention/margin as the service deadline.
    """
    r = clamp_retention(tech, expected_lifetime_s * margin)
    e = write_energy(tech, r)
    return WriteOp(
        retention_s=r,
        energy_pj_bit=e,
        latency_scale=max(0.25, e / tech.write_energy_pj_bit),
        endurance_at_point=endurance_at(tech, r),
    )


def refresh_deadline(op: WriteOp, written_at_s: float, margin: float = 2.0) -> float:
    """Absolute time by which the block must be refreshed, migrated, or
    dropped (retention minus the safety window)."""
    return written_at_s + op.retention_s / margin
