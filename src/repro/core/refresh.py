"""Cluster-level retention tracking + refresh scheduling (paper §4:
"the scheduler will need to track the data expiration times, and decide
whether to refresh it or move it to another tier based on the state of the
requests that depend on that data").

The tracker is deterministic and simulation-time-driven (the serving engine
advances time); policies are pluggable. Actions:

- REFRESH: rewrite in place (costs a write + wear) — live data
- MIGRATE: move to a colder tier — idle-but-retained data (e.g. paused session)
- DROP:    let soft state expire — recompute on demand (KV is soft state)
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import dcm
from repro.core.memclass import MemTechnology


class Action(Enum):
    REFRESH = "refresh"
    MIGRATE = "migrate"
    DROP = "drop"


@dataclass
class TrackedRegion:
    region_id: int
    owner: str               # e.g. "weights", "session:42"
    tier: str
    n_blocks: int
    bytes: float
    written_at: float
    retention_s: float
    deadline: float
    live: bool = True
    idle_since: Optional[float] = None


@dataclass
class ScheduledAction:
    at: float
    action: Action
    region: TrackedRegion


class RetentionTracker:
    """Priority queue of retention deadlines over all tracked regions."""

    def __init__(self, margin: float = 2.0, idle_migrate_after_s: float = 300.0):
        self.margin = margin
        self.idle_migrate_after_s = idle_migrate_after_s
        self._regions: Dict[int, TrackedRegion] = {}
        self._heap: List[Tuple[float, int, int]] = []
        self._ids = itertools.count()
        self.stats = {"refresh": 0, "migrate": 0, "drop": 0,
                      "refresh_bytes": 0.0}

    def track(self, owner: str, tier: str, n_blocks: int, nbytes: float,
              now: float, retention_s: float) -> int:
        rid = next(self._ids)
        deadline = now + retention_s / self.margin
        region = TrackedRegion(rid, owner, tier, n_blocks, nbytes, now,
                               retention_s, deadline)
        self._regions[rid] = region
        heapq.heappush(self._heap, (deadline, rid, 0))
        return rid

    def touch(self, rid: int, now: float) -> None:
        """Mark a region as just-accessed (resets idleness)."""
        r = self._regions.get(rid)
        if r:
            r.idle_since = None

    def mark_idle(self, rid: int, now: float) -> None:
        r = self._regions.get(rid)
        if r and r.idle_since is None:
            r.idle_since = now

    def release(self, rid: int) -> Optional[TrackedRegion]:
        return self._regions.pop(rid, None)

    def get(self, rid: int) -> Optional[TrackedRegion]:
        """O(1) region lookup by id — the serving hot path (every KV page
        read) goes through this, never through `regions()`."""
        return self._regions.get(rid)

    def regions(self) -> List[TrackedRegion]:
        return list(self._regions.values())

    def due(self, now: float) -> List[TrackedRegion]:
        out = []
        while self._heap and self._heap[0][0] <= now:
            deadline, rid, gen = heapq.heappop(self._heap)
            r = self._regions.get(rid)
            if r is None or r.deadline != deadline:
                continue  # stale entry (released or re-armed)
            out.append(r)
        return out

    def rearm(self, r: TrackedRegion, now: float,
              retention_s: Optional[float] = None) -> None:
        r.written_at = now
        if retention_s is not None:
            r.retention_s = retention_s
        r.deadline = now + r.retention_s / self.margin
        heapq.heappush(self._heap, (r.deadline, r.region_id, 0))


PolicyFn = Callable[[TrackedRegion, float], Action]


def default_policy(tracker: RetentionTracker) -> PolicyFn:
    """Paper-default policy: refresh live data, migrate long-idle data,
    drop dead soft state (the engine releases dead regions eagerly, so DROP
    here is the backstop for orphaned state)."""
    def policy(region: TrackedRegion, now: float) -> Action:
        if not region.live:
            return Action.DROP
        if (region.idle_since is not None and
                now - region.idle_since > tracker.idle_migrate_after_s):
            return Action.MIGRATE
        return Action.REFRESH
    return policy


class RefreshScheduler:
    """Drives tracker deadlines into device refresh/migrate/drop work."""

    def __init__(self, tracker: RetentionTracker, policy: Optional[PolicyFn] = None):
        self.tracker = tracker
        self.policy = policy or default_policy(tracker)

    def tick(self, now: float) -> List[ScheduledAction]:
        """Process due regions; returns the actions taken (the memory
        simulator charges their cost)."""
        actions = []
        for region in self.tracker.due(now):
            act = self.policy(region, now)
            actions.append(ScheduledAction(at=now, action=act, region=region))
            if act == Action.REFRESH:
                self.tracker.stats["refresh"] += 1
                self.tracker.stats["refresh_bytes"] += region.bytes
                self.tracker.rearm(region, now)
            elif act == Action.MIGRATE:
                self.tracker.stats["migrate"] += 1
                self.tracker.release(region.region_id)
            else:
                self.tracker.stats["drop"] += 1
                self.tracker.release(region.region_id)
        return actions
