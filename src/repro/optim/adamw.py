"""Sharded AdamW with fp32 moments, global-norm clipping, and optional
ZeRO-1-style optimizer-state sharding over the data axis.

Parameters stay in ``cfg.param_dtype`` (bf16); moments and the update math
run in fp32. The optimizer state is a pytree congruent with params so the
sharding machinery (runtime/sharding.py) applies unchanged.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef, is_def


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def lr_at(oc: OptConfig, step):
    """Linear warmup + cosine decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    t = jnp.clip((step - oc.warmup_steps) / jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = oc.min_lr_frac + (1 - oc.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return oc.lr * warm * cos


def opt_state_defs(param_defs) -> dict:
    """ParamDef tree for the optimizer state (fp32 moments)."""
    def f32(d: ParamDef) -> ParamDef:
        return dataclasses.replace(d, dtype="float32", init="zeros")
    return {
        "m": jax.tree.map(f32, param_defs, is_leaf=is_def),
        "v": jax.tree.map(f32, param_defs, is_leaf=is_def),
        "step": ParamDef((), (), init="zeros", dtype="int32"),
    }


def init_opt_state(params) -> dict:
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(oc: OptConfig, params, grads, opt_state) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, stats)."""
    grads, gnorm = clip_by_global_norm(grads, oc.grad_clip)
    step = opt_state["step"] + 1
    lr = lr_at(oc, step)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_); new_m.append(nm); new_v.append(nv)
    new_params = jax.tree.unflatten(tdef, new_p)
    new_state = {"m": jax.tree.unflatten(tdef, new_m),
                 "v": jax.tree.unflatten(tdef, new_v),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
