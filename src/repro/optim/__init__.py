from repro.optim.adamw import (OptConfig, adamw_update, init_opt_state, lr_at,
                               opt_state_defs, clip_by_global_norm, global_norm)

__all__ = ["OptConfig", "adamw_update", "init_opt_state", "lr_at",
           "opt_state_defs", "clip_by_global_norm", "global_norm"]
