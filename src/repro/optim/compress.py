"""Gradient compression for cross-pod data parallelism, with error feedback.

At 512+ chips the inter-pod all-reduce of bf16 gradients dominates the
collective term; both compressors here cut wire bytes (int8: 2x vs bf16,
top-k: ~(1/k)x) while error feedback keeps convergence (residuals are fed
back into the next step — the standard EF-SGD construction).

Usage: wrap the gradient tree between value_and_grad and the optimizer:
  comp_state = init_state(grads_like)
  grads, comp_state = compress_decompress(grads, comp_state, scheme)
The compress->(simulated allreduce)->decompress round trip happens inside
one jit so XLA sees the int8/sparse representation crossing the DP axis.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def init_state(grads_like) -> Any:
    """Error-feedback residual buffers (fp32), congruent with grads."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def _int8_roundtrip(g):
    """Per-tensor scale symmetric int8 quantization."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_roundtrip(g, frac: float):
    g32 = g.astype(jnp.float32)
    flat = g32.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return kept.reshape(g32.shape)


def compress_decompress(grads, ef_state, scheme: str = "int8",
                        topk_frac: float = 0.05) -> Tuple[Any, Any]:
    """Returns (decompressed grads as seen post-allreduce, new EF state)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        if scheme == "int8":
            out = _int8_roundtrip(g32)
        elif scheme == "topk":
            out = _topk_roundtrip(g32, topk_frac)
        elif scheme == "none":
            out = g32
        else:
            raise ValueError(scheme)
        new_e = g32 - out
        return out.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    outs, errs = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    return jax.tree.unflatten(tdef, list(outs)), jax.tree.unflatten(tdef, list(errs))


def wire_bytes(grads, scheme: str = "int8", topk_frac: float = 0.05) -> float:
    """Bytes on the wire for one DP all-reduce of these grads."""
    total_elems = sum(g.size for g in jax.tree.leaves(grads))
    if scheme == "int8":
        return total_elems * 1.0
    if scheme == "topk":
        return total_elems * topk_frac * 8.0  # value + index
    return total_elems * 2.0  # bf16
