"""Logical-axis sharding rules with divisibility fallback.

Sharding is expressed per-tensor as logical axis names; the rules map a
logical name to mesh axes. A dim is only sharded when its size divides the
mapped mesh-axis product — otherwise the rule silently falls back to
replication for that dim (recorded via :func:`explain_specs` so the fallback
is auditable, see DESIGN.md §4).

This one mechanism is what lets a single model implementation shard a 76B
dense model, a 64-expert MoE, an MQA model whose 8 query heads do not divide
the 16-way model axis, and a batch-1 long-context decode, over the same
(16,16) / (2,16,16) production meshes.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.param import ParamDef, is_def

MeshAxes = Union[None, str, Tuple[str, ...]]

# Logical axis -> mesh axes. "model" is tensor/expert parallel; batch-like
# activation axes map to ("pod", "data") which collapses to just "data" on
# the single-pod mesh.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    # parameter axes
    "layers": None,
    "embed": None,             # d_model (kept replicated; residual stream)
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "experts": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "lora": None,
    "conv": None,
    "codebooks": None,
    # activation axes
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_seq_res": None,     # residual stream between blocks (SP variant: "model")
    "act_kv_seq": "model",     # flash-decoding style KV-seq sharding
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_ff": "model",
    "act_vocab": "model",
    "act_embed": None,
    "act_experts": "model",
}

# Variant rule-sets used by the perf hillclimb (EXPERIMENTS.md §Perf).
SEQUENCE_PARALLEL_RULES = dict(DEFAULT_RULES, act_seq_res="model")


def _axes_tuple(spec: MeshAxes) -> Tuple[str, ...]:
    if spec is None:
        return ()
    if isinstance(spec, str):
        return (spec,)
    return tuple(spec)


def mesh_axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    size = 1
    for a in axes:
        if a in mesh.shape:
            size *= mesh.shape[a]
    return size


def spec_for(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[Dict[str, MeshAxes]] = None,
) -> P:
    """Build a PartitionSpec, dropping mesh axes that don't divide the dim
    or that don't exist in this mesh, and never using a mesh axis twice."""
    rules = rules or DEFAULT_RULES
    used: set = set()
    out = []
    for name, dim in zip(logical_axes, shape):
        entry: MeshAxes = rules.get(name) if name else None
        axes = tuple(a for a in _axes_tuple(entry) if a in mesh.shape and a not in used)
        while axes and dim % mesh_axis_size(mesh, axes) != 0:
            axes = axes[:-1]  # drop trailing axes until divisible
        if axes:
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def sharding_for(logical_axes, shape, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(logical_axes, shape, mesh, rules))


def param_partition_specs(defs, mesh: Mesh, rules=None):
    """PartitionSpec tree for a ParamDef tree."""
    return jax.tree.map(
        lambda d: spec_for(d.logical_axes, d.shape, mesh, rules), defs, is_leaf=is_def)


def param_shardings(defs, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda d: sharding_for(d.logical_axes, d.shape, mesh, rules), defs, is_leaf=is_def)


def explain_specs(defs, mesh: Mesh, rules=None):
    """List (path, shape, logical_axes, spec, fallbacks) for auditing."""
    rules = rules or DEFAULT_RULES
    rows = []
    flat = jax.tree_util.tree_flatten_with_path(defs, is_leaf=is_def)[0]
    for path, d in flat:
        spec = spec_for(d.logical_axes, d.shape, mesh, rules)
        fallbacks = []
        for name, dim, got in zip(d.logical_axes, d.shape, spec):
            want = _axes_tuple(rules.get(name) if name else None)
            if want and got is None:
                fallbacks.append(f"{name}({dim})!~{'x'.join(want)}")
        rows.append((jax.tree_util.keystr(path), d.shape, d.logical_axes, spec, fallbacks))
    return rows


def constrain(x, logical_axes, mesh: Mesh, rules=None):
    """with_sharding_constraint via logical axes (no-op outside jit)."""
    s = sharding_for(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, s)


class ShardCtx:
    """Activation-sharding helper threaded through the model code.

    ``ShardCtx(None)`` (CPU smoke tests) makes every constraint a no-op, so
    the same model code runs unsharded on one device and SPMD on the
    production mesh.
    """

    def __init__(self, mesh: Optional[Mesh], rules: Optional[Dict[str, MeshAxes]] = None):
        self.mesh = mesh
        self.rules = dict(rules or DEFAULT_RULES)

    def c(self, x, logical_axes):
        if self.mesh is None:
            return x
        return constrain(x, logical_axes, self.mesh, self.rules)

    def kv_axes(self, cfg) -> Tuple[Optional[str], ...]:
        """KV-cache sharding policy: shard kv-heads over the model axis when
        divisible, else fall back to sharding the cache *sequence* dim
        (flash-decoding style; GSPMD inserts the softmax-stat reductions)."""
        if self.mesh is None:
            return ("act_batch", None, None, None)
        size = mesh_axis_size(self.mesh, _axes_tuple(self.rules.get("act_kv_heads")))
        if size > 1 and cfg.n_kv_heads % size == 0:
            return ("act_batch", None, "act_kv_heads", None)
        return ("act_batch", "act_kv_seq", None, None)

    def kv(self, cfg, cache: dict) -> dict:
        if self.mesh is None:
            return cache
        axes = self.kv_axes(cfg)
        out = dict(cache)
        for name in ("k", "v"):
            if name in out:
                out[name] = self.c(out[name], axes)
        if "pos" in out:
            out["pos"] = self.c(out["pos"], axes[:2])
        return out
