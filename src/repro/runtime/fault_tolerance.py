"""Fault tolerance for 1000+-node operation: failure detection, elastic
re-mesh planning, and straggler mitigation.

All components are deterministic and simulation-time-driven so they are unit-
testable on this CPU container; the same logic drives a real deployment with
wall-clock timestamps (heartbeats come from the per-host agent; re-mesh
plans feed the launcher which restarts the jit program on the new mesh and
restores the latest checkpoint with resharding — see repro.ckpt).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Failure detection
# ---------------------------------------------------------------------------


class HeartbeatMonitor:
    def __init__(self, workers: Sequence[str], timeout_s: float = 30.0):
        self.timeout = timeout_s
        self.last: Dict[str, float] = {w: 0.0 for w in workers}

    def beat(self, worker: str, now: float) -> None:
        self.last[worker] = now

    def failed(self, now: float) -> List[str]:
        return sorted(w for w, t in self.last.items() if now - t > self.timeout)

    def alive(self, now: float) -> List[str]:
        return sorted(w for w, t in self.last.items() if now - t <= self.timeout)


# ---------------------------------------------------------------------------
# Elastic re-mesh planning
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    n_chips: int
    dropped_chips: int
    notes: str = ""


def plan_mesh(available_chips: int, *, model_parallel: int = 16,
              chips_per_host: int = 4, multi_pod_threshold: int = 512) -> MeshPlan:
    """Largest usable (data, model) mesh from the surviving chips.

    Policy: keep the model axis fixed at the sharding-rule size (16) when
    possible (no resharding of the TP dimension => restore is a pure DP
    re-layout); shrink the data axis to the largest fit; drop the remainder
    (they become hot spares). Falls back to smaller model axes (8, 4, 2, 1)
    when fewer than one TP group survives.
    """
    for mp in [model_parallel, 8, 4, 2, 1]:
        if available_chips >= mp:
            data = available_chips // mp
            used = data * mp
            if used >= multi_pod_threshold and data % 2 == 0:
                return MeshPlan((2, data // 2, mp), ("pod", "data", "model"),
                                used, available_chips - used,
                                f"multi-pod: model axis {mp}")
            return MeshPlan((data, mp), ("data", "model"), used,
                            available_chips - used, f"model axis {mp}")
    return MeshPlan((1, 1), ("data", "model"), 1, available_chips - 1,
                    "degenerate single chip")


def resharding_moves(old: MeshPlan, new: MeshPlan,
                     param_bytes: float) -> dict:
    """Estimate the data movement for an elastic transition. With the model
    axis preserved, each surviving chip keeps its TP shard and only the
    optimizer-state DP partitioning changes; otherwise all params reload
    from the checkpoint."""
    old_mp = old.shape[-1]
    new_mp = new.shape[-1]
    if old_mp == new_mp:
        return {"kind": "dp_relayout", "bytes_moved": 0.0,
                "ckpt_reload": False}
    return {"kind": "tp_reshard", "bytes_moved": param_bytes,
            "ckpt_reload": True}


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------


@dataclass
class StragglerPolicy:
    ewma_alpha: float = 0.2
    slow_factor: float = 1.8      # flag if > factor x median
    strikes_to_evict: int = 3


class StragglerMitigator:
    """Per-worker EWMA step times; flags persistent stragglers for eviction
    (at which point the elastic planner produces a new mesh without them)."""

    def __init__(self, workers: Sequence[str],
                 policy: Optional[StragglerPolicy] = None):
        self.policy = policy or StragglerPolicy()
        self.ewma: Dict[str, float] = {w: 0.0 for w in workers}
        self.strikes: Dict[str, int] = {w: 0 for w in workers}

    def record_step(self, times: Dict[str, float]) -> List[str]:
        """Record one step's per-worker durations; returns workers to evict."""
        a = self.policy.ewma_alpha
        for w, t in times.items():
            self.ewma[w] = t if self.ewma[w] == 0.0 else (1 - a) * self.ewma[w] + a * t
        vals = sorted(self.ewma[w] for w in self.ewma if self.ewma[w] > 0)
        if not vals:
            return []
        median = vals[len(vals) // 2]
        evict = []
        for w, e in self.ewma.items():
            if e > self.policy.slow_factor * median:
                self.strikes[w] += 1
                if self.strikes[w] >= self.policy.strikes_to_evict:
                    evict.append(w)
            else:
                self.strikes[w] = 0
        return sorted(evict)


# ---------------------------------------------------------------------------
# Orchestration state machine (drives train.py's recovery loop)
# ---------------------------------------------------------------------------


@dataclass
class ClusterState:
    workers: List[str]
    chips_per_worker: int
    monitor: HeartbeatMonitor = field(init=False)
    stragglers: StragglerMitigator = field(init=False)
    evicted: List[str] = field(default_factory=list)
    _last_healthy: int = field(init=False, default=-1)

    def __post_init__(self):
        self.monitor = HeartbeatMonitor(self.workers)
        self.stragglers = StragglerMitigator(self.workers)
        self._last_healthy = len(self.workers)

    def healthy_workers(self, now: float) -> List[str]:
        failed = set(self.monitor.failed(now)) | set(self.evicted)
        return [w for w in self.workers if w not in failed]

    def current_plan(self, now: float, **kw) -> MeshPlan:
        return plan_mesh(len(self.healthy_workers(now)) * self.chips_per_worker,
                         **kw)

    def handle_step(self, now: float, step_times: Dict[str, float]) -> Optional[MeshPlan]:
        """Returns a new MeshPlan when the cluster shape changed since the
        last step (heartbeat failures, external evictions, or stragglers)."""
        for w in self.stragglers.record_step(step_times):
            if w not in self.evicted:
                self.evicted.append(w)
        healthy = len(self.healthy_workers(now))
        if healthy != self._last_healthy:
            self._last_healthy = healthy
            return self.current_plan(now)
        return None
