from repro.serving.cluster import ClusterFrontend
from repro.serving.engine import (ComputeBackend, EngineConfig, MemoryPlane,
                                  PrefillChunk, ServeEngine, StepPlan,
                                  StepReport)
from repro.serving.kv_cache import PagedKVManager, PressureStats
from repro.serving.scheduler import ContinuousBatchScheduler, Request

__all__ = ["EngineConfig", "ServeEngine", "ComputeBackend", "MemoryPlane",
           "StepPlan", "StepReport", "PrefillChunk", "PagedKVManager",
           "PressureStats", "ContinuousBatchScheduler", "Request",
           "ClusterFrontend"]
