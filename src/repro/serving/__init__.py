from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.kv_cache import PagedKVManager
from repro.serving.scheduler import ContinuousBatchScheduler, Request

__all__ = ["EngineConfig", "ServeEngine", "PagedKVManager",
           "ContinuousBatchScheduler", "Request"]
