from repro.serving.cluster import ClusterFrontend, PrefixDirectory
from repro.serving.engine import (ComputeBackend, EngineConfig, MemoryPlane,
                                  PrefillChunk, ServeEngine, SnapshotHandle,
                                  StepPlan, StepReport, choose_hot_tier,
                                  latency_percentiles)
from repro.serving.directory import DirectoryShard, ShardedDirectory
from repro.serving.events import (Event, EventKind, EventQueue, EventTrace,
                                  NonQuiescentError)
from repro.serving.fabric import Fabric
from repro.serving.fleet_sim import (FleetConfig, FleetRequest, FleetSim,
                                     latency_slo)
from repro.serving.kv_cache import PagedKVManager, PressureStats, RadixStats
from repro.serving.radix import PrefixMatch, RadixKVIndex, RadixNode
from repro.serving.retention_lifecycle import LifecycleStats, RetentionLifecycle
from repro.serving.scheduler import ContinuousBatchScheduler, Request

__all__ = ["EngineConfig", "ServeEngine", "ComputeBackend", "MemoryPlane",
           "StepPlan", "StepReport", "PrefillChunk", "PagedKVManager",
           "PressureStats", "RadixStats", "LifecycleStats",
           "RetentionLifecycle", "ContinuousBatchScheduler",
           "Request", "ClusterFrontend", "PrefixDirectory", "RadixKVIndex",
           "RadixNode", "PrefixMatch", "SnapshotHandle", "choose_hot_tier",
           "latency_percentiles", "Event", "EventKind", "EventQueue",
           "EventTrace", "NonQuiescentError", "FleetConfig", "FleetRequest",
           "FleetSim", "latency_slo", "Fabric", "ShardedDirectory",
           "DirectoryShard"]
