"""One retention state machine for every KV-prefix lifecycle transition.

The paper's §4 argument is that the *system* — not the device — should
program retention from what it observes about the data. Before this
module, the transitions were scattered: promotion lived in
``PagedKVManager._maybe_promote``, cold decay in ``maintain``, and the
cross-replica arrival programming inline in ``adopt_prefix`` — so DCM
reprogram traffic was metered in three places and the rules could not be
tested in isolation. Every retention transition now routes through
:class:`RetentionLifecycle` (DESIGN.md §9), shared by ``kv_cache.py``,
``radix.py`` callers, ``engine.py`` and the migration arrival path in
``cluster.py``.

State machine (per radix node; ``node.hot`` is the state bit)::

            observe_reuse (hits >= hot_threshold)
    SHORT ------------------------------------------> HOT
      ^                                                |
      |   demote (eviction pressure, unlocked only)    |
      +------------------------------------------------+
      |
      | decay_due (idle > cold_ttl_s)      spill/evict
      +----------------------------------> gone (soft state; recompute)

- **SHORT** — pages programmed at the session's expected lifetime.
- **HOT** — observed reuse crossed ``hot_threshold``: pages re-programmed
  to ``hot_retention_s`` (a DCM retention change is a block rewrite,
  metered as refresh traffic) and, when a hot tier is configured,
  migrated there.
- **demote** — new with this module: under sustained eviction pressure a
  hot node is demoted back to short retention *before* leaf eviction
  reaches it — the reprogram is metered, the hits reset (the node must
  re-earn promotion), and only then does it become an ordinary eviction
  candidate. Pinned (locked) nodes are never demoted: a live session's
  path is not reprogrammable out from under it.
- **decay** — unlocked leaves idle past ``cold_ttl_s`` are spilled to the
  colder tier when one is configured, else dropped (an identical future
  prompt recomputes).
- **arrival** — a cross-replica migration re-programs retention on the
  receiving replica: donor-hot prefixes land in the hot tier at
  ``hot_retention_s``, cold ones at session retention.

The lifecycle owns page *retention and placement*; tree structure
(insert/evict/pin) stays with ``RadixKVIndex`` and page/refcount
lifetime with ``PagedKVManager``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.core.simulator import MemorySystem


@dataclass
class LifecycleStats:
    """Ledger of every retention transition (one metering point)."""
    retention_promotions: int = 0  # nodes SHORT -> HOT
    promoted_pages: int = 0        # pages re-programmed in place
    migrated_pages: int = 0        # pages moved into the hot tier
    retention_demotions: int = 0   # nodes HOT -> SHORT under pressure
    demoted_pages: int = 0         # pages re-programmed back down
    cold_decays: int = 0           # cold leaves dropped after cold_ttl_s
    cold_spilled_pages: int = 0    # cold pages demoted to the spill tier
    adopted_pages: int = 0         # pages grafted from another replica
    adopted_tokens: int = 0        # tokens those pages cover
    arrivals_hot: int = 0          # migrations programmed hot on arrival
    arrivals_short: int = 0        # migrations programmed at session life
    scrubbed_pages: int = 0        # scrub-on-read corrections (DESIGN.md §11)

    def as_dict(self) -> dict:
        return {
            "retention_promotions": self.retention_promotions,
            "promoted_pages": self.promoted_pages,
            "migrated_pages": self.migrated_pages,
            "retention_demotions": self.retention_demotions,
            "demoted_pages": self.demoted_pages,
            "cold_decays": self.cold_decays,
            "cold_spilled_pages": self.cold_spilled_pages,
            "adopted_pages": self.adopted_pages,
            "adopted_tokens": self.adopted_tokens,
            "arrivals_hot": self.arrivals_hot,
            "arrivals_short": self.arrivals_short,
            "scrubbed_pages": self.scrubbed_pages,
        }


class RetentionLifecycle:
    """Promote / demote / decay / arrival programming for prefix KV.

    Invariants the tests rely on:

    - **Single metering point** — every DCM retention reprogram (promote,
      demote, arrival) goes through this class, so refresh-traffic
      accounting cannot diverge between call sites.
    - **Pinned paths are never demoted** — :meth:`demote` refuses nodes
      with ``lock_ref > 0``; a live session's retention cannot be
      shortened out from under it.
    - **Demote precedes eviction** — with ``demote_on_pressure``, the
      manager's eviction loop offers every hot leaf to :meth:`demote`
      before it may be popped; a hot node therefore always passes
      through SHORT (reprogram metered) before leaving the tree under
      pressure.
    - **Hits reset on demotion** — a demoted node must re-cross
      ``hot_threshold`` to be promoted again (no promote/demote
      flapping from a single stale hit count).
    """

    def __init__(self, mem: MemorySystem, *, tier: str,
                 kv_bytes_token: float,
                 session_retention_s: float,
                 hot_retention_s: float,
                 hot_threshold: int,
                 hot_tier: Optional[str] = None,
                 cold_ttl_s: Optional[float] = None,
                 spill_tier: Optional[str] = None,
                 demote_on_pressure: bool = False):
        self.mem = mem
        self.tier = tier
        self.kv_bytes_token = kv_bytes_token
        self.session_retention_s = session_retention_s
        self.hot_retention_s = hot_retention_s
        self.hot_threshold = hot_threshold
        self.hot_tier = hot_tier
        self.cold_ttl_s = cold_ttl_s
        self.spill_tier = spill_tier
        self.demote_on_pressure = demote_on_pressure
        self.stats = LifecycleStats()

    # -- the one metered reprogram primitive ---------------------------
    def _reprogram(self, page, retention_s: float) -> bool:
        """Re-program a page region's DCM retention in place. A retention
        change is a block rewrite — metered as reprogram/refresh traffic,
        not steady writes (paper §4)."""
        if page.region_id is None:
            return False
        r = self.mem.tracker.get(page.region_id)
        if r is None:
            return False
        nbytes = page.n_tokens * self.kv_bytes_token
        op = self.mem.devices[page.tier].write(
            nbytes, expected_lifetime_s=retention_s, refresh=True)
        self.mem.tracker.rearm(r, self.mem.now, retention_s=op.retention_s)
        return True

    # -- scrub-on-read (reliability plane, DESIGN.md §11) ---------------
    def scrub(self, page) -> bool:
        """Correct a page whose age-driven raw error count crossed the
        scrub threshold. Invariant (scrub-charged-as-refresh): the
        corrective rewrite is metered exactly like a scheduled refresh —
        refresh bytes + check bits + in-place wear — and the retention
        clock re-arms, so scrub and refresh traffic share one budget and
        a scrubbed page skips its next refresh deadline. This is the
        *only* entry point for scrub metering in the serving layer, same
        single-metering-point rule as :meth:`_reprogram`."""
        if page.region_id is None:
            return False
        if self.mem.scrub_region(page.region_id):
            self.stats.scrubbed_pages += 1
            return True
        return False

    # -- SHORT -> HOT ---------------------------------------------------
    def observe_reuse(self, node) -> None:
        """Walk the matched path; promote nodes whose observed hit count
        crossed ``hot_threshold`` (reuse -> retention programming)."""
        while node is not None and node.parent is not None:
            if not node.hot and node.hits >= self.hot_threshold:
                self.promote(node)
            node = node.parent

    def promote(self, node) -> None:
        """SHORT -> HOT: long-retention DCM programming for every page,
        and placement in the hot tier when one is configured."""
        node.hot = True
        self.stats.retention_promotions += 1
        for page in node.pages:
            self._promote_page(page)

    def _promote_page(self, page) -> None:
        if page.region_id is None:
            return
        nbytes = page.n_tokens * self.kv_bytes_token
        if self.hot_tier and page.tier != self.hot_tier:
            rid = self.mem.write_region(self.hot_tier, "prefix:hot", nbytes,
                                        expected_lifetime_s=self.hot_retention_s)
            if rid is not None:
                self.mem.read_region(page.region_id, nbytes)  # migration read
                self.mem.release_region(page.region_id)
                page.region_id = rid
                page.tier = self.hot_tier
                self.stats.migrated_pages += 1
                return
        if self._reprogram(page, self.hot_retention_s):
            self.stats.promoted_pages += 1

    # -- HOT -> SHORT (pressure) ----------------------------------------
    def demote(self, node) -> bool:
        """HOT -> SHORT under eviction pressure: re-program the node's
        pages back to session retention (metered) and reset its hit count
        so promotion must be re-earned. Refuses pinned (locked) nodes and
        nodes that are not hot; pages stay in their current tier —
        migrating them back to the base tier would consume exactly the
        capacity the pressure is trying to free (they follow on natural
        churn). Returns True when the node was demoted."""
        if not self.demote_on_pressure or not node.hot or node.lock_ref > 0:
            return False
        node.hot = False
        node.hits = 0
        self.stats.retention_demotions += 1
        for page in node.pages:
            if self._reprogram(page, self.session_retention_s):
                self.stats.demoted_pages += 1
        return True

    # -- SHORT -> gone (cold decay) -------------------------------------
    def decay_due(self, node, now: float) -> bool:
        """An unlocked leaf idle past ``cold_ttl_s`` should decay."""
        if self.cold_ttl_s is None:
            return False
        return now - node.last_access > self.cold_ttl_s

    def decay_deadline(self, node) -> Optional[float]:
        """Wall-clock instant this node becomes decay-due — the
        event-driven clock schedules a RETENTION_DECAY event here instead
        of polling :meth:`decay_due` every step (DESIGN.md §12)."""
        if self.cold_ttl_s is None:
            return None
        return node.last_access + self.cold_ttl_s

    def spill_cold(self, node, now: float) -> int:
        """Cold demotion to the spill tier: move every page that is not
        already there (migration read + colder write, session retention).
        Returns pages moved; stamps the node so it does not re-trigger
        next step."""
        moved = 0
        for page in node.pages:
            if page.region_id is None or page.tier == self.spill_tier:
                continue
            nbytes = page.n_tokens * self.kv_bytes_token
            rid = self.mem.write_region(
                self.spill_tier, "prefix:cold", nbytes,
                expected_lifetime_s=self.session_retention_s)
            if rid is None:
                continue
            self.mem.read_region(page.region_id, nbytes)  # migration read
            self.mem.release_region(page.region_id)
            page.region_id = rid
            page.tier = self.spill_tier
            moved += 1
        if moved:
            self.stats.cold_spilled_pages += moved
            node.last_access = now
        return moved

    def note_decay(self) -> None:
        self.stats.cold_decays += 1

    # -- cross-replica arrival ------------------------------------------
    def arrival(self, hot: bool) -> Tuple[str, float]:
        """Retention re-programmed on migration arrival (DESIGN.md §7):
        donor-hot prefixes land in the hot tier at ``hot_retention_s``,
        cold ones in the base tier at session retention. Returns
        ``(tier, retention_s)`` for the receiver's page allocations."""
        if hot:
            self.stats.arrivals_hot += 1
            return (self.hot_tier or self.tier), self.hot_retention_s
        self.stats.arrivals_short += 1
        return self.tier, self.session_retention_s

    def note_adoption(self, pages: int, tokens: int) -> None:
        self.stats.adopted_pages += pages
        self.stats.adopted_tokens += tokens
