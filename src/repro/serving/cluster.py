"""Multi-replica cluster frontend over per-replica MRM control planes.

The paper's deployment unit is a fleet: many accelerators, each with its
own MRM stack, serving a shared request population (§2.2 "millions of
users"). :class:`ClusterFrontend` fans requests across N
:class:`~repro.serving.engine.ServeEngine` replicas:

- **radix-affinity routing** — a request is routed to the replica whose
  radix prefix tree already holds the longest page-aligned prefix of its
  prompt (so the hit is real: shared pages attach, prefill compute is
  skipped). This replaces whole-key sha1 hashing — a prompt that shares a
  system prompt or conversation history finds the replica that served it,
  whatever its session key;
- **session-affinity fallback** — requests carrying a ``session_key`` with
  no radix match anywhere go to their sticky replica (first pick recorded),
  so a user's *first* follow-up still lands where their prefix will be;
- **least-loaded routing** — keyless, matchless requests go to the replica
  with the fewest queued+resident requests; ties break on KV capacity
  pressure (live KV bytes vs the KV tier's capacity), so a replica with a
  saturated KV tier no longer wins ties on queue length alone;
- **shared simulated clock** — replicas execute a step in parallel; a
  cluster round lasts as long as the slowest replica, and lagging replicas
  advance to the fleet clock (servicing their refresh deadlines while
  "waiting");
- **aggregated fleet report** — tokens, per-tier bytes, energy,
  capacity-pressure resolutions, prefix-reuse counters and pooled TTFT/ITL
  percentiles summed across replicas, with the per-replica breakdown
  attached (conservation is testable).
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Tuple

from repro.serving.engine import ServeEngine, latency_percentiles


class ClusterFrontend:
    def __init__(self, engines: List[ServeEngine]):
        if not engines:
            raise ValueError("ClusterFrontend needs at least one replica")
        self.engines = list(engines)
        self.routes: Dict[str, int] = {}          # session_key -> replica
        self.requests: Dict[int, Tuple[int, int]] = {}  # rid -> (replica, local)
        self._next_rid = 0
        self.steps = 0
        self.radix_routed = 0      # requests placed by prefix affinity

    # ------------------------------------------------------------------
    @property
    def now(self) -> float:
        return max(e.mem.now for e in self.engines)

    @property
    def idle(self) -> bool:
        return all(e.sched.idle for e in self.engines)

    def _load_key(self, i: int) -> tuple:
        """Replica load for routing: queue+resident first, then KV capacity
        pressure (live KV bytes vs the KV tier's capacity) so a saturated
        KV tier loses ties, then index for determinism."""
        e = self.engines[i]
        load = len(e.sched.queue) + len(e.sched.active)
        cap = e.mem.devices[e.ecfg.kv_tier].capacity
        kv_pressure = e.kv.live_kv_bytes() / max(cap, 1.0)
        return (load, round(kv_pressure, 9), i)

    def route(self, session_key: Optional[str] = None,
              prompt_tokens: Optional[list] = None) -> int:
        # 1) radix-match-length affinity: the replica already holding the
        #    longest prefix of this prompt wins (load breaks ties)
        if prompt_tokens is not None:
            matches = [e.prefix_match_len(prompt_tokens) for e in self.engines]
            best = max(matches)
            if best > 0:
                i = min((i for i, m in enumerate(matches) if m == best),
                        key=self._load_key)
                self.radix_routed += 1
                if session_key is not None:
                    self.routes[str(session_key)] = i
                return i
        # 2) sticky session fallback (the user's first follow-up lands
        #    where their prefix will be, before the tree has seen it)
        if session_key is not None:
            key = str(session_key)
            if key not in self.routes:
                h = int(hashlib.sha1(key.encode()).hexdigest(), 16)
                self.routes[key] = h % len(self.engines)
            return self.routes[key]
        # 3) least-loaded (KV-pressure-aware)
        return min(range(len(self.engines)), key=self._load_key)

    def submit(self, prompt_tokens: list, max_new_tokens: int,
               session_key: Optional[str] = None) -> int:
        """Route and enqueue a request; returns a cluster-wide request id."""
        replica = self.route(session_key, prompt_tokens)
        local = self.engines[replica].submit(prompt_tokens, max_new_tokens)
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = (replica, local)
        return rid

    def output(self, rid: int) -> list:
        replica, local = self.requests[rid]
        return self.engines[replica].outputs[local]

    def replica_of(self, rid: int) -> int:
        return self.requests[rid][0]

    # ------------------------------------------------------------------
    def step(self) -> dict:
        """One cluster round: every busy replica runs an engine step in
        parallel; the fleet clock advances to the slowest replica."""
        busy = [e for e in self.engines if not e.sched.idle]
        for e in busy:
            e.step()
        now = self.now
        for e in self.engines:
            if e.mem.now < now:
                e.mem.advance(now - e.mem.now)
        self.steps += 1
        return {"now_s": now, "busy_replicas": len(busy)}

    def run_until_idle(self, max_steps: int = 10000) -> dict:
        while not self.idle and self.steps < max_steps:
            self.step()
        return self.report()

    # ------------------------------------------------------------------
    def report(self) -> dict:
        reps = [e.report() for e in self.engines]
        tokens = sum(r["tokens_generated"] for r in reps)
        energy = sum(r["memory"]["total_energy_j"] for r in reps)
        tiers: Dict[str, dict] = {}
        for r in reps:
            for name, t in r["memory"]["tiers"].items():
                agg = tiers.setdefault(name, {"capacity_gb": 0.0,
                                              "read_gb": 0.0, "write_gb": 0.0,
                                              "refresh_gb": 0.0,
                                              "energy_j": 0.0})
                for k in agg:
                    agg[k] += t[k]
        pressure: Dict[str, int] = {}
        for r in reps:
            for k, v in r["pressure"].items():
                pressure[k] = pressure.get(k, 0) + v
        records = [rec for e in self.engines for rec in e.sched.latency]
        return {
            "replicas": len(self.engines),
            "cluster_steps": self.steps,
            "sim_time_s": self.now,
            "finished": sum(r["finished"] for r in reps),
            "tokens_generated": tokens,
            "fleet_tokens_per_s": tokens / max(self.now, 1e-9),
            "energy_per_token_j": energy / max(tokens, 1),
            "tiers": tiers,
            "pressure": pressure,
            "dropped_allocs": sum(r["dropped_allocs"] for r in reps),
            "prefix_hits": sum(r["prefix_hits"] for r in reps),
            "prefix_tokens_reused": sum(r["prefix_tokens_reused"] for r in reps),
            "prefill_tokens_computed": sum(r["prefill_tokens_computed"]
                                           for r in reps),
            "prefill_tokens_skipped": sum(r["prefill_tokens_skipped"]
                                          for r in reps),
            "radix_routed": self.radix_routed,
            "latency": latency_percentiles(records),
            "per_replica": reps,
        }
