"""Multi-replica cluster frontend over per-replica MRM control planes.

The paper's deployment unit is a fleet: many accelerators, each with its
own MRM stack, serving a shared request population (§2.2 "millions of
users"). :class:`ClusterFrontend` fans requests across N
:class:`~repro.serving.engine.ServeEngine` replicas:

- **session-affinity routing** — requests carrying a ``session_key`` hash
  to a sticky replica, so a user's repeated prompts hit the same replica's
  prefix index (shared-prefix KV reuse is per-replica state);
- **least-loaded routing** — keyless requests go to the replica with the
  fewest queued+resident requests;
- **shared simulated clock** — replicas execute a step in parallel; a
  cluster round lasts as long as the slowest replica, and lagging replicas
  advance to the fleet clock (servicing their refresh deadlines while
  "waiting");
- **aggregated fleet report** — tokens, per-tier bytes, energy and
  capacity-pressure resolutions summed across replicas, with the
  per-replica breakdown attached (conservation is testable).
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Tuple

from repro.serving.engine import ServeEngine


class ClusterFrontend:
    def __init__(self, engines: List[ServeEngine]):
        if not engines:
            raise ValueError("ClusterFrontend needs at least one replica")
        self.engines = list(engines)
        self.routes: Dict[str, int] = {}          # session_key -> replica
        self.requests: Dict[int, Tuple[int, int]] = {}  # rid -> (replica, local)
        self._next_rid = 0
        self.steps = 0

    # ------------------------------------------------------------------
    @property
    def now(self) -> float:
        return max(e.mem.now for e in self.engines)

    @property
    def idle(self) -> bool:
        return all(e.sched.idle for e in self.engines)

    def route(self, session_key: Optional[str] = None) -> int:
        if session_key is not None:
            key = str(session_key)
            if key not in self.routes:
                h = int(hashlib.sha1(key.encode()).hexdigest(), 16)
                self.routes[key] = h % len(self.engines)
            return self.routes[key]
        return min(range(len(self.engines)),
                   key=lambda i: (len(self.engines[i].sched.queue) +
                                  len(self.engines[i].sched.active), i))

    def submit(self, prompt_tokens: list, max_new_tokens: int,
               session_key: Optional[str] = None) -> int:
        """Route and enqueue a request; returns a cluster-wide request id."""
        replica = self.route(session_key)
        local = self.engines[replica].submit(prompt_tokens, max_new_tokens)
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = (replica, local)
        return rid

    def output(self, rid: int) -> list:
        replica, local = self.requests[rid]
        return self.engines[replica].outputs[local]

    def replica_of(self, rid: int) -> int:
        return self.requests[rid][0]

    # ------------------------------------------------------------------
    def step(self) -> dict:
        """One cluster round: every busy replica runs an engine step in
        parallel; the fleet clock advances to the slowest replica."""
        busy = [e for e in self.engines if not e.sched.idle]
        for e in busy:
            e.step()
        now = self.now
        for e in self.engines:
            if e.mem.now < now:
                e.mem.advance(now - e.mem.now)
        self.steps += 1
        return {"now_s": now, "busy_replicas": len(busy)}

    def run_until_idle(self, max_steps: int = 10000) -> dict:
        while not self.idle and self.steps < max_steps:
            self.step()
        return self.report()

    # ------------------------------------------------------------------
    def report(self) -> dict:
        reps = [e.report() for e in self.engines]
        tokens = sum(r["tokens_generated"] for r in reps)
        energy = sum(r["memory"]["total_energy_j"] for r in reps)
        tiers: Dict[str, dict] = {}
        for r in reps:
            for name, t in r["memory"]["tiers"].items():
                agg = tiers.setdefault(name, {"capacity_gb": 0.0,
                                              "read_gb": 0.0, "write_gb": 0.0,
                                              "refresh_gb": 0.0,
                                              "energy_j": 0.0})
                for k in agg:
                    agg[k] += t[k]
        pressure: Dict[str, int] = {}
        for r in reps:
            for k, v in r["pressure"].items():
                pressure[k] = pressure.get(k, 0) + v
        return {
            "replicas": len(self.engines),
            "cluster_steps": self.steps,
            "sim_time_s": self.now,
            "finished": sum(r["finished"] for r in reps),
            "tokens_generated": tokens,
            "fleet_tokens_per_s": tokens / max(self.now, 1e-9),
            "energy_per_token_j": energy / max(tokens, 1),
            "tiers": tiers,
            "pressure": pressure,
            "dropped_allocs": sum(r["dropped_allocs"] for r in reps),
            "prefix_hits": sum(r["prefix_hits"] for r in reps),
            "per_replica": reps,
        }
