"""Multi-replica cluster frontend over per-replica MRM control planes,
with a fleet-level prefix directory and cross-replica KV migration.

The paper's deployment unit is a fleet: many accelerators, each with its
own MRM stack, serving a shared request population (§2.2 "millions of
users"). PR 2 made prefix reuse real *inside* one replica; this module
turns the per-replica radix trees into one coherent fleet memory plane
(DESIGN.md §7): KV state is read-dominated and rewrite-tolerant, so
*moving* a hot prefix's pages between replicas is cheap relative to
recomputing them cold.

- **PrefixDirectory** — a fleet-level map from page-aligned prefix keys
  (position-space token tuples) to the replicas whose radix trees hold
  them. Ownership is registered when a replica publishes a path
  (``register_prefix`` / ``adopt_prefix``) and invalidated when a leaf
  leaves a tree (pressure eviction, watermark, cold decay) — the evicted
  run's prefixes are dropped, ancestor prefixes stay owned.
- **route-first, migrate-on-miss** — :meth:`ClusterFrontend.route`
  consults the directory: the least-loaded owner of the longest
  registered prefix wins while it has headroom; when every owner is
  overloaded (load gap above ``migrate_load_gap`` vs the least-loaded
  replica) the donor's pages and compute snapshot are *pulled* into the
  target replica as a metered inter-replica transfer — bytes charged at
  ``interconnect_gbps`` into the simulated clock, page writes metered
  against the receiving tiers, retention re-programmed on arrival through
  the one lifecycle state machine (DESIGN.md §9: a donor-hot prefix lands
  in the receiver's hot tier at long retention).
- **shared-fabric admission control** (DESIGN.md §13) — transfers run
  over a :class:`~repro.serving.fabric.Fabric` topology: every replica
  has one full-duplex NIC (up + down link) and the switch core carries a
  bisection-bandwidth cap, so concurrent migrations and replications
  contend realistically (two exports from one donor serialize on its
  up-link even to distinct receivers). A transfer finding any resource
  busy queues (the wait is reported in the fleet report's
  ``interconnect`` section) and the triggering request's TTFT pays queue
  wait + transfer time.
- **predictive replication** (DESIGN.md §13) — the directory counts
  fleet-wide hits per entry; crossing ``replicate_threshold`` pushes the
  prefix to the ``replicate_copies`` least-loaded non-owners *before*
  the fan-out burst lands, as low-priority ``REPLICATION_PUSH`` events
  that yield (re-defer) whenever the fabric is carrying demand traffic.
- **session-affinity fallback** — requests carrying a ``session_key``
  with no directory match go to their sticky replica;
- **least-loaded routing** — keyless, matchless requests go to the
  replica with the fewest queued+resident requests; ties break on the KV
  tier's physical occupancy (live session pages, directory-owned
  radix-resident prefixes and metered snapshots all count, so a replica
  stuffed with pinned shared prefixes is not treated as empty);
- **two clock disciplines** (DESIGN.md §12) — ``clock_mode="lockstep"``
  is the PR 3–8 compatibility driver: a cluster round lasts as long as
  the slowest replica and lagging replicas advance to the fleet clock
  (every existing sweep reproduces bit-for-bit). ``clock_mode="event"``
  runs the same replicas on the typed-event core of
  :mod:`repro.serving.events`: each replica's steps are events on its
  *own* clock, arrivals are timestamped events, migrations deliver at
  the link's free time (the triggering request admits only after
  delivery), and replicas synchronize solely through the directory, the
  links and the fleet event queue;
- **aggregated fleet report** — tokens, per-tier bytes, energy, pressure
  resolutions, prefix-reuse and interconnect counters, pooled TTFT/ITL
  percentiles, with the per-replica breakdown attached (conservation is
  testable), plus a ``quiesced`` flag and the event-trace digest.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.serving.directory import ShardedDirectory
from repro.serving.engine import ServeEngine, latency_percentiles
from repro.serving.events import (Event, EventKind, EventQueue, EventTrace,
                                  NonQuiescentError)
from repro.serving.fabric import Fabric
from repro.serving.radix import _flat


class PrefixDirectory:
    """Fleet-level map: page-aligned prefix -> owning replicas, stored as
    fixed-width sha1 *digests* hash-partitioned over
    :class:`~repro.serving.directory.DirectoryShard`s (DESIGN.md §13).

    Keys are position-space: each page's digest chains the hash state of
    every page before it (sentinel meta prefix + prompt tokens, exactly
    the radix tree's path), so a lookup agrees with what
    ``RadixKVIndex.match_len`` would find on the owner while storing 20
    bytes per entry instead of the full token tuple. Every page-aligned
    prefix of a registered path gets an entry (idempotent), which makes
    invalidation exact: an evicted leaf drops ownership of precisely the
    run it covered, as O(changed pages) shard ops.

    Hook traffic (register on publish, invalidate on evict/decay) queues
    into a pending delta and is applied as one batch at the next read
    (``flush``) — an eviction sweep's invalidations land as a single
    O(changes) delta instead of interleaved point updates. Reads
    (lookup / owned_by / n_entries) always flush first, so callers never
    observe stale ownership."""

    def __init__(self, page_tokens: int, n_shards: int = 8):
        if page_tokens < 1:
            raise ValueError("page_tokens must be >= 1")
        self.page_tokens = page_tokens
        self.shards = ShardedDirectory(n_shards)
        self.registrations = 0
        self.invalidations = 0
        self._delta: List[Tuple[str, bytes, int]] = []

    @staticmethod
    def _key(tokens: Sequence) -> list:
        return _flat(tokens)

    def _digests(self, flat: list) -> List[bytes]:
        """sha1 digest per page-aligned prefix, one incremental pass: the
        hash state carries across page boundaries, so digesting all n
        prefixes of an n-page path is O(path), not O(path^2)."""
        pt = self.page_tokens
        n = (len(flat) // pt) * pt
        h = hashlib.sha1()
        out: List[bytes] = []
        for end in range(pt, n + 1, pt):
            h.update(repr(flat[end - pt:end]).encode())
            out.append(h.digest())
        return out

    def register(self, replica: int, tokens: Sequence) -> None:
        """Replica ``replica`` now holds every page-aligned prefix of
        ``tokens`` in its radix tree."""
        digs = self._digests(self._key(tokens))
        self._delta.extend(("add", d, replica) for d in digs)
        if digs:
            self.registrations += 1

    def invalidate(self, replica: int, tokens: Sequence,
                   tail_tokens: int) -> None:
        """A leaf covering the last ``tail_tokens`` of path ``tokens``
        left ``replica``'s tree: drop its ownership of the prefixes that
        run covered (ancestor prefixes remain owned — they are still in
        the tree). One linear hash pass; O(changed pages) shard ops."""
        digs = self._digests(self._key(tokens))
        pt = self.page_tokens
        start_page = max(len(digs) * pt - tail_tokens, 0) // pt
        self._delta.extend(("discard", d, replica)
                           for d in digs[start_page:])
        self.invalidations += 1

    def flush(self) -> None:
        """Apply queued hook ops as one delta batch."""
        if self._delta:
            ops, self._delta = self._delta, []
            self.shards.apply_delta(ops)

    def lookup_entry(self, tokens: Sequence
                     ) -> Tuple[int, Optional[Set[int]], Optional[bytes]]:
        """Longest registered page-aligned prefix of ``tokens``:
        ``(matched_tokens, owner_replicas, digest)`` — the digest is the
        directory key for hit recording; ``(0, None, None)`` on miss."""
        self.flush()
        digs = self._digests(self._key(tokens))
        for i in range(len(digs) - 1, -1, -1):
            owners = self.shards.owners(digs[i])
            if owners:
                return (i + 1) * self.page_tokens, owners, digs[i]
        return 0, None, None

    def lookup(self, tokens: Sequence) -> Tuple[int, Optional[Set[int]]]:
        matched, owners, _ = self.lookup_entry(tokens)
        return matched, owners

    def record_hit(self, digest: bytes) -> int:
        """One fleet-wide hit on ``digest``'s entry; returns the count —
        the predictive replicator's threshold signal."""
        return self.shards.hit(digest)

    def owned_by(self, replica: int) -> int:
        self.flush()
        return sum(1 for sh in self.shards.shards
                   for o in sh.owners.values() if replica in o)

    def n_entries(self) -> int:
        self.flush()
        return len(self.shards)


class ClusterFrontend:
    """Fans requests across N :class:`ServeEngine` replicas under one
    fleet memory plane (DESIGN.md §7).

    Invariants the tests rely on:

    - **Directory ownership lifecycle** — the :class:`PrefixDirectory`
      mirrors every replica's radix tree: ownership appears with
      ``register_prefix``/``adopt_prefix`` (incl. the bootstrap of trees
      that served before this frontend attached) and disappears with
      exactly the run an evicted leaf covered.
    - **Migration conservation** — a migration copies (never moves) the
      donor's pages: donor refcounts are untouched, receiver pages are
      tree-owned, and both replicas tear down to zero allocator
      utilization; truncated adoptions stay page-aligned and never leave
      an unresolved pressure event.
    - **Report conservation** — fleet totals (tokens, per-tier bytes,
      pressure resolutions, pooled latency records) equal the sum of the
      per-replica reports.
    - **Clock coherence** — a cluster round ends with every replica at
      the fleet clock (the slowest replica's time), and a migration's
      interconnect wait is charged to the triggering request's TTFT.
    """

    #: bounded speculative-push retries: after this many fabric-hot
    #: defers a push is abandoned (the demand path will pull on miss)
    _PUSH_MAX_DEFERS = 8

    def __init__(self, engines: List[ServeEngine],
                 migrate_prefixes: bool = False,
                 interconnect_gbps: float = 50.0,
                 migrate_load_gap: int = 2,
                 prefix_affinity: bool = True,
                 clock_mode: str = "lockstep",
                 record_trace: bool = False,
                 replicate_threshold: Optional[int] = None,
                 replicate_copies: int = 1,
                 directory_shards: int = 8,
                 fabric_bisection_gbps: Optional[float] = None):
        if not engines:
            raise ValueError("ClusterFrontend needs at least one replica")
        if interconnect_gbps <= 0:
            raise ValueError("interconnect_gbps must be > 0")
        if clock_mode not in ("lockstep", "event"):
            raise ValueError(f"unknown clock_mode {clock_mode!r}")
        self.clock_mode = clock_mode
        self.engines = list(engines)
        self.migrate_prefixes = migrate_prefixes
        # GBYTES/s — deliberately the same (historically misnamed) unit as
        # memclass's read_bw_gbps/write_bw_gbps tier fields
        self.interconnect_gbps = interconnect_gbps
        self.migrate_load_gap = migrate_load_gap
        self.prefix_affinity = prefix_affinity
        self.routes: Dict[str, int] = {}          # session_key -> replica
        self.requests: Dict[int, Tuple[int, int]] = {}  # rid -> (replica, local)
        self._next_rid = 0
        self.steps = 0
        self.radix_routed = 0      # requests placed by prefix affinity
        self.migrations = 0        # cross-replica prefix transfers
        self.migrated_tokens = 0   # tokens newly backed on a receiver
        self.migration_bytes = 0.0  # KV + snapshot bytes over the wire
        self.migration_s = 0.0      # interconnect transfer time charged
        self.migration_queue_wait_s = 0.0  # time spent queued on a busy link
        self.migrations_queued = 0  # transfers that found their link busy
        self._last_migrated = 0    # tokens grafted for the pending submit
        # predictive replication (DESIGN §13): once a directory entry's
        # fleet-wide hit count crosses the threshold, push it to the
        # least-loaded non-owners ahead of the burst (None = reactive)
        self.replicate_threshold = replicate_threshold
        self.replicate_copies = replicate_copies
        self.replications = 0          # speculative pushes delivered
        self.replicated_tokens = 0
        self.replication_bytes = 0.0
        self.replication_s = 0.0
        self.replications_deferred = 0  # pushes that yielded to a hot fabric
        self.pushes_abandoned = 0       # defer budget exhausted / entry gone
        self._push_inflight: Set[Tuple[bytes, int]] = set()
        self._pending_pushes: Dict[int, tuple] = {}
        self._push_seq = 0
        # shared-fabric admission control (DESIGN §13): every transfer
        # holds its donor's up-link, its receiver's down-link, and one
        # bisection core channel — concurrent migrations and replications
        # contend realistically; a transfer finding any resource busy
        # queues, and the triggering request waits out queue + transfer.
        self.fabric = Fabric(len(engines), interconnect_gbps,
                             fabric_bisection_gbps)
        # deferred interconnect charges (replica -> seconds): applied at
        # the next cluster step, *after* the triggering requests are
        # enqueued, so their submitted_at predates the transfer and their
        # TTFT pays for the queue wait + migration time. Deferring a whole
        # burst (rather than flushing per submit) is what lets same-burst
        # migrations to one receiver actually contend for its link.
        self._pending_transfer: Dict[int, float] = {}
        # fleet-level prefix directory: every replica's publishes and
        # evictions flow in through the manager hooks; pre-existing tree
        # content (engines that served before this frontend) bootstraps in
        self.directory = PrefixDirectory(engines[0].ecfg.page_tokens,
                                         n_shards=directory_shards)
        for i, e in enumerate(self.engines):
            e.kv.on_prefix_insert = (
                lambda tokens, _i=i: self.directory.register(_i, tokens))
            e.kv.on_prefix_evict = (
                lambda tokens, tail, _i=i:
                    self.directory.invalidate(_i, tokens, tail))
            for node in e.kv.radix.nodes():
                self.directory.register(i, e.kv.radix.full_key(node))
        # event clock (DESIGN.md §12): typed events on a deterministic
        # queue; replicas advance independently. Unused in lockstep mode.
        self.events = EventQueue()
        self.trace = EventTrace(record=record_trace)
        self._pending_arrivals: Dict[int, tuple] = {}  # rid -> submit args
        self._step_pending: Dict[int, bool] = {}
        self._step_seq: Dict[int, int] = {}
        self._last_delivery_at: Optional[float] = None
        self._route_time = 0.0
        self._migration_seq = 0
        self._decay_next: Dict[int, Optional[float]] = {}

    # ------------------------------------------------------------------
    @property
    def now(self) -> float:
        return max(e.mem.now for e in self.engines)

    @property
    def idle(self) -> bool:
        return all(e.sched.idle for e in self.engines)

    def _load(self, i: int) -> int:
        e = self.engines[i]
        return len(e.sched.queue) + len(e.sched.active)

    def _load_key(self, i: int) -> tuple:
        """Replica load for routing: queue+resident first, then the KV
        tier's *physical* occupancy (allocator utilization: live session
        pages, directory-owned radix-resident prefixes AND metered
        snapshots all occupy it) — so a replica stuffed with pinned hot
        prefixes loses ties to an equally-queued replica with free KV —
        then index for determinism. O(1) per replica: no session or tree
        walk on the routing path."""
        e = self.engines[i]
        return (self._load(i),
                round(e.mem.utilization(e.ecfg.kv_tier), 9), i)

    # -- the directory protocol: route first, migrate on miss ----------
    def _migrate(self, donor: int, target: int, key,
                 speculative: bool = False) -> int:
        """Pull the donor's published prefix (pages + compute snapshot)
        into the target replica as a metered inter-replica transfer.
        ``speculative`` marks a predictive replication push: same wire
        physics and tier metering, separate ledger, and no request is
        gated on its delivery. Returns the tokens now matched on the
        target (0 = nothing moved)."""
        exp = self.engines[donor].export_prefix(key)
        if exp is None:
            return 0
        e = self.engines[target]
        imp = e.import_prefix(exp["tokens"], caches=exp["caches"],
                              hot=exp["hot"], hits=exp["hits"],
                              snap_kind=exp["snap_kind"],
                              snap_tokens=exp["snap_tokens"],
                              page_data=exp.get("page_data"),
                              page_tokens=exp.get("page_tokens"))
        if imp["total_tokens"] == 0:
            return 0
        moved = (imp["new_tokens"] * e.kv.kv_bytes_token
                 + imp["snapshot_bytes"])
        if moved > 0:
            # shared-fabric admission control: the transfer starts when
            # the donor's up-link, the receiver's down-link AND a core
            # channel are all free (queue wait), then holds all three for
            # bytes / bandwidth. Lockstep advances the receiver's clock
            # at the next cluster step (_flush_transfer); event mode
            # schedules a MIGRATION_DELIVERY event at the wire-done time
            # and (for demand pulls) gates the triggering request's
            # admission on it. Either way TTFT pays queue wait + transfer.
            dur = moved / (self.interconnect_gbps * 1e9)
            t_req = (self._route_time if self.clock_mode == "event"
                     else e.mem.now)
            start, done = self.fabric.reserve(donor, target, moved, t_req)
            wait = start - t_req
            if self.clock_mode == "event":
                if not speculative:
                    self._last_delivery_at = done
                self._migration_seq += 1
                self.events.push(Event(done,
                                       EventKind.MIGRATION_DELIVERY, target,
                                       key=self._migration_seq,
                                       info=(imp["new_tokens"],
                                             int(speculative))))
            else:
                self._pending_transfer[target] = max(
                    self._pending_transfer.get(target, 0.0), done - t_req)
            if speculative:
                self.replications += 1
                self.replicated_tokens += imp["new_tokens"]
                self.replication_bytes += moved
                self.replication_s += dur
            else:
                if wait > 0:
                    self.migrations_queued += 1
                    self.migration_queue_wait_s += wait
                self.migrations += 1
                self.migrated_tokens += imp["new_tokens"]
                self.migration_bytes += moved
                self.migration_s += dur
        return imp["total_tokens"]

    def _flush_transfer(self, i: int) -> None:
        t = self._pending_transfer.pop(i, 0.0)
        if t > 0:
            self.engines[i].mem.advance(t)

    def _route_by_prefix(self, prompt_tokens: list,
                         session_key: Optional[str]) -> Optional[int]:
        """Directory consult: the least-loaded owner of the longest
        registered prefix wins while it has headroom; otherwise the
        prefix is migrated to the least-loaded replica and the request
        follows it."""
        if not self.prefix_affinity:
            return None
        key = self.engines[0].radix_key_for(prompt_tokens)
        if key is None:
            return None
        matched, owners, digest = self.directory.lookup_entry(key)
        if not matched or not owners:
            return None
        live = [i for i in owners if i < len(self.engines)]
        if not live:
            return None
        hits = self.directory.record_hit(digest)
        choice = min(live, key=self._load_key)
        if self.migrate_prefixes and len(self.engines) > 1:
            least = min(range(len(self.engines)), key=self._load_key)
            if (least not in live and
                    self._load(choice) - self._load(least)
                    > self.migrate_load_gap):
                got = self._migrate(choice, least, key)
                if got > 0:
                    self._last_migrated = got
                    choice = least
        if (self.replicate_threshold is not None
                and len(self.engines) > 1
                and hits >= self.replicate_threshold):
            self._maybe_replicate(key, digest)
        self.radix_routed += 1
        if session_key is not None:
            self.routes[str(session_key)] = choice
        return choice

    def _maybe_replicate(self, key, digest: bytes) -> None:
        """Predictive replication (DESIGN §13): the entry crossed its
        fleet-wide hit threshold — push it to the least-loaded non-owners
        until ``1 + replicate_copies`` replicas hold it. Event mode
        schedules low-priority REPLICATION_PUSH events (they fire after
        every demand event at the same instant and re-defer while the
        fabric is hot); lockstep pushes inline, skipping when the fabric
        is busy (the next hit retries)."""
        _, owners, _ = self.directory.lookup_entry(key)  # post-migration
        if not owners:
            return
        live = sorted(i for i in owners if i < len(self.engines))
        if not live:
            return
        inflight = sum(1 for d, _t in self._push_inflight if d == digest)
        need = self.replicate_copies + 1 - len(live) - inflight
        if need <= 0:
            return
        targets = sorted(
            (i for i in range(len(self.engines))
             if i not in owners and (digest, i) not in self._push_inflight),
            key=self._load_key)[:need]
        donor = min(live, key=self._load_key)
        for target in targets:
            if self.clock_mode == "event":
                self._push_seq += 1
                self._push_inflight.add((digest, target))
                self._pending_pushes[self._push_seq] = (digest, key, target, 0)
                self.events.push(Event(self._route_time,
                                       EventKind.REPLICATION_PUSH, target,
                                       key=self._push_seq))
            else:
                if self.fabric.hot(donor, target,
                                   self.engines[target].mem.now):
                    self.replications_deferred += 1
                    continue
                self._migrate(donor, target, key, speculative=True)

    def route(self, session_key: Optional[str] = None,
              prompt_tokens: Optional[list] = None) -> int:
        # 1) fleet prefix directory: owner affinity, migrate on overload
        if prompt_tokens is not None:
            i = self._route_by_prefix(prompt_tokens, session_key)
            if i is not None:
                return i
        # 2) sticky session fallback (the user's first follow-up lands
        #    where their prefix will be, before the directory has seen it)
        if session_key is not None:
            key = str(session_key)
            if key not in self.routes:
                h = int(hashlib.sha1(key.encode()).hexdigest(), 16)
                self.routes[key] = h % len(self.engines)
            return self.routes[key]
        # 3) least-loaded (KV-pressure-aware, hot-prefix bytes included)
        return min(range(len(self.engines)), key=self._load_key)

    def submit(self, prompt_tokens: list, max_new_tokens: int,
               session_key: Optional[str] = None,
               at: Optional[float] = None,
               abandon_after_s: Optional[float] = None) -> int:
        """Route and enqueue a request; returns a cluster-wide request id.

        Lockstep mode routes immediately on the shared clock (exactly the
        PR 3–8 behavior). Event mode records an ARRIVAL event at ``at``
        (default: the fleet clock) — routing happens when the event
        fires, against the replica loads of *that* simulated instant, and
        an optional ``abandon_after_s`` schedules a timeout event that
        drops the request if it is still queued."""
        rid = self._next_rid
        self._next_rid += 1
        if self.clock_mode == "event":
            t = self.now if at is None else at
            self._pending_arrivals[rid] = (
                prompt_tokens, max_new_tokens, session_key)
            self.events.push(Event(max(t, self.events.last_time),
                                   EventKind.ARRIVAL, -1, key=rid))
            if abandon_after_s is not None:
                self.events.push(Event(max(t, self.events.last_time)
                                       + abandon_after_s,
                                       EventKind.ABANDON, -1, key=rid))
            return rid
        self._last_migrated = 0
        replica = self.route(session_key, prompt_tokens)
        local = self.engines[replica].submit(
            prompt_tokens, max_new_tokens,
            migrated_tokens=self._last_migrated)
        # the migration this submit may have triggered is charged at the
        # next cluster step (submitted_at predates the transfer, so TTFT
        # pays the link's queue wait + transfer time); deferring past the
        # whole submit burst is what makes same-burst migrations to one
        # receiver contend for its link (admission control)
        self.requests[rid] = (replica, local)
        return rid

    def output(self, rid: int) -> list:
        replica, local = self.requests[rid]
        return self.engines[replica].outputs[local]

    def replica_of(self, rid: int) -> int:
        return self.requests[rid][0]

    # ------------------------------------------------------------------
    def step(self) -> dict:
        """One cluster round: every busy replica runs an engine step in
        parallel; the fleet clock advances to the slowest replica."""
        for i in list(self._pending_transfer):
            # deliver queued interconnect transfers: each receiver stalls
            # to its link's delivery time (queue wait + transfer included)
            self._flush_transfer(i)
        busy = [e for e in self.engines if not e.sched.idle]
        for e in busy:
            e.step()
        now = self.now
        for e in self.engines:
            if e.mem.now < now:
                e.mem.advance(now - e.mem.now)
        self.steps += 1
        return {"now_s": now, "busy_replicas": len(busy)}

    # -- event clock (DESIGN.md §12) -----------------------------------
    def _ensure_step(self, i: int, t: float) -> None:
        """Schedule a STEP event for replica ``i`` no earlier than its own
        clock — an idle replica's clock *jumps* to the arrival instant at
        the step (replicas advance independently)."""
        if self._step_pending.get(i):
            return
        self._step_pending[i] = True
        self._step_seq[i] = self._step_seq.get(i, 0) + 1
        when = max(self.engines[i].mem.now, t, self.events.last_time)
        self.events.push(Event(when, EventKind.STEP, i,
                               key=self._step_seq[i]))

    def _ev_arrival(self, ev: Event) -> None:
        prompt_tokens, max_new_tokens, session_key = \
            self._pending_arrivals.pop(ev.key)
        self._last_migrated = 0
        self._last_delivery_at = None
        self._route_time = ev.time
        replica = self.route(session_key, prompt_tokens)
        admit_after = (self._last_delivery_at
                       if self._last_delivery_at is not None else ev.time)
        local = self.engines[replica].submit(
            prompt_tokens, max_new_tokens,
            migrated_tokens=self._last_migrated,
            at=ev.time, admit_after=admit_after)
        self.requests[ev.key] = (replica, local)
        self._ensure_step(replica, max(ev.time, admit_after))

    def _schedule_decay(self, i: int) -> None:
        """Wall-clock retention decay (DESIGN.md §12): instead of per-step
        polling, an idle replica gets a RETENTION_DECAY event at the
        earliest leaf deadline — its clock jumps there and the cold sweep
        runs exactly on time. Already-due leaves sweep inline."""
        e = self.engines[i]
        due = e.kv.next_decay_due()
        if due is None:
            return
        if due <= e.mem.now:
            e.kv.maintain()
            due = e.kv.next_decay_due()
            if due is None or due <= e.mem.now:
                return  # nothing further can decay (e.g. spilled leaves)
        cur = self._decay_next.get(i)
        due = max(due + 1e-9, self.events.last_time)  # decay_due is strict >
        if cur is not None and cur <= due:
            return
        self._decay_next[i] = due
        self.events.push(Event(due, EventKind.RETENTION_DECAY, i))

    def _ev_decay(self, ev: Event) -> None:
        self._decay_next[ev.replica] = None
        e = self.engines[ev.replica]
        if not e.sched.idle:
            return  # busy replica: per-step maintain() already polls
        if e.mem.now < ev.time:
            e.mem.advance(ev.time - e.mem.now)
        e.kv.maintain()
        self._schedule_decay(ev.replica)

    def _ev_step(self, ev: Event) -> None:
        i = ev.replica
        self._step_pending[i] = False
        e = self.engines[i]
        if e.sched.idle:
            return
        if e.mem.now < ev.time:
            e.mem.advance(ev.time - e.mem.now)
        before = e.mem.now
        e.step()
        self.steps += 1
        if e.sched.idle:
            self._schedule_decay(i)
            return
        next_t = e.mem.now
        if next_t <= before + 1e-12:
            # the step did no work: everything queued admits in the
            # future (in-flight migration) — sleep to the earliest
            future = [r.admit_after for r in e.sched.queue
                      if r.admit_after > before]
            if not future:
                raise NonQuiescentError(
                    f"replica {i} stalled at t={before}: work queued but "
                    "no step progress and no future admission")
            next_t = min(future)
        self._ensure_step(i, next_t)

    def _ev_delivery(self, ev: Event) -> None:
        # pages were grafted (and metered) at migration time; the event
        # marks when the link actually frees. An otherwise-idle receiver
        # moves its clock to the delivery instant so later steps (and the
        # gated request's admission) start after the wire time.
        e = self.engines[ev.replica]
        if e.mem.now < ev.time:
            e.mem.advance(ev.time - e.mem.now)
        self._ensure_step(ev.replica, ev.time)

    def _ev_abandon(self, ev: Event) -> None:
        entry = self.requests.get(ev.key)
        if entry is None:
            return  # arrival never fired (cancelled before routing)
        replica, local = entry
        self.engines[replica].sched.abandon(local, ev.time)

    def _ev_push(self, ev: Event) -> None:
        """Execute (or re-defer) one speculative replication push. The
        event kind is the lowest priority, so at its timestamp every
        demand-side fabric reservation has already been made: a push that
        finds the path hot yields — retrying at the projected free
        instant, bounded by ``_PUSH_MAX_DEFERS`` — which is exactly how a
        demand migration preempts queued speculative work."""
        digest, key, target, defers = self._pending_pushes.pop(ev.key)
        matched, owners, _ = self.directory.lookup_entry(key)
        live = ([i for i in owners if i < len(self.engines)]
                if matched and owners else [])
        if not live or target in owners:
            # evicted fleet-wide, or the receiver became an owner on its
            # own (demand migration beat the push): nothing to do
            self._push_inflight.discard((digest, target))
            return
        donor = min(live, key=self._load_key)
        if self.fabric.hot(donor, target, ev.time):
            self.replications_deferred += 1
            if defers + 1 >= self._PUSH_MAX_DEFERS:
                self.pushes_abandoned += 1
                self._push_inflight.discard((digest, target))
                return
            free = self.fabric.free_at(donor, target, ev.time)
            self._push_seq += 1
            self._pending_pushes[self._push_seq] = (digest, key, target,
                                                    defers + 1)
            self.events.push(Event(free, EventKind.REPLICATION_PUSH, target,
                                   key=self._push_seq))
            return
        self._route_time = ev.time
        got = self._migrate(donor, target, key, speculative=True)
        self._push_inflight.discard((digest, target))
        if got == 0:
            self.pushes_abandoned += 1

    _EVENT_HANDLERS = {
        EventKind.ARRIVAL: _ev_arrival,
        EventKind.STEP: _ev_step,
        EventKind.MIGRATION_DELIVERY: _ev_delivery,
        EventKind.ABANDON: _ev_abandon,
        EventKind.RETENTION_DECAY: _ev_decay,
        EventKind.REPLICATION_PUSH: _ev_push,
    }

    def run_events(self, max_events: int = 1_000_000,
                   on_stall: str = "raise") -> dict:
        """Drain the event queue (event clock mode): replicas step on
        their own clocks, synchronizing only through the directory, the
        interconnect links and the fleet event queue."""
        for i, e in enumerate(self.engines):
            if e.sched.idle:
                self._schedule_decay(i)  # pre-existing trees decay on time
        processed = 0
        while self.events:
            if processed >= max_events:
                rep = self.report()
                if on_stall == "report":
                    return rep
                raise NonQuiescentError(
                    f"cluster not quiescent after {processed} events: "
                    f"{len(self.events)} pending", rep)
            ev = self.events.pop()
            self.trace.add(ev)
            self._EVENT_HANDLERS[ev.kind](self, ev)
            processed += 1
        return self.report()

    def run_until_idle(self, max_steps: int = 10000,
                       on_stall: str = "raise") -> dict:
        """Run to quiescence. Exhausting the budget with requests still
        queued raises :class:`NonQuiescentError` (default) or returns the
        report flagged ``quiesced=False`` (``on_stall="report"``) — the
        PR 1–8 behavior was a silent truncated return."""
        if self.clock_mode == "event":
            return self.run_events(max_events=max_steps, on_stall=on_stall)
        start = self.steps
        while not self.idle and self.steps - start < max_steps:
            self.step()
        rep = self.report()
        if not self.idle and on_stall != "report":
            raise NonQuiescentError(
                f"cluster not quiescent after {max_steps} steps: "
                f"{sum(len(e.sched.queue) + len(e.sched.active) for e in self.engines)}"
                " requests pending", rep)
        return rep

    # ------------------------------------------------------------------
    def report(self) -> dict:
        reps = [e.report() for e in self.engines]
        tokens = sum(r["tokens_generated"] for r in reps)
        energy = sum(r["memory"]["total_energy_j"] for r in reps)
        tiers: Dict[str, dict] = {}
        for r in reps:
            for name, t in r["memory"]["tiers"].items():
                agg = tiers.setdefault(name, {"capacity_gb": 0.0,
                                              "read_gb": 0.0, "write_gb": 0.0,
                                              "refresh_gb": 0.0,
                                              "energy_j": 0.0})
                for k in agg:
                    agg[k] += t[k]
        pressure: Dict[str, int] = {}
        for r in reps:
            for k, v in r["pressure"].items():
                pressure[k] = pressure.get(k, 0) + v
        records = [rec for e in self.engines for rec in e.sched.latency]
        return {
            "replicas": len(self.engines),
            "cluster_steps": self.steps,
            "clock_mode": self.clock_mode,
            "sim_time_s": self.now,
            "quiesced": self.idle,
            "pending_requests": sum(len(e.sched.queue) + len(e.sched.active)
                                    for e in self.engines),
            "abandoned": sum(e.sched.stats.abandoned for e in self.engines),
            "trace": self.trace.as_dict(),
            "finished": sum(r["finished"] for r in reps),
            "tokens_generated": tokens,
            "fleet_tokens_per_s": tokens / max(self.now, 1e-9),
            "energy_per_token_j": energy / max(tokens, 1),
            "tiers": tiers,
            "pressure": pressure,
            "dropped_allocs": sum(r["dropped_allocs"] for r in reps),
            "prefix_hits": sum(r["prefix_hits"] for r in reps),
            "prefix_hits_migrated": sum(e.kv.prefix_hits_migrated
                                        for e in self.engines),
            "prefix_tokens_reused": sum(r["prefix_tokens_reused"] for r in reps),
            "prefill_tokens_computed": sum(r["prefill_tokens_computed"]
                                           for r in reps),
            "prefill_tokens_skipped": sum(r["prefill_tokens_skipped"]
                                          for r in reps),
            "snapshot_bytes": sum(r["snapshot_bytes"] for r in reps),
            "radix_routed": self.radix_routed,
            "directory": {
                "entries": self.directory.n_entries(),
                "registrations": self.directory.registrations,
                "invalidations": self.directory.invalidations,
                "shards": self.directory.shards.shard_counters(),
            },
            "interconnect": {
                "gbps": self.interconnect_gbps,
                "migrations": self.migrations,
                "migrated_tokens": self.migrated_tokens,
                "migration_bytes": self.migration_bytes,
                "migration_s": self.migration_s,
                "queued_migrations": self.migrations_queued,
                "queue_wait_s": self.migration_queue_wait_s,
                "replications": self.replications,
                "replicated_tokens": self.replicated_tokens,
                "replication_bytes": self.replication_bytes,
                "replication_s": self.replication_s,
                "replications_deferred": self.replications_deferred,
                "pushes_abandoned": self.pushes_abandoned,
            },
            "fabric": self.fabric.report(),
            "latency": latency_percentiles(records),
            "per_replica": reps,
        }
