"""Continuous-batching request scheduler with prefill/decode separation.

Splitwise-style ([34], cited by the paper) phase awareness: prefill work is
admitted up to `max_prefills_per_step` per engine step so decode latency
stays bounded; decode rounds run over all resident sessions. Deterministic
(no wall clock — simulation time comes from the engine).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional


@dataclass
class Request:
    request_id: int
    prompt_tokens: list       # list[int] (or list[list[int]] multi-codebook)
    max_new_tokens: int
    submitted_at: float
    prefilled_at: Optional[float] = None
    finished_at: Optional[float] = None
    generated: int = 0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt_tokens)


@dataclass
class SchedulerStats:
    admitted: int = 0
    finished: int = 0
    queue_peak: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0


class ContinuousBatchScheduler:
    def __init__(self, max_batch_slots: int, max_prefills_per_step: int = 2):
        self.max_slots = max_batch_slots
        self.max_prefills = max_prefills_per_step
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}  # slot -> request
        self.free_slots: List[int] = list(range(max_batch_slots))
        self.stats = SchedulerStats()

    def submit(self, req: Request) -> None:
        self.queue.append(req)
        self.stats.queue_peak = max(self.stats.queue_peak, len(self.queue))

    def admissions(self) -> List[tuple]:
        """Pick (slot, request) pairs to prefill this step."""
        out = []
        while (self.queue and self.free_slots and
               len(out) < self.max_prefills):
            req = self.queue.popleft()
            slot = self.free_slots.pop(0)
            self.active[slot] = req
            self.stats.admitted += 1
            self.stats.prefill_tokens += req.prompt_len
            out.append((slot, req))
        return out

    def decode_slots(self) -> List[int]:
        return sorted(self.active)

    def finish(self, slot: int, now: float) -> Request:
        req = self.active.pop(slot)
        req.finished_at = now
        self.free_slots.append(slot)
        self.free_slots.sort()
        self.stats.finished += 1
        return req

    @property
    def idle(self) -> bool:
        return not self.queue and not self.active
