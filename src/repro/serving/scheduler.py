"""Continuous-batching request scheduler with prefill/decode separation.

Splitwise-style ([34], cited by the paper) phase awareness: prefill work is
admitted up to `max_prefills_per_step` per engine step so decode latency
stays bounded; decode rounds run over all resident sessions. With chunked
prefill, a slot can be resident but still *prefilling* (its prompt is being
fed in `chunk_tokens` pieces interleaved with decode rounds); such slots
are excluded from decode until the engine marks them decoding.

Prefix-aware admission: when the engine supplies a ``match_len`` scorer
(longest radix prefix the KV tree already holds for a request),
``admissions`` prefers the queued request with the longest match — requests
sharing a hot prefix batch together, so the shared pages are attached while
still pinned-hot instead of after eviction. FIFO breaks ties, and a
request bypassed ``max_skip`` times is admitted regardless (no starvation).

Deterministic (no wall clock — simulation time comes from the engine).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Set


@dataclass
class Request:
    request_id: int
    prompt_tokens: list       # list[int] (or list[list[int]] multi-codebook)
    max_new_tokens: int
    submitted_at: float
    prefilled_at: Optional[float] = None
    first_token_at: Optional[float] = None  # TTFT = this - submitted_at
    finished_at: Optional[float] = None
    generated: int = 0
    prompt_pos: int = 0       # prompt tokens prefilled so far (chunked prefill)
    sched_skipped: int = 0    # times bypassed by prefix-aware admission
    # prefix tokens a cross-replica migration grafted here for this
    # request: prefix-aware admission counts them as a match even if the
    # grafted leaf is evicted before the request is picked
    migrated_tokens: int = 0
    # earliest simulated time this request may be admitted — event-mode
    # migrations land their pages at the link's delivery time, and the
    # triggering request waits for them (TTFT pays queue wait + transfer)
    admit_after: float = 0.0
    abandoned_at: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt_tokens)


@dataclass
class SchedulerStats:
    admitted: int = 0
    finished: int = 0
    queue_peak: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_chunks: int = 0
    prefix_reorders: int = 0  # admissions that jumped the FIFO order
    migrated_admissions: int = 0  # admitted requests with a migrated prefix
    abandoned: int = 0        # queued requests dropped by timeout/cancel


class ContinuousBatchScheduler:
    """Slot bookkeeping + admission policy for one engine.

    Invariants the tests rely on: every submitted request is admitted
    exactly once and finished exactly once; ``len(active) <= max_slots``
    at all times; prefix-aware admission never starves the FIFO head
    beyond ``max_skip`` bypasses; slots marked ``prefilling`` are excluded
    from decode until the engine marks them decoding."""

    def __init__(self, max_batch_slots: int, max_prefills_per_step: int = 2,
                 max_skip: int = 4):
        self.max_slots = max_batch_slots
        self.max_prefills = max_prefills_per_step
        self.max_skip = max_skip
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}  # slot -> request
        self.free_slots: List[int] = list(range(max_batch_slots))
        self.prefilling: Set[int] = set()     # slots mid-chunked-prefill
        self.stats = SchedulerStats()
        self.latency: List[dict] = []         # per-finished-request records

    def submit(self, req: Request) -> None:
        self.queue.append(req)
        self.stats.queue_peak = max(self.stats.queue_peak, len(self.queue))

    def _pick(self, match_len: Optional[Callable[[Request], int]],
              now: Optional[float] = None) -> Optional[Request]:
        """Next request to admit: FIFO head, unless prefix-aware scoring
        finds a longer-match request further back (bounded by max_skip).
        With ``now`` (arrival-time admission, event clock), requests whose
        ``admit_after`` is still in the future are invisible — their pages
        (or their arrival itself) haven't happened yet on this replica's
        clock. Returns None when nothing is admissible."""
        if now is None:
            eligible = list(range(len(self.queue)))
        else:
            eligible = [i for i, r in enumerate(self.queue)
                        if r.admit_after <= now]
            if not eligible:
                return None

        def _take(idx: int) -> Request:
            req = self.queue[idx]
            del self.queue[idx]
            return req

        first = eligible[0]
        if match_len is None or len(eligible) == 1:
            return _take(first)
        head = self.queue[first]
        if head.sched_skipped >= self.max_skip:
            return _take(first)
        # a freshly migrated prefix scores as a match even when the
        # grafted leaf was evicted between migration and admission
        scores = [max(match_len(self.queue[i]), self.queue[i].migrated_tokens)
                  for i in eligible]
        best = max(scores)
        idx = eligible[scores.index(best)]  # earliest submitter among ties
        if idx == first or best <= 0:
            return _take(first)
        req = _take(idx)
        for i in eligible:
            if i < idx:  # indices below idx are unshifted by the delete
                self.queue[i].sched_skipped += 1
        self.stats.prefix_reorders += 1
        return req

    def admissions(self, limit: Optional[int] = None,
                   match_len: Optional[Callable[[Request], int]] = None,
                   now: Optional[float] = None) -> List[tuple]:
        """Pick (slot, request) pairs to start prefilling this step."""
        n = self.max_prefills if limit is None else min(limit, self.max_prefills)
        out = []
        while self.queue and self.free_slots and len(out) < n:
            req = self._pick(match_len, now)
            if req is None:
                break
            slot = self.free_slots.pop(0)
            self.active[slot] = req
            self.stats.admitted += 1
            if req.migrated_tokens > 0:
                self.stats.migrated_admissions += 1
            self.stats.prefill_tokens += req.prompt_len
            out.append((slot, req))
        return out

    def abandon_timed_out(self, now: float, timeout: float) -> List[Request]:
        """Drop queued requests older than ``timeout`` — the user hung up
        before first token. Only *queued* requests abandon (a session
        already holding slots runs to completion); every dropped request
        is fully removed, so abandonment can never leak queue entries."""
        dropped = [r for r in self.queue if now - r.submitted_at >= timeout]
        if dropped:
            self.queue = deque(r for r in self.queue
                               if now - r.submitted_at < timeout)
            for r in dropped:
                r.abandoned_at = now
            self.stats.abandoned += len(dropped)
        return dropped

    def abandon(self, request_id: int, now: float) -> bool:
        """Drop one queued request by id (event-driven abandonment).
        Returns False if it already left the queue (admitted/finished)."""
        for i, r in enumerate(self.queue):
            if r.request_id == request_id:
                del self.queue[i]
                r.abandoned_at = now
                self.stats.abandoned += 1
                return True
        return False

    # -- chunked-prefill phase tracking (engine-driven) ----------------
    def mark_prefilling(self, slot: int) -> None:
        self.prefilling.add(slot)

    def mark_decoding(self, slot: int) -> None:
        self.prefilling.discard(slot)

    def decode_slots(self) -> List[int]:
        return sorted(s for s in self.active if s not in self.prefilling)

    def finish(self, slot: int, now: float) -> Request:
        req = self.active.pop(slot)
        self.prefilling.discard(slot)
        req.finished_at = now
        self.free_slots.append(slot)
        self.free_slots.sort()
        self.stats.finished += 1
        self.latency.append(_latency_record(req))
        return req

    @property
    def idle(self) -> bool:
        return not self.queue and not self.active


def _latency_record(req: Request) -> dict:
    """TTFT/ITL sample for one finished request (simulated seconds)."""
    ttft = (req.first_token_at - req.submitted_at
            if req.first_token_at is not None else None)
    itl = None
    if (req.first_token_at is not None and req.finished_at is not None
            and req.generated > 1):
        itl = (req.finished_at - req.first_token_at) / (req.generated - 1)
    return {"request_id": req.request_id, "ttft": ttft, "itl": itl,
            "generated": req.generated}
