"""Analytic event-driven fleet simulator (DESIGN.md §12).

The real ``ServeEngine`` runs actual JAX compute, so a fleet of replicas
tops out at a handful of requests per test. ROADMAP open item 2 needs the
opposite regime: dozens–hundreds of replicas and ~10⁶ queued sessions, so
that retention decay, refresh scheduling and migration queuing meet
*realistic timescales* — the regime where the paper's managed-retention
bet (PAPER.md §4) either pays or doesn't. :class:`FleetSim` closes that
gap with an analytic replica model on the event core of
:mod:`repro.serving.events`:

- **Requests are symbolic.** A :class:`FleetRequest` carries a prefix
  *group* plus shared/unique token counts instead of token lists, so
  prefix matching is a dict lookup and a million sessions fit in memory.
- **Latency is byte-accounted, not made up.** A replica round costs one
  weight pass plus the KV bytes it moves, at the per-tier bandwidths of
  the :mod:`repro.core.memclass` technology table (HBM hot tier, MRM
  warm tier, NAND cold tier). DRAM refresh and MRM scrub traffic are
  integrated over simulated wall-clock, mirroring the §11 metering.
- **Fleet semantics match the real cluster plane.** Route-first /
  migrate-on-miss against a hash-sharded prefix directory (DESIGN §13),
  transfers contending on a shared :class:`~repro.serving.fabric.Fabric`
  (per-replica NIC up/down links + bisection core), optional predictive
  replication (hit-threshold-triggered low-priority pushes that yield to
  demand traffic), retention registration/decay with pins, and the
  pressure policy chain (evict-LRU → spill-to-cold → recompute) with a
  balancing ledger — the same invariants the engine-backed
  ``ClusterFrontend`` enforces, checked by :meth:`FleetSim.check`.

Every processed event feeds the sha1 :class:`~repro.serving.events.EventTrace`;
``report()["trace"]["digest"]`` is the determinism contract asserted by
the test harness and CI. The simulator draws no randomness itself —
scenario generators (see ``experiments/scenarios.py``) own the RNG, so
one seed fixes the whole trajectory.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Set, Tuple

from repro.core.memclass import TECHNOLOGIES
from .directory import ShardedDirectory
from .events import Event, EventKind, EventQueue, EventTrace, NonQuiescentError
from .fabric import Fabric

_EPS = 1e-12


@dataclass(frozen=True)
class FleetRequest:
    """One symbolic inference session.

    ``shared_tokens`` is the reusable prefix (system prompt, document,
    agent scratchpad) identified fleet-wide by ``group``; ``unique_tokens``
    is the per-session suffix that can never hit. Token counts, not token
    ids — the analytic plane only moves bytes."""
    session_key: int
    group: int
    shared_tokens: int
    unique_tokens: int
    max_new_tokens: int
    arrival_s: float
    abandon_after_s: Optional[float] = None
    tenant: str = "default"


@dataclass
class FleetConfig:
    """Knobs for the analytic fleet. Bandwidths/refresh come from the
    named :mod:`repro.core.memclass` technologies, so the scenario zoo
    measures the same memory classes as the TCO/reliability sweeps."""
    n_replicas: int = 4
    slots_per_replica: int = 16
    max_prefills_per_round: int = 8
    chunk_tokens: int = 256
    page_tokens: int = 64
    kv_bytes_per_token: int = 131072      # 32L x 2 x 8H x 128d x fp16
    weight_bytes: float = 14e9
    hot_tech: str = "hbm3e"
    warm_tech: str = "mrm_pcm"
    cold_tech: str = "nand_slc"
    hot_capacity_bytes: float = 64e9
    warm_capacity_bytes: float = 256e9
    cold_capacity_bytes: float = 1e12
    interconnect_gbps: float = 100.0
    # shared fabric (DESIGN §13): per-replica NIC links at
    # interconnect_gbps; the switch core carries fabric_bisection_gbps
    # aggregate (None = half-bisection: link * n_replicas // 2)
    fabric_bisection_gbps: Optional[float] = None
    migrate_prefixes: bool = True
    migrate_load_gap: int = 4
    # predictive replication (DESIGN §13): once a group's fleet-wide
    # directory hit count reaches the threshold, push it to the
    # replicate_copies least-loaded non-owners (None = reactive only)
    replicate_threshold: Optional[int] = None
    replicate_copies: int = 2
    push_max_defers: int = 8
    directory_shards: int = 8
    cold_ttl_s: float = 300.0
    scrub_interval_s: Optional[float] = None
    record_trace: bool = False


@dataclass(slots=True)
class _Session:
    sid: int
    req: FleetRequest
    replica: int = -1
    phase: str = "queued"   # queued | prefill | decode | done | abandoned
    match_tokens: int = 0
    prefill_done: int = 0
    generated: int = 0
    hot_bytes: float = 0.0
    pinned_group: int = -1
    first_token_at: float = -1.0
    finished_at: float = -1.0


@dataclass(slots=True)
class _Group:
    group: int
    pages: int            # longest registered shared prefix, in pages
    bytes: float
    tier: str             # "warm" | "cold"
    pins: int = 0
    hits: int = 0
    hot: bool = False     # promoted to long-retention programming
    last_access: float = 0.0
    available_at: float = 0.0   # > now while a migration is in flight


class _Replica:
    """Per-replica state. Replicas advance independently: ``now`` only
    moves when one of this replica's events fires."""

    __slots__ = (
        "rid", "now", "queue", "active", "groups", "hot_live", "warm_live",
        "cold_live", "pending_prefills", "pending_decodes",
        "service_pending", "round_counter", "decay_next", "last_integrated",
    )

    def __init__(self, rid: int):
        self.rid = rid
        self.now = 0.0
        self.queue: deque = deque()          # sids, lazily skipping abandoned
        self.active: Dict[int, _Session] = {}
        self.groups: Dict[int, _Group] = {}
        self.hot_live = 0.0
        self.warm_live = 0.0
        self.cold_live = 0.0
        self.pending_prefills: List[Tuple[int, int]] = []   # (sid, chunk_toks)
        self.pending_decodes: List[int] = []
        self.service_pending = False
        self.round_counter = 0
        self.decay_next: Optional[float] = None
        self.last_integrated = 0.0

    def load(self) -> int:
        return len(self.queue) + len(self.active)


class FleetSim:
    """Event-driven analytic fleet. Submit :class:`FleetRequest` objects
    (any order — the queue's content-derived tie-breaks make pop order
    insertion-invariant), then :meth:`run` to quiescence."""

    def __init__(self, config: Optional[FleetConfig] = None):
        self.cfg = config or FleetConfig()
        c = self.cfg
        self.hot = TECHNOLOGIES[c.hot_tech]
        self.warm = TECHNOLOGIES[c.warm_tech]
        self.cold = TECHNOLOGIES[c.cold_tech]
        self.replicas = [_Replica(r) for r in range(c.n_replicas)]
        self.sessions: Dict[int, _Session] = {}
        self.queue = EventQueue()
        self.trace = EventTrace(record=c.record_trace)
        # fleet-shared planes: hash-sharded prefix directory (group ids
        # as keys) + the shared fabric every transfer contends on
        self.directory = ShardedDirectory(c.directory_shards)
        self.fabric = Fabric(c.n_replicas, c.interconnect_gbps,
                             c.fabric_bisection_gbps)
        # traffic + pressure counters
        self.stats = {
            "submitted": 0, "finished": 0, "abandoned": 0,
            "prefill_tokens": 0, "saved_tokens": 0, "decoded_tokens": 0,
            "kv_write_bytes": 0.0, "kv_read_bytes_hot": 0.0,
            "kv_read_bytes_warm": 0.0, "kv_read_bytes_cold": 0.0,
            "hot_refresh_bytes": 0.0, "warm_refresh_bytes": 0.0,
            "scrub_bytes": 0.0, "reprogram_bytes": 0.0,
            "reprogram_events": 0, "decayed_bytes": 0.0,
            "migrations": 0, "migrated_bytes": 0.0,
            "migration_queue_wait_s": 0.0,
            "replication_pushes": 0, "replications": 0,
            "replicated_bytes": 0.0, "pushes_deferred": 0,
            "pushes_abandoned": 0, "chained_submits": 0,
            "pressure_events": 0, "resolved_evict": 0, "resolved_spill": 0,
            "resolved_recompute": 0, "unresolved": 0,
        }
        self._records: List[dict] = []
        self._migration_seq = 0
        # speculative pushes in flight: group -> receiver rids (cleared
        # on delivery/drop, so a group is pushed at most once per target)
        self._push_inflight: Dict[int, Set[int]] = {}
        # closed-loop chains: parent session_key -> (follow-up, think_s)
        self._chained: Dict[int, Tuple[FleetRequest, float]] = {}
        # peak gauges (satellite: the fleet report used to sample these
        # after teardown, reporting 0s for any drained run)
        self.peak_directory_groups = 0
        self.peak_load = 0

    # -- submission ---------------------------------------------------------

    def submit(self, req: FleetRequest) -> None:
        sid = req.session_key
        if sid in self.sessions:
            raise ValueError(f"duplicate session_key {sid}")
        self.sessions[sid] = _Session(sid=sid, req=req)
        self.stats["submitted"] += 1
        self.queue.push(Event(req.arrival_s, EventKind.ARRIVAL, -1, key=sid))
        if req.abandon_after_s is not None:
            self.queue.push(Event(req.arrival_s + req.abandon_after_s,
                                  EventKind.ABANDON, -1, key=sid))

    def chain(self, parent_key: int, req: FleetRequest,
              think_s: float) -> None:
        """Closed-loop follow-up: when session ``parent_key`` finishes,
        ``req`` arrives ``think_s`` after the *completion instant* (its
        own ``arrival_s`` is ignored) — the arrival process is shaped by
        achieved latency, not a pre-drawn schedule. An abandoned parent
        drops its whole chain. Deterministic: the re-arrival time is
        derived from the simulated completion, and the scenario pre-draws
        ``think_s``, so no randomness depends on execution order."""
        if think_s < 0:
            raise ValueError(f"think_s must be >= 0, got {think_s}")
        if parent_key in self._chained:
            raise ValueError(f"session {parent_key} already has a chained "
                             "follow-up")
        self._chained[parent_key] = (req, think_s)

    def _drop_chain(self, sid: int) -> None:
        nxt = self._chained.pop(sid, None)
        while nxt is not None:
            nxt = self._chained.pop(nxt[0].session_key, None)

    # -- byte model ---------------------------------------------------------

    def _page_align(self, tokens: int) -> int:
        return (tokens // self.cfg.page_tokens) * self.cfg.page_tokens

    def _read_bw(self, tier: str) -> float:
        tech = {"hot": self.hot, "warm": self.warm, "cold": self.cold}[tier]
        return tech.read_bw_gbps * 1e9

    def _integrate_retention(self, rep: _Replica, t: float) -> None:
        """Charge refresh traffic for the interval since this replica last
        advanced: DRAM hot rows refresh on their interval; the MRM warm
        tier only refreshes if its technology demands it (usually not —
        that absence is the paper's density/energy discount)."""
        dt = t - rep.last_integrated
        if dt <= 0:
            return
        rep.last_integrated = t
        if self.hot.refresh_interval_s:
            self.stats["hot_refresh_bytes"] += (
                rep.hot_live * dt / self.hot.refresh_interval_s)
        if self.warm.refresh_interval_s:
            self.stats["warm_refresh_bytes"] += (
                rep.warm_live * dt / self.warm.refresh_interval_s)

    # -- pressure chain -----------------------------------------------------

    def _register_group(self, rep: _Replica, s: _Session, t: float) -> None:
        """Register/extend the shared prefix this session just computed,
        demoting its bytes from the hot working set into the retention
        plane. Capacity pressure resolves through the same policy chain
        as the real MemoryPlane: evict-LRU → spill-to-cold → recompute."""
        c = self.cfg
        pages = self._page_align(s.req.shared_tokens) // c.page_tokens
        if pages <= 0:
            return
        g = rep.groups.get(s.req.group)
        if g is not None and g.pages >= pages:
            return
        delta_pages = pages - (g.pages if g else 0)
        delta = float(delta_pages * c.page_tokens * c.kv_bytes_per_token)
        tier = g.tier if g else "warm"
        if tier == "warm" and rep.warm_live + delta > c.warm_capacity_bytes:
            self.stats["pressure_events"] += 1
            self._evict_lru(rep, delta - (c.warm_capacity_bytes
                                          - rep.warm_live))
            if rep.warm_live + delta <= c.warm_capacity_bytes + _EPS:
                self.stats["resolved_evict"] += 1
            elif rep.cold_live + delta <= c.cold_capacity_bytes:
                tier = "cold"
                self.stats["resolved_spill"] += 1
            else:
                self.stats["resolved_recompute"] += 1
                return  # nobody registers; next borrower recomputes
        elif tier == "cold" and rep.cold_live + delta > c.cold_capacity_bytes:
            self.stats["pressure_events"] += 1
            self.stats["resolved_recompute"] += 1
            return
        # move the shared delta out of this session's hot working set
        moved = min(s.hot_bytes, delta)
        s.hot_bytes -= moved
        rep.hot_live -= moved
        if tier == "warm":
            rep.warm_live += delta
        else:
            rep.cold_live += delta
        self.stats["reprogram_bytes"] += delta
        self.stats["reprogram_events"] += 1
        if g is None:
            g = _Group(group=s.req.group, pages=pages, bytes=delta,
                       tier=tier, last_access=t)
            rep.groups[s.req.group] = g
            # session keeps decoding against the pages it just registered
            if s.pinned_group < 0:
                g.pins += 1
                s.pinned_group = s.req.group
        else:
            g.pages = pages
            g.bytes += delta
            g.last_access = t
        self._dir_add(s.req.group, rep.rid)

    def _dir_add(self, group: int, rid: int) -> None:
        self.directory.add(group, rid)
        # ownership gained by any path (own compute, demand migration,
        # push delivery) cancels a pending speculative push to this rid
        inflight = self._push_inflight.get(group)
        if inflight is not None:
            inflight.discard(rid)
        n = len(self.directory)
        if n > self.peak_directory_groups:
            self.peak_directory_groups = n

    def _evict_lru(self, rep: _Replica, need: float) -> float:
        """Evict unpinned warm groups, LRU-first, until ``need`` bytes are
        freed or no candidates remain. Pinned groups are untouchable —
        the 'pinned prefixes never decay while referenced' invariant."""
        freed = 0.0
        cands = sorted(
            (g for g in rep.groups.values()
             if g.pins == 0 and g.tier == "warm"),
            key=lambda g: (g.last_access, g.group))
        for g in cands:
            if freed >= need:
                break
            self._drop_group(rep, g)
            freed += g.bytes
        return freed

    def _drop_group(self, rep: _Replica, g: _Group) -> None:
        assert g.pins == 0, "dropping a pinned prefix group"
        if g.tier == "warm":
            rep.warm_live -= g.bytes
        else:
            rep.cold_live -= g.bytes
        del rep.groups[g.group]
        self.directory.discard(g.group, rep.rid)

    # -- routing + migration ------------------------------------------------

    def _least_loaded(self) -> _Replica:
        return min(self.replicas, key=lambda r: (r.load(), r.rid))

    def _route(self, req: FleetRequest, t: float) -> _Replica:
        """Route-first / migrate-on-miss (DESIGN §7): prefer a directory
        owner of the request's group; if every owner is overloaded past
        ``migrate_load_gap`` vs the fleet minimum, send the session to the
        least-loaded replica and pull the prefix over the fabric. Every
        directory match also bumps the group's fleet-wide hit count — the
        predictive replicator's threshold signal (DESIGN §13)."""
        owners = self.directory.owners(req.group)
        best = self._least_loaded()
        if not owners or self._page_align(req.shared_tokens) <= 0:
            return best
        hits = self.directory.hit(req.group)
        if (self.cfg.replicate_threshold is not None
                and self.cfg.n_replicas > 1
                and hits >= self.cfg.replicate_threshold):
            self._maybe_replicate(req.group, t)
        owner = min((self.replicas[r] for r in owners),
                    key=lambda r: (r.load(), r.rid))
        if owner.load() - best.load() <= self.cfg.migrate_load_gap:
            return owner
        if self.cfg.migrate_prefixes and req.group not in best.groups:
            self._migrate(owner, best, req.group, t)
        return best

    def _migrate(self, src: _Replica, dst: _Replica, group: int,
                 t: float) -> None:
        """Demand pull: reserve the fabric path immediately (donor
        up-link + receiver down-link + one core channel) — speculative
        pushes queued behind this instant will see the fabric hot and
        re-defer, which is exactly how demand traffic preempts them."""
        g = src.groups[group]
        start, done = self.fabric.reserve(src.rid, dst.rid, int(g.bytes), t)
        self._migration_seq += 1
        self.queue.push(Event(done, EventKind.MIGRATION_DELIVERY, dst.rid,
                              key=self._migration_seq,
                              info=(group, g.pages, int(g.bytes), 0)))
        self.stats["migrations"] += 1
        self.stats["migrated_bytes"] += g.bytes
        self.stats["migration_queue_wait_s"] += start - t

    def _maybe_replicate(self, group: int, t: float) -> None:
        """Schedule speculative pushes so ``1 + replicate_copies``
        replicas hold the group. Pushes are REPLICATION_PUSH events — the
        lowest event priority, so at any instant every demand-side fabric
        reservation lands first and the push handler sees (and yields to)
        it."""
        owners = self.directory.owners(group)
        if not owners:
            return
        inflight = self._push_inflight.setdefault(group, set())
        need = self.cfg.replicate_copies + 1 - len(owners) - len(inflight)
        if need <= 0:
            return
        targets = sorted(
            (r for r in self.replicas
             if r.rid not in owners and r.rid not in inflight),
            key=lambda r: (r.load(), r.rid))[:need]
        for rep in targets:
            inflight.add(rep.rid)
            self.stats["replication_pushes"] += 1
            self.queue.push(Event(t, EventKind.REPLICATION_PUSH, rep.rid,
                                  key=group))

    def _on_replication_push(self, ev: Event) -> None:
        """Execute (or re-defer) one speculative push. A hot fabric means
        demand traffic reserved the path first: the push yields, retrying
        at the projected free instant, up to ``push_max_defers`` times."""
        group = ev.key
        defers = ev.info[0] if ev.info else 0
        inflight = self._push_inflight.setdefault(group, set())
        owners = self.directory.owners(group)
        if not owners or ev.replica in owners:
            inflight.discard(ev.replica)
            return  # group evicted fleet-wide / receiver already owns it
        donor = min((self.replicas[r] for r in owners),
                    key=lambda r: (r.load(), r.rid))
        if self.fabric.hot(donor.rid, ev.replica, ev.time):
            self.stats["pushes_deferred"] += 1
            if defers + 1 >= self.cfg.push_max_defers:
                self.stats["pushes_abandoned"] += 1
                inflight.discard(ev.replica)
                return
            free = self.fabric.free_at(donor.rid, ev.replica, ev.time)
            self.queue.push(Event(free, EventKind.REPLICATION_PUSH,
                                  ev.replica, key=group,
                                  info=(defers + 1,)))
            return
        g = donor.groups[group]
        start, done = self.fabric.reserve(donor.rid, ev.replica,
                                          int(g.bytes), ev.time)
        self._migration_seq += 1
        self.queue.push(Event(done, EventKind.MIGRATION_DELIVERY, ev.replica,
                              key=self._migration_seq,
                              info=(group, g.pages, int(g.bytes), 1)))
        self.stats["replications"] += 1
        self.stats["replicated_bytes"] += g.bytes
        # stays in _push_inflight until the delivery installs ownership

    # -- event handlers -----------------------------------------------------

    def _on_arrival(self, ev: Event) -> None:
        s = self.sessions[ev.key]
        rep = self._route(s.req, ev.time)
        s.replica = rep.rid
        rep.queue.append(s.sid)
        load = rep.load()
        if load > self.peak_load:
            self.peak_load = load
        self._ensure_service(rep, ev.time)

    def _on_migration_delivery(self, ev: Event) -> None:
        group, pages, nbytes = ev.info[:3]
        inflight = self._push_inflight.get(group)
        if inflight is not None:
            inflight.discard(ev.replica)
        rep = self.replicas[ev.replica]
        g = rep.groups.get(group)
        if g is not None and g.pages >= pages:
            return  # a racing registration already owns a longer prefix
        if rep.warm_live + nbytes > self.cfg.warm_capacity_bytes:
            self.stats["pressure_events"] += 1
            self._evict_lru(rep, nbytes - (self.cfg.warm_capacity_bytes
                                           - rep.warm_live))
            if rep.warm_live + nbytes > self.cfg.warm_capacity_bytes + _EPS:
                self.stats["resolved_recompute"] += 1
                return  # no room: drop the transfer, borrowers recompute
            self.stats["resolved_evict"] += 1
        if g is None:
            rep.groups[group] = _Group(
                group=group, pages=pages, bytes=float(nbytes), tier="warm",
                last_access=ev.time, available_at=ev.time)
            rep.warm_live += nbytes
        else:
            if g.tier == "cold":
                rep.cold_live -= g.bytes
                rep.warm_live += g.bytes
                g.tier = "warm"
            delta = float(nbytes) - g.bytes
            g.pages, g.bytes, g.available_at = pages, float(nbytes), ev.time
            rep.warm_live += delta
        # arrival re-programs retention on the receiving device (§8)
        self.stats["reprogram_bytes"] += nbytes
        self.stats["reprogram_events"] += 1
        self._dir_add(group, rep.rid)

    def _on_abandon(self, ev: Event) -> None:
        s = self.sessions[ev.key]
        if s.phase in ("done", "abandoned"):
            return
        self._drop_chain(s.sid)
        rep = self.replicas[s.replica]
        if s.phase in ("prefill", "decode"):
            rep.active.pop(s.sid, None)
            rep.hot_live -= s.hot_bytes
            s.hot_bytes = 0.0
            self._unpin(rep, s, ev.time)
        s.phase = "abandoned"
        self.stats["abandoned"] += 1

    def _on_retention_decay(self, ev: Event) -> None:
        rep = self.replicas[ev.replica]
        rep.decay_next = None
        expired = [g for g in rep.groups.values()
                   if g.pins == 0
                   and ev.time - g.last_access > self.cfg.cold_ttl_s - _EPS]
        for g in sorted(expired, key=lambda g: g.group):
            self.stats["decayed_bytes"] += g.bytes
            self._drop_group(rep, g)
        self._schedule_decay(rep)

    def _on_scrub(self, ev: Event) -> None:
        rep = self.replicas[ev.replica]
        self.stats["scrub_bytes"] += rep.warm_live + rep.cold_live
        # recur only while the fleet still has work — scrubbing an
        # otherwise-quiet fleet forever would never quiesce
        if self.queue:
            self.queue.push(Event(ev.time + self.cfg.scrub_interval_s,
                                  EventKind.SCRUB_DUE, rep.rid))

    # -- replica service rounds ---------------------------------------------

    def _ensure_service(self, rep: _Replica, t: float) -> None:
        if rep.service_pending:
            return
        rep.service_pending = True
        rep.round_counter += 1
        self.queue.push(Event(max(rep.now, t), EventKind.CHUNK_COMPLETE,
                              rep.rid, key=rep.round_counter))

    def _unpin(self, rep: _Replica, s: _Session, t: float) -> None:
        if s.pinned_group < 0:
            return
        g = rep.groups.get(s.pinned_group)
        s.pinned_group = -1
        if g is None:
            return
        g.pins -= 1
        g.last_access = t
        if g.pins == 0:
            self._schedule_decay(rep, g.last_access + self.cfg.cold_ttl_s)

    def _schedule_decay(self, rep: _Replica,
                        due: Optional[float] = None) -> None:
        if due is None:
            dues = [g.last_access + self.cfg.cold_ttl_s
                    for g in rep.groups.values() if g.pins == 0]
            if not dues:
                return
            due = min(dues)
        due = max(due, self.queue.last_time)
        if rep.decay_next is not None and rep.decay_next <= due + _EPS:
            return
        rep.decay_next = due
        self.queue.push(Event(due, EventKind.RETENTION_DECAY, rep.rid))

    def _admit(self, rep: _Replica, t: float) -> None:
        c = self.cfg
        admitted = 0
        while (rep.queue and len(rep.active) < c.slots_per_replica
               and admitted < c.max_prefills_per_round):
            s = self.sessions[rep.queue[0]]
            if s.phase == "abandoned":
                rep.queue.popleft()
                continue
            need = float((s.req.shared_tokens + s.req.unique_tokens
                          + s.req.max_new_tokens) * c.kv_bytes_per_token)
            if rep.hot_live + need > c.hot_capacity_bytes and rep.active:
                break  # backpressure: wait for running sessions to drain
            if rep.hot_live + need > c.hot_capacity_bytes:
                raise NonQuiescentError(
                    f"session {s.sid} needs {need:.0f}B hot KV > capacity "
                    f"{c.hot_capacity_bytes:.0f}B on replica {rep.rid}")
            rep.queue.popleft()
            admitted += 1
            g = rep.groups.get(s.req.group)
            match = 0
            if g is not None and g.available_at <= t + _EPS:
                match = min(g.pages * c.page_tokens,
                            self._page_align(s.req.shared_tokens))
                if match > 0:
                    g.pins += 1
                    g.hits += 1
                    g.last_access = t
                    s.pinned_group = s.req.group
                    if not g.hot and g.hits >= 2:
                        # observed reuse programs long retention (§5)
                        g.hot = True
                        self.stats["reprogram_bytes"] += g.bytes
                        self.stats["reprogram_events"] += 1
                    # zero-copy splice: matched pages are *read in place*
                    key = ("kv_read_bytes_warm" if g.tier == "warm"
                           else "kv_read_bytes_cold")
                    self.stats[key] += match * c.kv_bytes_per_token
            s.match_tokens = match
            s.prefill_done = match
            s.phase = "prefill"
            self.stats["saved_tokens"] += match
            rep.active[s.sid] = s

    def _on_service(self, ev: Event) -> None:
        """One replica service round: apply the work planned at the
        previous round (chunk completions, one decode token per active
        decoder), then plan and schedule the next round. The event's
        timestamp is the *completion* instant of the planned work, so
        TTFT/ITL land at byte-model-accurate times."""
        c = self.cfg
        rep = self.replicas[ev.replica]
        rep.now = ev.time
        self._integrate_retention(rep, ev.time)
        t = ev.time
        # 1. apply the round planned at the previous service event
        for sid, toks in rep.pending_prefills:
            s = self.sessions.get(sid)
            if s is None or s.phase != "prefill":
                continue
            s.prefill_done += toks
            nbytes = float(toks * c.kv_bytes_per_token)
            s.hot_bytes += nbytes
            rep.hot_live += nbytes
            self.stats["prefill_tokens"] += toks
            self.stats["kv_write_bytes"] += nbytes
            total = s.req.shared_tokens + s.req.unique_tokens
            if s.prefill_done >= s.req.shared_tokens:
                self._register_group(rep, s, t)
            if s.prefill_done >= total:
                s.phase = "decode"
        for sid in rep.pending_decodes:
            s = self.sessions.get(sid)
            if s is None or s.phase != "decode":
                continue
            s.generated += 1
            nbytes = float(c.kv_bytes_per_token)
            s.hot_bytes += nbytes
            rep.hot_live += nbytes
            self.stats["decoded_tokens"] += 1
            self.stats["kv_write_bytes"] += nbytes
            if s.first_token_at < 0:
                s.first_token_at = t
            if s.generated >= s.req.max_new_tokens:
                self._finish(rep, s, t)
        rep.pending_prefills = []
        rep.pending_decodes = []
        # 2. plan the next round
        self._admit(rep, t)
        duration = 0.0
        any_prefill = False
        for s in rep.active.values():
            total = s.req.shared_tokens + s.req.unique_tokens
            if s.phase == "prefill":
                if len(rep.pending_prefills) >= c.max_prefills_per_round:
                    continue
                toks = min(c.chunk_tokens, total - s.prefill_done)
                rep.pending_prefills.append((s.sid, toks))
                any_prefill = True
                duration += toks * c.kv_bytes_per_token / (
                    self.hot.write_bw_gbps * 1e9)
            elif s.phase == "decode":
                rep.pending_decodes.append(s.sid)
                duration += s.hot_bytes / (self.hot.read_bw_gbps * 1e9)
                g = rep.groups.get(s.pinned_group)
                if g is not None:
                    span = min(g.pages * c.page_tokens, s.match_tokens)
                    duration += (span * c.kv_bytes_per_token
                                 / self._read_bw(g.tier))
                duration += c.kv_bytes_per_token / (
                    self.hot.write_bw_gbps * 1e9)
                self.stats["kv_read_bytes_hot"] += s.hot_bytes
        rep.pending_prefills.sort()
        rep.pending_decodes.sort()
        if not rep.pending_prefills and not rep.pending_decodes:
            rep.service_pending = False
            return
        duration += c.weight_bytes / (self.hot.read_bw_gbps * 1e9)
        rep.round_counter += 1
        kind = (EventKind.CHUNK_COMPLETE if any_prefill
                else EventKind.DECODE_ROUND)
        self.queue.push(Event(t + duration, kind, rep.rid,
                              key=rep.round_counter))

    def _finish(self, rep: _Replica, s: _Session, t: float) -> None:
        s.phase = "done"
        s.finished_at = t
        rep.active.pop(s.sid, None)
        rep.hot_live -= s.hot_bytes
        s.hot_bytes = 0.0
        self._unpin(rep, s, t)
        self.stats["finished"] += 1
        gen = s.generated
        itl = ((t - s.first_token_at) / (gen - 1)) if gen > 1 else 0.0
        self._records.append({
            "request_id": s.sid,
            "ttft": s.first_token_at - s.req.arrival_s,
            "itl": itl,
            "generated": gen,
        })
        nxt = self._chained.pop(s.sid, None)
        if nxt is not None:
            # closed-loop client: the follow-up arrives think-time after
            # the completion the client actually observed
            follow, think = nxt
            self.stats["chained_submits"] += 1
            self.submit(replace(follow, arrival_s=t + think))

    # -- driver -------------------------------------------------------------

    _HANDLERS = {
        EventKind.ARRIVAL: "_on_arrival",
        EventKind.MIGRATION_DELIVERY: "_on_migration_delivery",
        EventKind.ABANDON: "_on_abandon",
        EventKind.RETENTION_DECAY: "_on_retention_decay",
        EventKind.SCRUB_DUE: "_on_scrub",
        EventKind.CHUNK_COMPLETE: "_on_service",
        EventKind.DECODE_ROUND: "_on_service",
        EventKind.REPLICATION_PUSH: "_on_replication_push",
    }

    def run(self, max_events: Optional[int] = None,
            on_stall: str = "raise") -> dict:
        """Drain the event queue to quiescence. ``max_events`` bounds the
        run; hitting it with events still pending raises
        :class:`NonQuiescentError` (``on_stall="raise"``) or returns the
        report with ``quiesced=False`` (``on_stall="report"``)."""
        if self.cfg.scrub_interval_s and self.queue:
            for rep in self.replicas:
                self.queue.push(Event(self.cfg.scrub_interval_s,
                                      EventKind.SCRUB_DUE, rep.rid))
        processed = 0
        while self.queue:
            if max_events is not None and processed >= max_events:
                report = self.report(quiesced=False)
                if on_stall == "report":
                    return report
                raise NonQuiescentError(
                    f"fleet not quiescent after {processed} events: "
                    f"{len(self.queue)} still pending", report)
            ev = self.queue.pop()
            self.trace.add(ev)
            getattr(self, self._HANDLERS[ev.kind])(ev)
            processed += 1
        return self.report(quiesced=True)

    # -- invariants + reporting ---------------------------------------------

    def check(self) -> None:
        """Conservation invariants at an event boundary. The property
        suite calls this after every event; any drift between the ledgers
        and ground truth (recomputed from sessions/groups) is a bug."""
        for rep in self.replicas:
            hot = sum(s.hot_bytes for s in rep.active.values())
            assert abs(hot - rep.hot_live) < 1.0, (
                f"replica {rep.rid} hot ledger {rep.hot_live} != {hot}")
            for g in rep.groups.values():
                pins = sum(1 for s in rep.active.values()
                           if s.pinned_group == g.group)
                assert pins == g.pins, (
                    f"group {g.group} pins {g.pins} != {pins} referents")
            warm = sum(g.bytes for g in rep.groups.values()
                       if g.tier == "warm")
            cold = sum(g.bytes for g in rep.groups.values()
                       if g.tier == "cold")
            assert abs(warm - rep.warm_live) < 1.0, (
                f"replica {rep.rid} warm ledger {rep.warm_live} != {warm}")
            assert abs(cold - rep.cold_live) < 1.0, (
                f"replica {rep.rid} cold ledger {rep.cold_live} != {cold}")
        st = self.stats
        assert st["pressure_events"] == (
            st["resolved_evict"] + st["resolved_spill"]
            + st["resolved_recompute"] + st["unresolved"])
        # every byte a transfer moved is metered on the fabric exactly
        # once, and split exactly across the demand/speculative ledgers
        assert abs(self.fabric.bytes_total
                   - (st["migrated_bytes"] + st["replicated_bytes"])) < 1.0, (
            f"fabric bytes {self.fabric.bytes_total} != migrated "
            f"{st['migrated_bytes']} + replicated {st['replicated_bytes']}")
        for group, inflight in self._push_inflight.items():
            owners = (self.directory.owners(group) or set())
            live = inflight & owners
            assert not live, (
                f"group {group} push in flight to owners {live}")
        for pk in self._chained:
            s = self.sessions.get(pk)
            assert s is not None and s.phase not in ("done", "abandoned"), (
                f"chained follow-up parent {pk} already terminal")
        for sid, s in self.sessions.items():
            if s.phase in ("done", "abandoned"):
                assert s.hot_bytes == 0.0, f"finished {sid} leaks hot bytes"
                assert s.pinned_group < 0, f"finished {sid} leaks a pin"

    def report(self, quiesced: bool = True) -> dict:
        st = dict(self.stats)
        demanded = st["prefill_tokens"] + st["saved_tokens"]
        reuse = st["saved_tokens"] / demanded if demanded else 0.0
        ledger_imbalance = st["pressure_events"] - (
            st["resolved_evict"] + st["resolved_spill"]
            + st["resolved_recompute"] + st["unresolved"])
        loads = [r.load() for r in self.replicas]
        return {
            "quiesced": quiesced,
            "pending_events": len(self.queue),
            "pending_sessions": sum(
                1 for s in self.sessions.values()
                if s.phase not in ("done", "abandoned")),
            "n_replicas": self.cfg.n_replicas,
            "sessions": {
                "submitted": st["submitted"], "finished": st["finished"],
                "abandoned": st["abandoned"],
            },
            "slo": latency_slo(self._records),
            "fleet": {
                "prefill_tokens": st["prefill_tokens"],
                "saved_tokens": st["saved_tokens"],
                "reuse_frac": reuse,
                "decoded_tokens": st["decoded_tokens"],
                "migrations": st["migrations"],
                "migrated_bytes": st["migrated_bytes"],
                "migration_queue_wait_s": st["migration_queue_wait_s"],
                "chained_submits": st["chained_submits"],
                # peak gauges, tracked while events fire — the old
                # at-teardown samples were always 0 on a drained fleet
                "directory_groups_peak": self.peak_directory_groups,
                "peak_load": self.peak_load,
                # at-drain residue (directory entries that survived
                # decay/eviction; loads are 0 iff quiesced)
                "directory_groups_final": len(self.directory),
                "max_load": max(loads), "min_load": min(loads),
            },
            "replication": {
                "threshold": self.cfg.replicate_threshold,
                "copies": self.cfg.replicate_copies,
                "pushes_scheduled": st["replication_pushes"],
                "replications": st["replications"],
                "replicated_bytes": st["replicated_bytes"],
                "pushes_deferred": st["pushes_deferred"],
                "pushes_abandoned": st["pushes_abandoned"],
            },
            "directory": self.directory.shard_counters(),
            "fabric": self.fabric.report(),
            "retention": {
                "hot_refresh_bytes": st["hot_refresh_bytes"],
                "warm_refresh_bytes": st["warm_refresh_bytes"],
                "scrub_bytes": st["scrub_bytes"],
                "reprogram_bytes": st["reprogram_bytes"],
                "reprogram_events": st["reprogram_events"],
                "decayed_bytes": st["decayed_bytes"],
                "kv_read_bytes_warm": st["kv_read_bytes_warm"],
                "kv_read_bytes_cold": st["kv_read_bytes_cold"],
            },
            "pressure": {
                "events": st["pressure_events"],
                "resolved_evict": st["resolved_evict"],
                "resolved_spill": st["resolved_spill"],
                "resolved_recompute": st["resolved_recompute"],
                "unresolved": st["unresolved"],
                "ledger_imbalance": ledger_imbalance,
            },
            "trace": self.trace.as_dict(),
        }


def _pct(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def latency_slo(records: List[dict]) -> dict:
    """TTFT/ITL p50/p95/p99 over finished-session records — same shape as
    ``repro.serving.engine.latency_percentiles`` but dependency-free so
    the analytic plane never imports JAX."""
    out = {}
    for key in ("ttft", "itl"):
        vals = sorted(r[key] for r in records)
        out[key] = {"p50": _pct(vals, 0.50), "p95": _pct(vals, 0.95),
                    "p99": _pct(vals, 0.99), "n": len(vals)}
    return out
