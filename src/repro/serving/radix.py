"""Token-level radix tree over page-aligned KV prefixes.

The paper's core claim is that inference soft state is write-once/read-many
and the *system* should manage its retention and placement (§2.2, §4);
shared prompt prefixes are the purest instance.  This module is the one
prefix abstraction every serving layer shares (DESIGN.md §6): the
`PagedKVManager` hangs its shared pages off the tree, the engine hangs its
compute-plane cache snapshots off it (the `payload` slot), the scheduler
scores admissions by `match_len`, and the cluster frontend routes by it.

Shape (after the sglang RadixCache design, adapted to page granularity):

- every node owns a run of whole pages — its `key` is the token sequence
  those pages cover, `len(key) % page_tokens == 0` always;
- children are keyed by their first page (a `page_tokens`-tuple), so a
  walk takes one dict lookup per page and splits always land on page
  boundaries (the match granularity the memory plane needs);
- `lock_ref` pins a node and all its ancestors while a live session holds
  its pages — pinned nodes are never evicted;
- eviction is leaf-LRU: only unlocked leaves are candidates, the
  least-recently-accessed goes first, and freeing a leaf may expose its
  parent as the next candidate;
- `hits` counts how often a node's tokens were reused — the observed-reuse
  signal the manager's retention programming (DCM §4) keys off;
- `payload` is an opaque compute-plane handle (the engine stores the donor
  slot's ring-cache snapshot here so a hit can skip prefill compute).

The tree never touches the memory simulator: page lifetime side effects
(refcounts, region release) belong to the caller.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple


def _page_key(tokens, start: int, page_tokens: int) -> tuple:
    """Hashable identity of one page's tokens (multi-codebook tokens are
    per-position sequences; flatten each to a tuple)."""
    page = tokens[start:start + page_tokens]
    return tuple(t if isinstance(t, (int,)) and not isinstance(t, bool)
                 else (int(t) if not hasattr(t, "__len__")
                       else tuple(int(x) for x in t))
                 for t in page)


def _tok_key(t):
    """One token's hashable identity (same normalization as _page_key)."""
    if isinstance(t, int) and not isinstance(t, bool):
        return t
    if hasattr(t, "__len__"):
        return tuple(int(x) for x in t)
    return int(t)


class RadixNode:
    __slots__ = ("key", "pages", "children", "parent", "lock_ref",
                 "last_access", "hits", "payload", "hot", "migrated",
                 "evicted_path")

    def __init__(self, key: tuple, pages: List[Any],
                 parent: Optional["RadixNode"], now: float):
        self.key = key                      # page-aligned token run
        self.pages = pages                  # one Page per page_tokens run
        self.children: Dict[tuple, "RadixNode"] = {}
        self.parent = parent
        self.lock_ref = 0                   # live sessions pinning this path
        self.last_access = now
        self.hits = 0                       # reuse count (retention signal)
        self.payload: Any = None            # opaque compute-plane handle
        self.hot = False                    # promoted to long retention
        self.migrated = False               # grafted from another replica
        self.evicted_path: Optional[tuple] = None  # full key at eviction

    @property
    def n_tokens(self) -> int:
        return len(self.key)

    def is_leaf(self) -> bool:
        return not self.children


@dataclass
class PrefixMatch:
    """Result of a longest-prefix walk. ``tokens`` is page-aligned by
    construction; a sub-page **tail** (vLLM-style, DESIGN.md §9) may
    extend it: ``tail_tokens`` more tokens of the prompt agree with the
    first page of ``tail_node`` (a child of ``node``), always strictly
    less than one page — a fully-matching page would have been consumed
    by the walk itself."""
    tokens: int = 0                      # matched token count (page-aligned)
    pages: List[Any] = field(default_factory=list)
    node: Optional[RadixNode] = None     # deepest matched node (lock target)
    payload: Any = None                  # nearest compute handle covering it
    tail_tokens: int = 0                 # sub-page tail beyond the boundary
    tail_node: Optional[RadixNode] = None  # child holding the tail's page


class RadixKVIndex:
    """Radix tree of page-aligned prefixes with leaf-LRU eviction.

    Invariants the tests rely on (property-tested in tests/test_radix.py):
    every node's key length is a whole number of pages and equals
    ``page_tokens * len(node.pages)``; a child's first page is its key in
    the parent's ``children`` dict (walks are one lookup per page); locked
    paths (``lock_ref > 0``) are never evicted; ``pop_leaf`` only detaches
    unlocked leaves and stamps ``evicted_path`` with the exact run the
    leaf covered, so callers can invalidate fleet-directory ownership."""

    def __init__(self, page_tokens: int):
        if page_tokens < 1:
            raise ValueError("page_tokens must be >= 1")
        self.page_tokens = page_tokens
        self.root = RadixNode((), [], None, 0.0)
        self.root.lock_ref = 1   # the root itself is never an eviction victim

    # -- walking --------------------------------------------------------
    def _pages_in_common(self, key: tuple, tokens, start: int) -> int:
        """Whole pages of `key` matching `tokens[start:]` (page units)."""
        pt = self.page_tokens
        n_key_pages = len(key) // pt
        avail_pages = (len(tokens) - start) // pt
        j = 0
        while j < min(n_key_pages, avail_pages):
            if _page_key(key, j * pt, pt) != _page_key(tokens, start + j * pt, pt):
                break
            j += 1
        return j

    def _split(self, node: RadixNode, n_pages: int, now: float) -> RadixNode:
        """Split `node` so its first `n_pages` pages become a new parent;
        the remainder stays on `node` (payload/hits travel with the deep
        half — they describe the full original run)."""
        pt = self.page_tokens
        head = RadixNode(node.key[:n_pages * pt], node.pages[:n_pages],
                         node.parent, now)
        head.lock_ref = node.lock_ref       # pins cover the whole path
        head.hits = node.hits
        head.hot = node.hot
        head.migrated = node.migrated       # provenance covers the whole run
        head.last_access = node.last_access
        parent = node.parent
        del parent.children[_page_key(node.key, 0, pt)]
        parent.children[_page_key(head.key, 0, pt)] = head
        node.key = node.key[n_pages * pt:]
        node.pages = node.pages[n_pages:]
        node.parent = head
        head.children[_page_key(node.key, 0, pt)] = node
        return head

    def match(self, tokens: Sequence, now: float,
              max_tokens: Optional[int] = None,
              bump_hits: bool = True,
              bump_lru: bool = True,
              with_tail: bool = False) -> PrefixMatch:
        """Longest page-aligned prefix of `tokens` present in the tree.
        Splits nodes at the match boundary (so the result's deepest node
        covers exactly the matched run) and bumps LRU stamps and hit
        counts on the matched path. A migration probe passes both bumps
        False: reading a prefix out to move its traffic AWAY is not local
        reuse — it must feed neither the retention signal nor the LRU
        order (or the donor would evict a genuinely-hot local prefix
        first).

        With ``with_tail`` the match also reports the sub-page tail: the
        longest run of tokens past the page-aligned boundary agreeing
        with the first page of one of ``node``'s children (DESIGN.md §9).
        The tail is informational — the caller decides whether to copy
        it — so tail discovery bumps no hit counts or LRU stamps."""
        pt = self.page_tokens
        limit = len(tokens) if max_tokens is None else min(max_tokens, len(tokens))
        page_limit = (limit // pt) * pt
        m = PrefixMatch(node=self.root)
        node = self.root
        while m.tokens < page_limit:
            child = node.children.get(_page_key(tokens, m.tokens, pt))
            if child is None:
                break
            j = self._pages_in_common(child.key, tokens, m.tokens)
            j = min(j, (page_limit - m.tokens) // pt)
            if j == 0:
                break
            if j * pt < len(child.key):
                child = self._split(child, j, now)
            node = child
            m.tokens += j * pt
            m.pages.extend(node.pages)
            m.node = node
        for n in self._path(m.node):
            if bump_lru:
                n.last_access = now
            if m.tokens and bump_hits:
                n.hits += 1
        m.payload = self._nearest_payload(m.node)
        if with_tail and limit > m.tokens:
            m.tail_tokens, m.tail_node = self._tail_of(m.node, tokens,
                                                       m.tokens, limit)
        return m

    def _tail_of(self, node: RadixNode, tokens, start: int,
                 limit: int) -> Tuple[int, Optional[RadixNode]]:
        """Longest sub-page run of ``tokens[start:limit]`` agreeing with
        the first page of one of ``node``'s children. Strictly less than
        one page by construction: a whole matching page would have been
        consumed by the page-aligned walk (or clipped by ``limit``)."""
        best, best_node = 0, None
        for child in node.children.values():
            n = 0
            cap = min(limit - start, len(child.key))
            while n < cap and _tok_key(child.key[n]) == _tok_key(tokens[start + n]):
                n += 1
            if n > best:
                best, best_node = n, child
        return best, best_node

    def match_len(self, tokens: Sequence,
                  max_tokens: Optional[int] = None) -> int:
        """Read-only longest-prefix length in tokens: no splits, no LRU or
        hit-count side effects (scheduler scoring / cluster routing)."""
        pt = self.page_tokens
        limit = len(tokens) if max_tokens is None else min(max_tokens, len(tokens))
        limit = (limit // pt) * pt
        node, matched = self.root, 0
        while matched < limit:
            child = node.children.get(_page_key(tokens, matched, pt))
            if child is None:
                break
            j = self._pages_in_common(child.key, tokens, matched)
            j = min(j, (limit - matched) // pt)
            if j == 0:
                break
            matched += j * pt
            if j * pt < len(child.key):
                break
            node = child
        return matched

    def _nearest_payload(self, node: RadixNode) -> Any:
        """A compute handle valid for a match ending at `node`: any payload
        at or below it (every descendant's prompt starts with this path)."""
        stack = [node]
        while stack:
            n = stack.pop()
            if n.payload is not None:
                return n.payload
            stack.extend(n.children.values())
        return None

    def subtree_payload(self, node: Optional[RadixNode]) -> Any:
        """Public form of the nearest-payload walk rooted at ``node``.
        The engine's sub-page tail reuse needs a payload whose token
        history agrees *through the tail* — any payload in the tail
        child's subtree qualifies, because every prompt below it starts
        with that child's first page (DESIGN.md §9)."""
        return None if node is None else self._nearest_payload(node)

    def payload_candidates(self, node: RadixNode) -> Iterator[Tuple[Any, int]]:
        """Yield ``(payload, holder_root_path_tokens)`` for every payload
        on ``node``'s root path and in its subtree. The holder's root-path
        length is the run the tree vouches for — callers filter on it (the
        engine's per-family snapshot resolution, DESIGN.md §8) so the
        tree-structure knowledge stays in this module."""
        depth = 0
        n = node
        while n is not None:
            depth += n.n_tokens
            n = n.parent
        d, n = depth, node
        while n is not None:                # the path itself, deepest first
            if n.payload is not None:
                yield n.payload, d
            d -= n.n_tokens
            n = n.parent
        stack = [(node, depth)]             # the subtree below
        while stack:
            n, d = stack.pop()
            if n is not node and n.payload is not None:
                yield n.payload, d
            stack.extend((c, d + c.n_tokens) for c in n.children.values())

    @staticmethod
    def _path(node: RadixNode) -> List[RadixNode]:
        out = []
        while node is not None:
            out.append(node)
            node = node.parent
        return out

    def full_key(self, node: RadixNode) -> tuple:
        """Root-to-node token path: the fleet-directory-visible identity
        of the prefix this node completes."""
        parts = []
        while node is not None and node.parent is not None:
            parts.append(node.key)
            node = node.parent
        out: tuple = ()
        for k in reversed(parts):
            out += k
        return out

    # -- insertion ------------------------------------------------------
    def insert(self, tokens: Sequence, pages: List[Any], now: float,
               payload: Any = None) -> Tuple[int, List[Any], RadixNode]:
        """Insert the page-aligned prefix `tokens` (``pages[i]`` covers
        tokens ``[i*pt, (i+1)*pt)``). Existing nodes keep their pages —
        duplicates from a concurrent cold start are NOT swapped in.
        Returns ``(dup_tokens, inserted_pages, deepest_node)``: the caller
        owns the refcount bump for `inserted_pages` (the tree's own
        reference), keeps full ownership of the duplicate pages, and may
        move its session lock to `deepest_node`."""
        pt = self.page_tokens
        n = (min(len(tokens), len(pages) * pt) // pt) * pt
        node, done = self.root, 0
        while done < n:
            child = node.children.get(_page_key(tokens, done, pt))
            if child is None:
                break
            j = self._pages_in_common(child.key, tokens, done)
            j = min(j, (n - done) // pt)
            if j == 0:
                break
            if j * pt < len(child.key):
                child = self._split(child, j, now)
            node = child
            node.last_access = now
            done += j * pt
        dup = done
        inserted: List[Any] = []
        if done < n:
            new = RadixNode(tuple(_flat(tokens[done:n])), pages[done // pt:n // pt],
                            node, now)
            node.children[_page_key(tokens, done, pt)] = new
            inserted = list(new.pages)
            node = new
        if payload is not None and node is not self.root and node.payload is None:
            node.payload = payload
        return dup, inserted, node

    def graft(self, tokens: Sequence, pages: List[Any], now: float,
              payload: Any = None, hits: int = 0,
              hot: bool = False) -> Tuple[int, List[Any], RadixNode]:
        """Graft an externally-built path (cross-replica migration): insert
        it and stamp the reuse state it arrived with — the donor's observed
        hit count and hot flag travel with the data, so a migrated-hot
        prefix keeps its retention signal on the receiving replica."""
        dup, inserted, node = self.insert(tokens, pages, now, payload=payload)
        if inserted and node is not self.root:
            node.hits = max(node.hits, hits)
            node.hot = node.hot or hot
            node.migrated = True
        return dup, inserted, node

    # -- pinning --------------------------------------------------------
    def lock(self, node: Optional[RadixNode]) -> None:
        for n in self._path(node):
            n.lock_ref += 1

    def unlock(self, node: Optional[RadixNode]) -> None:
        for n in self._path(node):
            n.lock_ref -= 1
            assert n.lock_ref >= 0 or n is self.root, "unbalanced unlock"

    # -- eviction -------------------------------------------------------
    def evictable_leaves(self) -> List[RadixNode]:
        return [n for n in self.nodes() if n.is_leaf() and n.lock_ref == 0]

    @staticmethod
    def lru_key(node: RadixNode) -> tuple:
        """The one eviction ordering (LRU, key tiebreak for determinism)
        every caller shares — the pressure path must agree with
        :meth:`pop_lru_leaf` or victim selection silently drifts."""
        return (node.last_access, node.key)

    def pop_lru_leaf(self) -> Optional[RadixNode]:
        """Remove and return the least-recently-accessed unlocked leaf
        (its pages' lifetime side effects are the caller's job)."""
        victims = self.evictable_leaves()
        if not victims:
            return None
        return self.pop_leaf(min(victims, key=self.lru_key))

    def pop_leaf(self, node: RadixNode) -> Optional[RadixNode]:
        """Remove a specific unlocked leaf (cold-decay path). The node's
        full root-to-leaf key is captured in ``evicted_path`` before the
        detach, so callers can invalidate fleet-directory ownership."""
        if not node.is_leaf() or node.lock_ref != 0 or node.parent is None:
            return None
        node.evicted_path = self.full_key(node)
        del node.parent.children[_page_key(node.key, 0, self.page_tokens)]
        node.parent = None
        return node

    # -- introspection (tests, reports) ---------------------------------
    def nodes(self) -> Iterator[RadixNode]:
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root:
                yield n
            stack.extend(n.children.values())

    def n_nodes(self) -> int:
        return sum(1 for _ in self.nodes())

    def total_tokens(self) -> int:
        return sum(n.n_tokens for n in self.nodes())

    def total_pages(self) -> int:
        return sum(len(n.pages) for n in self.nodes())


def _flat(tokens) -> list:
    return [t if isinstance(t, int) and not isinstance(t, bool)
            else (int(t) if not hasattr(t, "__len__")
                  else tuple(int(x) for x in t))
            for t in tokens]
