"""Typed events, a deterministic priority queue, and a hashed event trace
— the spine of the event-driven fleet simulator (DESIGN.md §12).

The paper's §4 bet is that retention can be *managed* because inference
traffic has structure: reuse bursts, diurnal lulls, abandonment. Seeing
that structure in simulation requires retention decay, refresh scheduling
and migration queuing to meet realistic *timescales* — which the lockstep
shared-clock rounds of ``ClusterFrontend.step()`` compress away (every
replica advances to the fleet max each round). This module provides the
event plumbing both fleet drivers share:

- :class:`EventKind` — the closed set of typed events: request arrival,
  prefill chunk completion, decode round, cross-replica migration
  delivery, wall-clock retention decay / scrub-due, abandonment timeout,
  and the generic replica step the real-engine driver schedules.
- :class:`EventQueue` — a binary heap whose ordering is **fully
  content-derived**: events sort by ``(time, kind, replica, key)`` where
  ``key`` is caller-supplied identity (session id, migration id, ...),
  never queue insertion order. Two simulations that schedule the same
  events in a different order therefore pop them in the same order —
  the determinism harness asserts trace-hash equality across tie-break
  insertion shuffles (ISSUE 9 satellite).
- :class:`EventTrace` — an incrementally-hashed record of every event
  processed. ``digest()`` is a sha1 over the canonical event tuples, so
  two runs are *bit-identical* iff their digests match; with
  ``record=True`` the concrete tuples are kept for debugging. The hash
  accumulates in O(1) memory, so million-event scenario runs stay cheap.
- :class:`NonQuiescentError` — raised when a ``run_until_idle`` /
  scenario run hits its step or event budget with work still pending
  (the PR 1–8 behavior was a *silent* return at ``max_steps``; ISSUE 9
  makes non-quiescence an explicit error or a flagged report field).
"""
from __future__ import annotations

import hashlib
import heapq
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Iterator, List, Optional, Tuple


class NonQuiescentError(RuntimeError):
    """A simulation run exhausted its step/event budget with requests
    still queued or resident. Carries the partial report so callers that
    *expect* truncation (``on_stall="report"``) can still inspect it."""

    def __init__(self, msg: str, report: Optional[dict] = None):
        super().__init__(msg)
        self.report = report


class EventKind(IntEnum):
    """Typed fleet events. The integer value is the tie-break priority at
    equal timestamps (lower fires first): deliveries land before the
    arrivals that might use them; arrivals enter queues before the step
    that could admit them; decay and abandonment sweep *after* compute at
    the same instant (a request finishing exactly at its abandonment
    deadline finishes)."""
    MIGRATION_DELIVERY = 0
    ARRIVAL = 1
    STEP = 2            # real-engine driver: one ServeEngine.step() due
    CHUNK_COMPLETE = 3  # analytic replica: a prefill chunk finished
    DECODE_ROUND = 4    # analytic replica: one batched decode round
    RETENTION_DECAY = 5  # wall-clock cold-leaf decay sweep
    ABANDON = 6         # abandonment timeout check for one session
    SCRUB_DUE = 7       # periodic retention-plane scrub read (DESIGN §11)
    REPLICATION_PUSH = 8  # speculative prefix push decision (DESIGN §13);
    #                       lowest priority: at an equal timestamp every
    #                       demand-side event (arrivals, their migrations)
    #                       fires first, so pushes see — and yield to —
    #                       the fabric reservations demand traffic made.


@dataclass(frozen=True, order=True)
class Event:
    """One scheduled fleet event. ``key`` is content-derived identity
    (session id, migration id, a per-replica step counter) — the
    tie-breaker beyond (time, kind, replica), so heap order never depends
    on insertion order. ``info`` is free-form trace payload; it only
    participates in ordering as the final dataclass-order tie-break when
    two events collide on the entire ``sort_key`` (still content-derived,
    never insertion order)."""
    time: float
    kind: EventKind
    replica: int
    key: int = 0
    info: Tuple = ()

    @property
    def sort_key(self) -> tuple:
        return (self.time, int(self.kind), self.replica, self.key)


class EventQueue:
    """Deterministic binary heap of :class:`Event`.

    Invariants the tests rely on:

    - **content-derived order** — pop order is exactly sorted
      ``(time, kind, replica, key)``; pushing the same event set in any
      order yields the same pop sequence (tie-break invariance).
    - **monotonic pops** — ``pop()`` never returns an event earlier than
      the last popped time (the fleet clock never runs backwards).
    """

    def __init__(self):
        self._heap: List[tuple] = []
        self.pushed = 0
        self.popped = 0
        self.last_time = 0.0

    def push(self, ev: Event) -> None:
        if ev.time < self.last_time - 1e-12:
            raise ValueError(
                f"event scheduled in the past: {ev.time} < {self.last_time}")
        heapq.heappush(self._heap, (ev.sort_key, ev))
        self.pushed += 1

    def pop(self) -> Event:
        _, ev = heapq.heappop(self._heap)
        self.popped += 1
        self.last_time = max(self.last_time, ev.time)
        return ev

    def peek_time(self) -> Optional[float]:
        return self._heap[0][1].time if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def drain(self) -> Iterator[Event]:
        while self._heap:
            yield self.pop()


@dataclass
class EventTrace:
    """Incrementally sha1-hashed trace of processed events.

    The canonical line for an event is ``time|kind|replica|key|info``
    with the time printed at fixed 9-decimal precision — enough that two
    runs agree iff their float trajectories are bit-identical at the
    event grain, without hashing raw float bits (repr noise). The
    determinism harness (ISSUE 9) asserts ``digest()`` equality across
    reruns and across tie-break insertion orderings; CI pins the smoke
    scenario's digest via the fleet report."""
    record: bool = False
    n_events: int = 0
    events: List[tuple] = field(default_factory=list)

    def __post_init__(self):
        self._h = hashlib.sha1()

    def add(self, ev: Event) -> None:
        line = (f"{ev.time:.9e}|{int(ev.kind)}|{ev.replica}|{ev.key}|"
                f"{ev.info!r}\n")
        self._h.update(line.encode())
        self.n_events += 1
        if self.record:
            self.events.append((ev.time, int(ev.kind), ev.replica, ev.key,
                                ev.info))

    def digest(self) -> str:
        return self._h.hexdigest()

    def as_dict(self) -> dict:
        return {"n_events": self.n_events, "digest": self.digest()}
