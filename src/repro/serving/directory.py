"""Hash-sharded ownership directory for the fleet plane (DESIGN.md §13).

PR 3's ``PrefixDirectory`` was a single process-local dict keyed by full
token tuples — every page-aligned prefix stored its entire token sequence
as the key (unbounded key bytes, one lock domain, O(prefix-length)
comparisons per probe). Production directories shard: this module holds
the generic machinery — fixed-width keys hashed across
:class:`DirectoryShard` partitions, per-shard lookup/update counters that
*prove* the control plane balances, per-entry fleet-wide hit counters
(the predictive replicator's signal), and a delta batch API so an
eviction sweep applies O(changed entries) directory ops in one flush.

Keys are opaque: the cluster frontend uses page-aligned prefix *digests*
(sha1 over page chunks, computed incrementally in one pass — see
``cluster.PrefixDirectory``), the analytic ``FleetSim`` uses integer
group ids. Shard choice avoids Python's randomized ``hash()`` — digests
use their leading bytes, ints a Fibonacci mix — so shard assignment (and
therefore every counter this module reports) is bit-stable across runs.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Set, Tuple

_FIB = 0x9E3779B97F4A7C15  # 2^64 / golden ratio; Fibonacci-hash multiplier


def _mix(key) -> int:
    """Deterministic 64-bit spread of a directory key (bytes digest or
    int group id). Never uses built-in ``hash`` (PYTHONHASHSEED)."""
    if isinstance(key, (bytes, bytearray)):
        return int.from_bytes(key[:8], "big")
    return (int(key) * _FIB) & 0xFFFFFFFFFFFFFFFF


class DirectoryShard:
    """One partition: owner sets + hit counts for its keys, plus the
    lookup/update tallies the load-balance report is built from."""

    __slots__ = ("owners", "hits", "lookups", "updates")

    def __init__(self):
        self.owners: Dict[object, Set[int]] = {}
        self.hits: Dict[object, int] = {}
        self.lookups = 0
        self.updates = 0


class ShardedDirectory:
    """Ownership map hash-partitioned over :class:`DirectoryShard`s."""

    def __init__(self, n_shards: int = 8):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards
        self.shards: List[DirectoryShard] = [DirectoryShard()
                                             for _ in range(n_shards)]
        self._len = 0
        self.delta_batches = 0
        self.delta_ops = 0

    def shard_of(self, key) -> int:
        return _mix(key) % self.n_shards

    def _shard(self, key) -> DirectoryShard:
        return self.shards[self.shard_of(key)]

    # -- single-key ops -----------------------------------------------------

    def add(self, key, replica: int) -> None:
        sh = self._shard(key)
        sh.updates += 1
        owners = sh.owners.get(key)
        if owners is None:
            sh.owners[key] = {replica}
            sh.hits[key] = 0
            self._len += 1
        else:
            owners.add(replica)

    def discard(self, key, replica: int) -> None:
        sh = self._shard(key)
        sh.updates += 1
        owners = sh.owners.get(key)
        if owners is None:
            return
        owners.discard(replica)
        if not owners:
            del sh.owners[key]
            del sh.hits[key]
            self._len -= 1

    def owners(self, key) -> Optional[Set[int]]:
        """Owner set for ``key`` (live reference), or None. Counts one
        shard lookup."""
        sh = self._shard(key)
        sh.lookups += 1
        return sh.owners.get(key)

    def hit(self, key) -> int:
        """Record one fleet-wide hit on ``key``; returns the new count.
        The replicator compares this against its threshold."""
        sh = self._shard(key)
        n = sh.hits.get(key, 0) + 1
        sh.hits[key] = n
        return n

    # -- delta batches ------------------------------------------------------

    def apply_delta(self, ops: Iterable[Tuple[str, object, int]]) -> int:
        """Apply an ordered batch of ``("add"|"discard", key, replica)``
        ops — an eviction sweep's invalidations land as one O(changes)
        flush. Returns the op count (0-op batches are not counted)."""
        n = 0
        for op, key, replica in ops:
            (self.add if op == "add" else self.discard)(key, replica)
            n += 1
        if n:
            self.delta_batches += 1
            self.delta_ops += n
        return n

    # -- introspection ------------------------------------------------------

    def __len__(self) -> int:
        return self._len

    def shard_counters(self) -> dict:
        return {
            "n_shards": self.n_shards,
            "entries": [len(sh.owners) for sh in self.shards],
            "lookups": [sh.lookups for sh in self.shards],
            "updates": [sh.updates for sh in self.shards],
            "delta_batches": self.delta_batches,
            "delta_ops": self.delta_ops,
        }
