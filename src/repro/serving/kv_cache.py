"""Paged KV-cache manager over the MRM pool.

PagedAttention-style block tables (the paper cites [21]) with pages sized to
MRM blocks: each session owns a list of pages; a page is `page_size` tokens
of per-layer KV (a multi-MB sequential unit — the paper's §2 access-grain
argument). Page *placement and lifetime* go through `repro.core`:

- allocation -> MemorySystem.write_region with a DCM retention programmed
  from the session's expected remaining lifetime;
- every decode step reads all live pages sequentially (instrumented);
- each appended token accumulates into the open page; page-full -> sealed,
  and the open page region is rewritten (append-only write pattern);
- session end -> regions released (soft state dropped, per §4).

Capacity pressure (paper §2.2/§4: the *system* manages retention, placement
and eviction of inference soft state): when the tier cannot serve an
allocation — or utilization crosses the high watermark — the manager
resolves it through an explicit policy chain instead of silently counting a
drop:

1. ``evict``     — LRU-evict shared-prefix index entries whose pages are
                   only pinned by the index (frees capacity immediately);
2. ``spill``     — place the page in a configured colder tier instead;
3. ``recompute`` — drop the page as soft state; a later read re-materializes
                   it (recompute-on-demand), metered as recompute tokens.

Every failed allocation ends in exactly one recorded resolution; silent
``dropped_allocs`` only remain under the legacy ``policy="none"``.

The JAX compute path keeps its own dense ring caches (models/attention.py);
this manager is the memory control plane that decides *where those bytes
live* and meters the device traffic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.simulator import MemorySystem

PRESSURE_POLICIES = ("none", "evict-lru", "spill", "recompute")


@dataclass
class Page:
    page_id: int
    region_id: Optional[int]   # MemorySystem region (None = dropped/expired)
    n_tokens: int
    sealed: bool = False
    refcount: int = 1          # >1 when shared via prefix caching
    prefix_key: Optional[str] = None
    tier: str = ""             # where the page lives (spill may differ)
    dropped: bool = False      # soft state dropped; recompute on read


@dataclass
class SessionKV:
    session_id: int
    pages: List[Page] = field(default_factory=list)
    tokens: int = 0
    shared_prefix_pages: int = 0


@dataclass
class PressureStats:
    """Ledger of capacity-pressure events and their explicit resolutions.
    Invariant: events == evict + spill + recompute + unresolved."""
    events: int = 0
    resolved_evict: int = 0
    resolved_spill: int = 0
    resolved_recompute: int = 0
    unresolved: int = 0
    prefix_evictions: int = 0      # index entries evicted (incl. watermark)
    watermark_evictions: int = 0   # subset triggered proactively
    recompute_tokens: int = 0      # tokens re-materialized on later reads

    def as_dict(self) -> dict:
        return {
            "events": self.events,
            "resolved_evict": self.resolved_evict,
            "resolved_spill": self.resolved_spill,
            "resolved_recompute": self.resolved_recompute,
            "unresolved": self.unresolved,
            "prefix_evictions": self.prefix_evictions,
            "watermark_evictions": self.watermark_evictions,
            "recompute_tokens": self.recompute_tokens,
        }


class PagedKVManager:
    def __init__(self, cfg: ModelConfig, mem: MemorySystem, tier: str,
                 page_tokens: int = 128,
                 expected_session_s: float = 600.0,
                 spill_tier: Optional[str] = None,
                 policy: str = "none",
                 high_watermark: Optional[float] = None):
        if policy not in PRESSURE_POLICIES:
            raise ValueError(f"policy {policy!r} not in {PRESSURE_POLICIES}")
        if policy == "spill" and spill_tier is None:
            raise ValueError("policy 'spill' requires spill_tier")
        self.cfg = cfg
        self.mem = mem
        self.tier = tier
        self.page_tokens = page_tokens
        self.expected_session_s = expected_session_s
        self.spill_tier = spill_tier
        self.policy = policy
        self.high_watermark = high_watermark
        self.kv_bytes_token = cfg.kv_bytes_per_token()
        self.page_bytes = self.kv_bytes_token * page_tokens
        self.sessions: Dict[int, SessionKV] = {}
        self._next_page = 0
        self.dropped_allocs = 0            # legacy: truly-silent drops only
        self.pressure = PressureStats()
        # automatic prefix caching (paper §2.2 cites vLLM's [53]): sealed
        # prefix pages are shared by key across sessions — repeated prompt
        # prefixes cost zero KV writes and zero extra MRM capacity
        self._prefix_index: Dict[str, List[Page]] = {}
        self._prefix_lru: Dict[str, float] = {}   # key -> last-use sim time
        self.prefix_hits = 0
        self.prefix_tokens_reused = 0

    # ------------------------------------------------------------------
    def open_session(self, session_id: int, prefix_key: Optional[str] = None,
                     prefix_tokens: int = 0) -> SessionKV:
        """``prefix_key``: stable identity of the prompt's page-aligned
        prefix; if the index holds it, its sealed pages are attached
        (refcounted) instead of re-written."""
        s = SessionKV(session_id)
        self.sessions[session_id] = s
        if prefix_key is not None and prefix_key in self._prefix_index:
            for page in self._prefix_index[prefix_key]:
                page.refcount += 1
                s.pages.append(page)
                s.tokens += page.n_tokens
            s.shared_prefix_pages = len(s.pages)
            self.prefix_hits += 1
            self.prefix_tokens_reused += s.tokens
            self._prefix_lru[prefix_key] = self.mem.now
        return s

    def register_prefix(self, session_id: int, prefix_key: str) -> None:
        """Publish this session's sealed leading pages under ``prefix_key``
        (call after the prompt's KV has been appended)."""
        s = self.sessions[session_id]
        if prefix_key in self._prefix_index or s.shared_prefix_pages:
            return
        sealed = [p for p in s.pages if p.sealed and not p.dropped]
        if sealed:
            for p in sealed:
                p.prefix_key = prefix_key
                p.refcount += 1  # the index holds its own reference
            self._prefix_index[prefix_key] = sealed
            self._prefix_lru[prefix_key] = self.mem.now

    # -- capacity pressure ---------------------------------------------
    def _lru_evictable_prefix(self) -> Optional[str]:
        """Least-recently-used prefix entry whose pages are pinned only by
        the index — evicting it frees capacity immediately."""
        best, best_t = None, None
        for key, pages in self._prefix_index.items():
            if all(p.refcount == 1 for p in pages):
                t = self._prefix_lru.get(key, 0.0)
                if best_t is None or t < best_t:
                    best, best_t = key, t
        return best

    def _alloc(self, owner: str, nbytes: float, tier: str) -> Optional[int]:
        return self.mem.write_region(tier, owner, nbytes,
                                     expected_lifetime_s=self.expected_session_s)

    def _evict_and_retry(self, owner: str, nbytes: float) -> Optional[int]:
        while True:
            victim = self._lru_evictable_prefix()
            if victim is None:
                return None
            self.evict_prefix(victim)
            self.pressure.prefix_evictions += 1
            rid = self._alloc(owner, nbytes, self.tier)
            if rid is not None:
                return rid

    def _resolve_pressure(self, owner: str, nbytes: float):
        """Allocation failed: decide what gives. Returns (region_id, tier,
        dropped) with the resolution recorded — never a silent drop unless
        the legacy policy 'none' is selected."""
        self.pressure.events += 1
        if self.policy == "none":
            self.pressure.unresolved += 1
            self.dropped_allocs += 1
            return None, self.tier, False
        if self.policy in ("evict-lru", "spill"):
            rid = self._evict_and_retry(owner, nbytes)
            if rid is not None:
                self.pressure.resolved_evict += 1
                return rid, self.tier, False
        if self.policy == "spill":
            rid = self._alloc(owner, nbytes, self.spill_tier)
            if rid is not None:
                self.pressure.resolved_spill += 1
                return rid, self.spill_tier, False
        # drop-and-recompute: the page's KV is soft state — admit the page
        # with no backing region; a later read re-materializes it
        self.pressure.resolved_recompute += 1
        return None, self.tier, True

    def _check_watermark(self) -> None:
        if self.high_watermark is None or self.policy == "none":
            return
        while self.mem.utilization(self.tier) > self.high_watermark:
            victim = self._lru_evictable_prefix()
            if victim is None:
                return
            self.evict_prefix(victim)
            self.pressure.prefix_evictions += 1
            self.pressure.watermark_evictions += 1

    # ------------------------------------------------------------------
    def _new_page(self, s: SessionKV, n_tokens: int) -> Page:
        self._check_watermark()
        owner = f"session:{s.session_id}"
        nbytes = n_tokens * self.kv_bytes_token
        tier, dropped = self.tier, False
        rid = self._alloc(owner, nbytes, self.tier)
        if rid is None:
            rid, tier, dropped = self._resolve_pressure(owner, nbytes)
        p = Page(self._next_page, rid, n_tokens, tier=tier, dropped=dropped,
                 sealed=n_tokens >= self.page_tokens)
        self._next_page += 1
        s.pages.append(p)
        return p

    def append_tokens(self, session_id: int, n: int) -> None:
        """Append n tokens' KV (prefill: n large; decode: n=1)."""
        s = self.sessions[session_id]
        while n > 0:
            if s.pages and not s.pages[-1].sealed:
                page = s.pages[-1]
                take = min(n, self.page_tokens - page.n_tokens)
                if take > 0:
                    # append-only rewrite of the open page region
                    if page.region_id is not None:
                        self.mem.devices[page.tier].write(
                            take * self.kv_bytes_token,
                            expected_lifetime_s=self.expected_session_s)
                    page.n_tokens += take
                    s.tokens += take
                    n -= take
                if page.n_tokens >= self.page_tokens:
                    page.sealed = True
                continue
            take = min(n, self.page_tokens)
            self._new_page(s, take)
            s.tokens += take
            n -= take

    def _rematerialize(self, s: SessionKV, page: Page) -> None:
        """A dropped page was read: recompute its KV (metered) and try to
        write it back; if the tier is still full it stays dropped and will
        be recomputed again next read. This is *not* a new pressure event —
        it services the recompute resolution already recorded when the page
        was dropped, so only recompute_tokens accrues here."""
        self.pressure.recompute_tokens += page.n_tokens
        owner = f"session:{s.session_id}"
        nbytes = page.n_tokens * self.kv_bytes_token
        tier = page.tier
        rid = self._alloc(owner, nbytes, tier)
        if rid is None and self.policy in ("evict-lru", "spill"):
            rid = self._evict_and_retry(owner, nbytes)
        if rid is None and self.policy == "spill":
            rid = self._alloc(owner, nbytes, self.spill_tier)
            tier = self.spill_tier
        if rid is not None:
            page.region_id = rid
            page.tier = tier
            page.dropped = False

    def read_all(self, session_id: int) -> float:
        """One decode step reads the whole cache sequentially (paper §2.2).
        Returns bytes read (recomputed pages included once re-materialized)."""
        s = self.sessions[session_id]
        total = 0.0
        for page in s.pages:
            if page.dropped:
                self._rematerialize(s, page)
            if page.region_id is not None:
                self.mem.read_region(page.region_id,
                                     page.n_tokens * self.kv_bytes_token,
                                     sequential=True)
                total += page.n_tokens * self.kv_bytes_token
        return total

    def close_session(self, session_id: int) -> None:
        s = self.sessions.pop(session_id, None)
        if s is None:
            return
        for page in s.pages:
            page.refcount -= 1
            if page.refcount <= 0 and page.region_id is not None:
                self.mem.release_region(page.region_id)
                page.region_id = None

    def evict_prefix(self, prefix_key: str) -> None:
        """Capacity/retention policy hook: drop the index's reference."""
        pages = self._prefix_index.pop(prefix_key, None)
        self._prefix_lru.pop(prefix_key, None)
        for page in pages or []:
            page.refcount -= 1
            if page.refcount <= 0 and page.region_id is not None:
                self.mem.release_region(page.region_id)
                page.region_id = None

    # ------------------------------------------------------------------
    def live_pages(self) -> int:
        return sum(len(s.pages) for s in self.sessions.values())

    def live_tokens(self) -> int:
        return sum(s.tokens for s in self.sessions.values())

    def pressure_report(self) -> dict:
        rep = self.pressure.as_dict()
        rep["dropped_allocs"] = self.dropped_allocs
        return rep
