"""Paged KV-cache manager over the MRM pool.

PagedAttention-style block tables (the paper cites [21]) with pages sized to
MRM blocks: each session owns a list of pages; a page is `page_size` tokens
of per-layer KV (a multi-MB sequential unit — the paper's §2 access-grain
argument). Page *placement and lifetime* go through `repro.core`:

- allocation -> MemorySystem.write_region with a DCM retention programmed
  from the session's expected remaining lifetime;
- every decode step reads all live pages sequentially (instrumented);
- each appended token accumulates into the open page; page-full -> sealed,
  and the open page region is rewritten (append-only write pattern);
- session end -> regions released (soft state dropped, per §4).

The JAX compute path keeps its own dense ring caches (models/attention.py);
this manager is the memory control plane that decides *where those bytes
live* and meters the device traffic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.simulator import MemorySystem


@dataclass
class Page:
    page_id: int
    region_id: Optional[int]   # MemorySystem region (None = dropped/expired)
    n_tokens: int
    sealed: bool = False
    refcount: int = 1          # >1 when shared via prefix caching
    prefix_key: Optional[str] = None


@dataclass
class SessionKV:
    session_id: int
    pages: List[Page] = field(default_factory=list)
    tokens: int = 0
    shared_prefix_pages: int = 0


class PagedKVManager:
    def __init__(self, cfg: ModelConfig, mem: MemorySystem, tier: str,
                 page_tokens: int = 128,
                 expected_session_s: float = 600.0):
        self.cfg = cfg
        self.mem = mem
        self.tier = tier
        self.page_tokens = page_tokens
        self.expected_session_s = expected_session_s
        self.kv_bytes_token = cfg.kv_bytes_per_token()
        self.page_bytes = self.kv_bytes_token * page_tokens
        self.sessions: Dict[int, SessionKV] = {}
        self._next_page = 0
        self.dropped_allocs = 0
        # automatic prefix caching (paper §2.2 cites vLLM's [53]): sealed
        # prefix pages are shared by key across sessions — repeated prompt
        # prefixes cost zero KV writes and zero extra MRM capacity
        self._prefix_index: Dict[str, List[Page]] = {}
        self.prefix_hits = 0
        self.prefix_tokens_reused = 0

    # ------------------------------------------------------------------
    def open_session(self, session_id: int, prefix_key: Optional[str] = None,
                     prefix_tokens: int = 0) -> SessionKV:
        """``prefix_key``: stable identity of the prompt's page-aligned
        prefix; if the index holds it, its sealed pages are attached
        (refcounted) instead of re-written."""
        s = SessionKV(session_id)
        self.sessions[session_id] = s
        if prefix_key is not None and prefix_key in self._prefix_index:
            for page in self._prefix_index[prefix_key]:
                page.refcount += 1
                s.pages.append(page)
                s.tokens += page.n_tokens
            s.shared_prefix_pages = len(s.pages)
            self.prefix_hits += 1
            self.prefix_tokens_reused += s.tokens
        return s

    def register_prefix(self, session_id: int, prefix_key: str) -> None:
        """Publish this session's sealed leading pages under ``prefix_key``
        (call after the prompt's KV has been appended)."""
        s = self.sessions[session_id]
        if prefix_key in self._prefix_index or s.shared_prefix_pages:
            return
        sealed = [p for p in s.pages if p.sealed]
        if sealed:
            for p in sealed:
                p.prefix_key = prefix_key
                p.refcount += 1  # the index holds its own reference
            self._prefix_index[prefix_key] = sealed

    def _new_page(self, s: SessionKV, n_tokens: int) -> Page:
        rid = self.mem.write_region(
            self.tier, f"session:{s.session_id}",
            n_tokens * self.kv_bytes_token,
            expected_lifetime_s=self.expected_session_s)
        if rid is None:
            self.dropped_allocs += 1
        p = Page(self._next_page, rid, n_tokens)
        self._next_page += 1
        s.pages.append(p)
        return p

    def append_tokens(self, session_id: int, n: int) -> None:
        """Append n tokens' KV (prefill: n large; decode: n=1)."""
        s = self.sessions[session_id]
        while n > 0:
            if s.pages and not s.pages[-1].sealed:
                page = s.pages[-1]
                take = min(n, self.page_tokens - page.n_tokens)
                if take > 0:
                    # append-only rewrite of the open page region
                    if page.region_id is not None:
                        self.mem.devices[self.tier].write(
                            take * self.kv_bytes_token,
                            expected_lifetime_s=self.expected_session_s)
                    page.n_tokens += take
                    s.tokens += take
                    n -= take
                if page.n_tokens >= self.page_tokens:
                    page.sealed = True
                continue
            take = min(n, self.page_tokens)
            self._new_page(s, take)
            s.tokens += take
            n -= take

    def read_all(self, session_id: int) -> float:
        """One decode step reads the whole cache sequentially (paper §2.2).
        Returns bytes read."""
        s = self.sessions[session_id]
        total = 0.0
        for page in s.pages:
            if page.region_id is not None:
                self.mem.read_region(page.region_id,
                                     page.n_tokens * self.kv_bytes_token,
                                     sequential=True)
                total += page.n_tokens * self.kv_bytes_token
        return total

    def close_session(self, session_id: int) -> None:
        s = self.sessions.pop(session_id, None)
        if s is None:
            return
        for page in s.pages:
            page.refcount -= 1
            if page.refcount <= 0 and page.region_id is not None:
                self.mem.release_region(page.region_id)
                page.region_id = None

    def evict_prefix(self, prefix_key: str) -> None:
        """Capacity/retention policy hook: drop the index's reference."""
        pages = self._prefix_index.pop(prefix_key, None)
        for page in pages or []:
            page.refcount -= 1
            if page.refcount <= 0 and page.region_id is not None:
                self.mem.release_region(page.region_id)
                page.region_id = None

    # ------------------------------------------------------------------
    def live_pages(self) -> int:
        return sum(len(s.pages) for s in self.sessions.values())

    def live_tokens(self) -> int:
        return sum(s.tokens for s in self.sessions.values())
