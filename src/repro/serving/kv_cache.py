"""Paged KV-cache manager over the MRM pool.

PagedAttention-style block tables (the paper cites [21]) with pages sized to
MRM blocks: each session owns a list of pages; a page is `page_size` tokens
of per-layer KV (a multi-MB sequential unit — the paper's §2 access-grain
argument). Page *placement and lifetime* go through `repro.core`:

- allocation -> MemorySystem.write_region with a DCM retention programmed
  from the session's expected remaining lifetime;
- every decode step reads all live pages sequentially (instrumented);
- each appended token accumulates into the open page; page-full -> sealed,
  and the open page region is rewritten (append-only write pattern);
- session end -> regions released (soft state dropped, per §4).

Shared prefixes live in a :class:`~repro.serving.radix.RadixKVIndex`
(DESIGN.md §6): a token-level radix tree over page-aligned prefixes.
``match_prefix`` finds the longest page-aligned prefix a new prompt shares
with any published prompt; ``open_session`` attaches those pages
(refcounted, path pinned) so repeated prefixes cost zero KV writes and zero
extra MRM capacity; ``register_prefix`` publishes a finished prompt's
sealed leading pages into the tree.

**Sub-page tails** (DESIGN.md §9): a match may end mid-page. With
``tail_copy`` the up-to-``page_tokens - 1`` shared tokens past the
page-aligned boundary are *copied* out of the holder's page into the
borrower's own fresh open page — metered as a sequential read plus the
ordinary page write, strictly cheaper than recomputing those tokens under
the per-tier latency model (a recompute also streams the weights). The
engine decides when the copy is worthwhile (it needs a compute snapshot
whose history covers the tail); the manager owns the byte movement.

Retention is programmed from *observed reuse* (paper §4), with every
transition routed through one
:class:`~repro.serving.retention_lifecycle.RetentionLifecycle` state
machine (DESIGN.md §9): promotion to ``hot_retention_s`` when a node's
hit count crosses ``hot_threshold`` (plus hot-tier placement when
configured), pressure-driven *demotion* back to session retention before
leaf eviction may reach a hot node, cold decay after ``cold_ttl_s``
(spill or drop), and retention re-programmed on cross-replica arrival.

Capacity pressure (paper §2.2/§4: the *system* manages retention, placement
and eviction of inference soft state): when the tier cannot serve an
allocation — or utilization crosses the high watermark — the manager
resolves it through an explicit policy chain instead of silently counting a
drop:

1. ``evict``     — leaf-LRU-evict radix nodes pinned only by the index
                   (frees capacity immediately);
2. ``spill``     — place the page in a configured colder tier instead;
3. ``recompute`` — drop the page as soft state; a later read re-materializes
                   it (recompute-on-demand), metered as recompute tokens.

Every failed allocation ends in exactly one recorded resolution; silent
``dropped_allocs`` only remain under the legacy ``policy="none"``.

The JAX compute path keeps its own dense ring caches (models/attention.py);
this manager is the memory control plane that decides *where those bytes
live* and meters the device traffic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.simulator import MemorySystem
from repro.serving.radix import PrefixMatch, RadixKVIndex, RadixNode
from repro.serving.retention_lifecycle import LifecycleStats, RetentionLifecycle

PRESSURE_POLICIES = ("none", "evict-lru", "spill", "recompute")

# kept as an alias: the per-transition counters moved into the unified
# retention lifecycle (DESIGN.md §9) but the report/export surface is
# unchanged
RadixStats = LifecycleStats


@dataclass
class Page:
    page_id: int
    region_id: Optional[int]   # MemorySystem region (None = dropped/expired)
    n_tokens: int
    sealed: bool = False
    refcount: int = 1          # >1 when shared via the radix prefix index
    tier: str = ""             # where the page lives (spill may differ)
    dropped: bool = False      # soft state dropped; recompute on read
    compute_page: Optional[int] = None  # paged-plane pool id (DESIGN.md §10)


@dataclass
class SessionKV:
    session_id: int
    pages: List[Page] = field(default_factory=list)
    tokens: int = 0
    shared_prefix_pages: int = 0
    radix_node: Optional[RadixNode] = None  # pinned path in the prefix tree


@dataclass
class PressureStats:
    """Ledger of capacity-pressure events and their explicit resolutions.
    Invariant: events == evict + spill + recompute + unresolved."""
    events: int = 0
    resolved_evict: int = 0
    resolved_spill: int = 0
    resolved_recompute: int = 0
    unresolved: int = 0
    prefix_evictions: int = 0      # radix leaves evicted (incl. watermark)
    watermark_evictions: int = 0   # subset triggered proactively
    recompute_tokens: int = 0      # tokens re-materialized on later reads

    def as_dict(self) -> dict:
        return {
            "events": self.events,
            "resolved_evict": self.resolved_evict,
            "resolved_spill": self.resolved_spill,
            "resolved_recompute": self.resolved_recompute,
            "unresolved": self.unresolved,
            "prefix_evictions": self.prefix_evictions,
            "watermark_evictions": self.watermark_evictions,
            "recompute_tokens": self.recompute_tokens,
        }


class PagedKVManager:
    """The memory-plane half of KV: page allocation/retention/eviction
    over the MRM pool, with shared prefixes hanging off a
    :class:`RadixKVIndex`.

    Invariants the tests rely on:

    - **Pin-transfer-at-register** — a live session always pins exactly
      one radix path: ``open_session`` pins the matched node,
      ``register_prefix`` moves that pin to the deepest published node,
      ``close_session`` releases it. Consequence: unlocked leaves hold
      pages referenced by nothing but the tree, so leaf-LRU eviction
      frees capacity immediately and pinned paths are never evicted.
    - **Pressure-ledger balance** — every failed allocation is resolved
      exactly once: ``events == resolved_evict + resolved_spill +
      resolved_recompute + unresolved``, and ``unresolved == 0`` for
      every policy except the legacy ``"none"``.
    - **Token/refcount conservation** — a page's refcount equals the
      number of live sessions holding it plus one if the tree holds it;
      regions are released exactly when the refcount reaches zero.
    - **Directory ownership lifecycle** — ``on_prefix_insert`` fires for
      every published/adopted path and ``on_prefix_evict`` fires with the
      exact run an evicted leaf covered (pressure, watermark and cold
      decay alike), so a fleet directory mirrors tree membership.
    - **Tail copies never alias** — a sub-page tail is copied into a page
      the borrower *owns* (refcount 1, unsealed); the holder's page is
      read once (metered) and never shared mid-page, so page refcounts
      stay whole-page by construction.
    """

    def __init__(self, cfg: ModelConfig, mem: MemorySystem, tier: str,
                 page_tokens: int = 128,
                 expected_session_s: float = 600.0,
                 spill_tier: Optional[str] = None,
                 policy: str = "none",
                 high_watermark: Optional[float] = None,
                 hot_threshold: int = 4,
                 hot_retention_s: float = 3600.0,
                 hot_tier: Optional[str] = None,
                 cold_ttl_s: Optional[float] = None,
                 tail_copy: bool = False,
                 demote_on_pressure: bool = False,
                 state_bytes_page: float = 0.0):
        if policy not in PRESSURE_POLICIES:
            raise ValueError(f"policy {policy!r} not in {PRESSURE_POLICIES}")
        if policy == "spill" and spill_tier is None:
            raise ValueError("policy 'spill' requires spill_tier")
        self.cfg = cfg
        self.mem = mem
        self.tier = tier
        self.page_tokens = page_tokens
        self.expected_session_s = expected_session_s
        self.spill_tier = spill_tier
        self.policy = policy
        self.high_watermark = high_watermark
        self.tail_copy = tail_copy
        self.kv_bytes_token = cfg.kv_bytes_per_token()
        # paged point stacks (DESIGN.md §10) pin one recurrent-state
        # snapshot per page alongside the KV token stream, so every page
        # region is sized (and its writes metered) with those bytes. Zero
        # on the ring path — there state lives in the engine's metered
        # SnapshotHandle regions and would be double-counted here.
        self.state_bytes_page = float(state_bytes_page)
        self.page_bytes = (self.kv_bytes_token * page_tokens
                           + self.state_bytes_page)
        # every retention transition — promote, demote, decay, arrival —
        # goes through the one lifecycle state machine (DESIGN.md §9)
        self.lifecycle = RetentionLifecycle(
            mem, tier=tier, kv_bytes_token=self.kv_bytes_token,
            session_retention_s=expected_session_s,
            hot_retention_s=hot_retention_s, hot_threshold=hot_threshold,
            hot_tier=hot_tier, cold_ttl_s=cold_ttl_s, spill_tier=spill_tier,
            demote_on_pressure=demote_on_pressure)
        self.sessions: Dict[int, SessionKV] = {}
        self._next_page = 0
        self.dropped_allocs = 0            # legacy: truly-silent drops only
        self.pressure = PressureStats()
        # the one prefix abstraction every serving layer shares: a radix
        # tree over page-aligned prefixes (replaces the flat whole-prompt
        # sha1 index — partial prefixes now match)
        self.radix = RadixKVIndex(page_tokens)
        self.prefix_hits = 0
        self.prefix_tokens_reused = 0
        self.prefix_hits_migrated = 0      # hits landing on a grafted path
        self.tail_hits = 0                 # sessions that copied a tail
        self.tail_tokens_copied = 0        # sub-page tokens copied, total
        self.tail_copy_bytes = 0.0         # bus bytes moved (read + write)
        # fleet-directory hooks (ClusterFrontend wires these): fired with
        # the full position-space token path on publish, and with
        # (full_path, tail_tokens) when a leaf leaves the tree
        self.on_prefix_insert: Optional[Callable[[Sequence], None]] = None
        self.on_prefix_evict: Optional[Callable[[tuple, int], None]] = None
        # paged-compute-plane hooks (ServeEngine wires these when the
        # kernel runs in place on the pages, DESIGN.md §10): alloc fires
        # for every Page this manager creates so the backend can bind a
        # pool page; release fires exactly when the refcount hits zero
        self.on_page_alloc: Optional[Callable[[Page], None]] = None
        self.on_page_release: Optional[Callable[[Page], None]] = None
        self._last_adopt_pages: List[Page] = []  # adopt_prefix's new pages

    # -- prefix tree ---------------------------------------------------
    @property
    def radix_stats(self) -> LifecycleStats:
        """Retention-transition counters (kept under the historical name;
        the transitions themselves live in the lifecycle, DESIGN.md §9)."""
        return self.lifecycle.stats

    def match_prefix(self, tokens: Sequence,
                     max_tokens: Optional[int] = None) -> PrefixMatch:
        """Longest page-aligned prefix of `tokens` present in the tree —
        plus, with ``tail_copy``, the sub-page tail beyond it. Bumps hit
        counts and promotes nodes whose observed reuse crossed the
        lifecycle's ``hot_threshold`` (reuse -> retention programming).
        The match is not yet pinned — pass it to :meth:`open_session` to
        attach it."""
        m = self.radix.match(tokens, self.mem.now, max_tokens=max_tokens,
                             with_tail=self.tail_copy)
        if m.tokens:
            self.lifecycle.observe_reuse(m.node)
        return m

    def tail_available(self, match: PrefixMatch) -> int:
        """Sub-page tail tokens the memory plane can actually serve for
        this match: the holder's page must be resident (not dropped, live
        region — unless the stack has no KV byte stream at all). The
        engine combines this with compute-side validity (a snapshot whose
        history covers the tail) before asking for the copy."""
        if (not self.tail_copy or match is None or match.tail_node is None
                or match.tokens == 0 or not match.tail_node.pages):
            return 0
        page = match.tail_node.pages[0]
        if page.dropped:
            return 0
        if page.region_id is None and self.kv_bytes_token > 0:
            return 0
        return match.tail_tokens

    def match_len(self, tokens: Sequence,
                  max_tokens: Optional[int] = None) -> int:
        """Side-effect-free match length (scheduler / router scoring)."""
        return self.radix.match_len(tokens, max_tokens=max_tokens)

    def open_session(self, session_id: int,
                     match: Optional[PrefixMatch] = None,
                     tail_tokens: int = 0) -> SessionKV:
        """Open a session; when a :class:`PrefixMatch` is supplied its
        pages are attached (refcounted) and the matched path is pinned, so
        the shared tokens cost no new KV writes and can never be evicted
        under this session. ``tail_tokens`` (<= ``match.tail_tokens``,
        engine-vetted via :meth:`tail_available`) additionally copies the
        sub-page tail out of the holder's page into a fresh page the
        session owns (DESIGN.md §9)."""
        s = SessionKV(session_id)
        self.sessions[session_id] = s
        if match is not None and match.tokens:
            for page in match.pages:
                page.refcount += 1
                s.pages.append(page)
                s.tokens += page.n_tokens
            s.shared_prefix_pages = len(match.pages)
            s.radix_node = match.node
            self.radix.lock(match.node)
            self.prefix_hits += 1
            self.prefix_tokens_reused += s.tokens
            node = match.node
            while node is not None:
                if node.migrated:   # the hit landed on cross-replica data
                    self.prefix_hits_migrated += 1
                    break
                node = node.parent
            if tail_tokens:
                self._copy_tail(s, match, tail_tokens)
        return s

    def _copy_tail(self, s: SessionKV, match: PrefixMatch,
                   tail_tokens: int) -> None:
        """Sub-page tail reuse: read ``tail_tokens`` of KV out of the
        holder's page (metered, sequential — the read happens *before*
        any allocation so pressure eviction cannot invalidate it) and
        write them into a fresh open page the borrower owns. Metered as a
        read + write; cheaper than recompute under the per-tier latency
        model because recompute would also stream the weights."""
        nbytes = tail_tokens * self.kv_bytes_token
        page = match.tail_node.pages[0]
        if page.region_id is not None and nbytes > 0:
            self.mem.read_region(page.region_id, nbytes, sequential=True)
        self._new_page(s, tail_tokens)    # the borrower's own open page
        s.tokens += tail_tokens
        self.tail_hits += 1
        self.tail_tokens_copied += tail_tokens
        self.tail_copy_bytes += 2.0 * nbytes
        self.prefix_tokens_reused += tail_tokens

    def register_prefix(self, session_id: int, tokens: Sequence,
                        payload: Any = None) -> int:
        """Publish this session's sealed leading pages into the radix tree
        under the token path (call after the prompt's KV is appended).
        ``tokens[i*page_tokens:(i+1)*page_tokens]`` must be what the i-th
        page covers. The session's pin moves to the deepest node so its
        freshly published prefix cannot be evicted under it. ``payload``
        may be the compute handle itself or a zero-arg factory for one
        (resolved only if the deepest node's payload slot is free — the
        engine's snapshots carry metered regions that must not be written
        for nothing). Returns the number of newly inserted pages."""
        s = self.sessions[session_id]
        run: List[Page] = []
        for p in s.pages:
            if p.sealed and not p.dropped:
                run.append(p)
            else:
                break
        n = min(len(run), len(tokens) // self.page_tokens)
        if n == 0:
            if not callable(payload):
                self._release_payload_obj(payload)
            return 0
        _, inserted, node = self.radix.insert(
            tokens[:n * self.page_tokens], run[:n], self.mem.now)
        if payload is not None and node is not self.radix.root \
                and node.payload is None:
            # a callable payload is a factory: resolved only when the node
            # actually takes it, so a metered snapshot region is never
            # written just to be released (occupied payload slot)
            obj = payload() if callable(payload) else payload
            if obj is not None:
                node.payload = obj
        elif not callable(payload):
            self._release_payload_obj(payload)
        for p in inserted:
            p.refcount += 1  # the tree holds its own reference
        if node is not self.radix.root:
            self.radix.lock(node)
            if s.radix_node is not None:
                self.radix.unlock(s.radix_node)
            s.radix_node = node
            self._notify_insert(tokens[:n * self.page_tokens])
        return len(inserted)

    def adopt_prefix(self, tokens: Sequence, hot: bool = False,
                     hits: int = 0) -> Tuple[int, int, Optional[RadixNode]]:
        """Adopt a foreign page-aligned prefix (cross-replica migration):
        allocate backing regions on *this* replica — metered writes into
        the hot tier with long retention when the donor observed the
        prefix hot (retention re-programmed on arrival), else the KV tier
        at session retention — and graft the path into the radix tree,
        tree-owned (refcount 1). Allocation failures fall back to leaf-LRU
        eviction and then truncate the adoption at a page boundary, so the
        ledger never records an unresolved event for an optional transfer.
        Returns ``(new_tokens, total_tokens, node)``: tokens newly backed
        here, total matched+adopted tokens, and the deepest node."""
        pt = self.page_tokens
        self._last_adopt_pages = []
        n = (len(tokens) // pt) * pt
        if n == 0:
            return 0, 0, None
        # match (not match_len): splits at the boundary so the duplicate
        # path can be *pinned* while we allocate — the eviction fallback
        # below must never free the very prefix the graft extends. No hit
        # bump: the arrival itself is not reuse (the first borrower's
        # open_session is)
        m = self.radix.match(tokens[:n], self.mem.now, bump_hits=False)
        dup = m.tokens
        self.radix.lock(m.node)
        # retention re-programmed on arrival: one decision point for the
        # whole fleet (the lifecycle, DESIGN.md §9)
        tier, life = self.lifecycle.arrival(hot)
        new_pages: List[Page] = []
        try:
            for _start in range(dup, n, pt):
                nbytes = pt * self.kv_bytes_token + self.state_bytes_page
                rid = self.mem.write_region(tier, "prefix:adopt", nbytes,
                                            expected_lifetime_s=life)
                used = tier
                if rid is None and tier != self.tier:
                    rid = self.mem.write_region(self.tier, "prefix:adopt",
                                                nbytes,
                                                expected_lifetime_s=life)
                    used = self.tier
                if rid is None and self.policy in ("evict-lru", "spill"):
                    # only policies that allow eviction may displace local
                    # prefixes for an inbound transfer; 'none'/'recompute'
                    # truncate instead (the transfer is optional). The
                    # arrival retention survives this path too.
                    rid = self._evict_and_retry("prefix:adopt", nbytes,
                                                lifetime_s=life)
                    used = self.tier
                if rid is None:
                    break        # page-aligned partial adoption
                p = Page(self._next_page, rid, pt, sealed=True, refcount=0,
                         tier=used)
                self._next_page += 1
                if self.on_page_alloc is not None:
                    self.on_page_alloc(p)
                new_pages.append(p)
        finally:
            self.radix.unlock(m.node)
        total = dup + len(new_pages) * pt
        if total == 0:
            return 0, 0, None
        pages_full: List[Optional[Page]] = [None] * (dup // pt) + new_pages
        dup2, inserted, node = self.radix.graft(
            tokens[:total], pages_full, self.mem.now, hits=hits, hot=hot)
        assert dup2 == dup, "graft walk disagrees with match_len"
        for p in inserted:
            p.refcount += 1    # the tree holds its own reference
        self._last_adopt_pages = list(inserted)
        self.lifecycle.note_adoption(len(inserted), len(inserted) * pt)
        if node is not self.radix.root:
            self._notify_insert(tokens[:total])
        return len(inserted) * pt, total, (None if node is self.radix.root
                                           else node)

    # -- fleet-directory notification ----------------------------------
    def _notify_insert(self, tokens: Sequence) -> None:
        if self.on_prefix_insert is not None:
            self.on_prefix_insert(tokens)

    @staticmethod
    def _release_payload_obj(payload: Any) -> None:
        """Compute-plane payloads may carry a metered backing region (the
        engine's SnapshotHandle); release it when the payload dies."""
        if payload is not None and hasattr(payload, "release"):
            payload.release()

    def _on_leaf_removed(self, victim: RadixNode) -> None:
        """A leaf left the tree (pressure eviction or cold decay): release
        its metered compute snapshot and invalidate fleet-directory
        ownership of the token run it covered."""
        self._release_payload_obj(victim.payload)
        victim.payload = None
        if self.on_prefix_evict is not None and victim.evicted_path is not None:
            self.on_prefix_evict(victim.evicted_path, victim.n_tokens)

    # -- reuse -> retention programming (via the lifecycle) ------------
    def maintain(self) -> None:
        """Cold-leaf decay (call once per engine step): unlocked leaves
        the lifecycle judges cold are demoted — spilled to the colder
        tier when one is configured, else dropped from the tree (soft
        state; an identical future prompt recomputes)."""
        if self.lifecycle.cold_ttl_s is None:
            return
        now = self.mem.now
        for leaf in self.radix.evictable_leaves():
            if not self.lifecycle.decay_due(leaf, now):
                continue
            if self.spill_tier and self.spill_tier != self.tier:
                self.lifecycle.spill_cold(leaf, now)
            elif self.radix.pop_leaf(leaf) is not None:
                self._on_leaf_removed(leaf)
                for page in leaf.pages:
                    self._unref_page(page)
                self.lifecycle.note_decay()

    def next_decay_due(self) -> Optional[float]:
        """Earliest wall-clock time any evictable leaf becomes decay-due
        (None when decay is off or nothing can decay). The event-driven
        clock (DESIGN.md §12) schedules a RETENTION_DECAY event at this
        instant instead of polling :meth:`maintain` every step — an idle
        replica whose clock jumps between arrivals still decays on time."""
        if self.lifecycle.cold_ttl_s is None:
            return None
        deadlines = [self.lifecycle.decay_deadline(leaf)
                     for leaf in self.radix.evictable_leaves()]
        deadlines = [d for d in deadlines if d is not None]
        return min(deadlines) if deadlines else None

    # -- capacity pressure ---------------------------------------------
    def _unref_page(self, page: Page) -> None:
        page.refcount -= 1
        if page.refcount <= 0:
            if page.region_id is not None:
                self.mem.release_region(page.region_id)
                page.region_id = None
            # fired once, region or not: a dropped page still holds a
            # compute-plane pool page the backend must reclaim
            if self.on_page_release is not None:
                self.on_page_release(page)

    def _evict_one_prefix_leaf(self) -> bool:
        """Leaf-LRU eviction: unlocked leaves hold pages pinned only by
        the tree (live sessions pin their paths), so evicting one frees
        capacity immediately. With ``demote_on_pressure`` the lifecycle
        interposes: cold leaves go first, and a hot leaf is *demoted*
        (retention reprogram metered, hits reset) before eviction may
        reach it — returning True without freeing counts as progress, the
        retry loop comes back and finds the leaf an ordinary candidate."""
        victims = self.radix.evictable_leaves()
        if not victims:
            return False
        if self.lifecycle.demote_on_pressure:
            cold = [v for v in victims if not v.hot]
            if not cold:
                if self.lifecycle.demote(min(victims,
                                             key=self.radix.lru_key)):
                    return True
            else:
                victims = cold   # cold leaves shield hot ones
        victim = self.radix.pop_leaf(min(victims, key=self.radix.lru_key))
        if victim is None:
            return False
        self._on_leaf_removed(victim)
        for page in victim.pages:
            self._unref_page(page)
        self.pressure.prefix_evictions += 1
        return True

    def _alloc(self, owner: str, nbytes: float, tier: str,
               lifetime_s: Optional[float] = None) -> Optional[int]:
        return self.mem.write_region(
            tier, owner, nbytes,
            expected_lifetime_s=(self.expected_session_s if lifetime_s is None
                                 else lifetime_s))

    def _evict_and_retry(self, owner: str, nbytes: float,
                         lifetime_s: Optional[float] = None) -> Optional[int]:
        while self._evict_one_prefix_leaf():
            rid = self._alloc(owner, nbytes, self.tier, lifetime_s=lifetime_s)
            if rid is not None:
                return rid
        return None

    def _resolve_pressure(self, owner: str, nbytes: float):
        """Allocation failed: decide what gives. Returns (region_id, tier,
        dropped) with the resolution recorded — never a silent drop unless
        the legacy policy 'none' is selected."""
        self.pressure.events += 1
        if self.policy == "none":
            self.pressure.unresolved += 1
            self.dropped_allocs += 1
            return None, self.tier, False
        if self.policy in ("evict-lru", "spill"):
            rid = self._evict_and_retry(owner, nbytes)
            if rid is not None:
                self.pressure.resolved_evict += 1
                return rid, self.tier, False
        if self.policy == "spill":
            rid = self._alloc(owner, nbytes, self.spill_tier)
            if rid is not None:
                self.pressure.resolved_spill += 1
                return rid, self.spill_tier, False
        # drop-and-recompute: the page's KV is soft state — admit the page
        # with no backing region; a later read re-materializes it
        self.pressure.resolved_recompute += 1
        return None, self.tier, True

    def _check_watermark(self) -> None:
        if self.high_watermark is None or self.policy == "none":
            return
        while self.mem.utilization(self.tier) > self.high_watermark:
            before = self.pressure.prefix_evictions
            if not self._evict_one_prefix_leaf():
                return
            # a demote-progress round frees nothing and is not an
            # eviction — only count rounds that actually popped a leaf
            if self.pressure.prefix_evictions > before:
                self.pressure.watermark_evictions += 1

    # ------------------------------------------------------------------
    def _new_page(self, s: SessionKV, n_tokens: int) -> Page:
        self._check_watermark()
        owner = f"session:{s.session_id}"
        nbytes = n_tokens * self.kv_bytes_token + self.state_bytes_page
        tier, dropped = self.tier, False
        rid = self._alloc(owner, nbytes, self.tier)
        if rid is None:
            rid, tier, dropped = self._resolve_pressure(owner, nbytes)
        p = Page(self._next_page, rid, n_tokens, tier=tier, dropped=dropped,
                 sealed=n_tokens >= self.page_tokens)
        self._next_page += 1
        if self.on_page_alloc is not None:
            self.on_page_alloc(p)
        s.pages.append(p)
        return p

    def append_tokens(self, session_id: int, n: int) -> None:
        """Append n tokens' KV (prefill: n large; decode: n=1)."""
        s = self.sessions[session_id]
        while n > 0:
            if s.pages and not s.pages[-1].sealed:
                page = s.pages[-1]
                take = min(n, self.page_tokens - page.n_tokens)
                if take > 0:
                    # append-only rewrite of the open page region
                    if page.region_id is not None:
                        self.mem.devices[page.tier].write(
                            take * self.kv_bytes_token,
                            expected_lifetime_s=self.expected_session_s)
                    page.n_tokens += take
                    s.tokens += take
                    n -= take
                if page.n_tokens >= self.page_tokens:
                    page.sealed = True
                continue
            take = min(n, self.page_tokens)
            self._new_page(s, take)
            s.tokens += take
            n -= take

    def _rematerialize(self, s: SessionKV, page: Page) -> None:
        """A dropped page was read: recompute its KV (metered) and try to
        write it back; if the tier is still full it stays dropped and will
        be recomputed again next read. This is *not* a new pressure event —
        it services the recompute resolution already recorded when the page
        was dropped, so only recompute_tokens accrues here."""
        self.pressure.recompute_tokens += page.n_tokens
        owner = f"session:{s.session_id}"
        nbytes = page.n_tokens * self.kv_bytes_token + self.state_bytes_page
        tier = page.tier
        rid = self._alloc(owner, nbytes, tier)
        if rid is None and self.policy in ("evict-lru", "spill"):
            rid = self._evict_and_retry(owner, nbytes)
        if rid is None and self.policy == "spill":
            rid = self._alloc(owner, nbytes, self.spill_tier)
            tier = self.spill_tier
        if rid is not None:
            page.region_id = rid
            page.tier = tier
            page.dropped = False

    def read_all(self, session_id: int) -> float:
        """One decode step reads the whole cache sequentially (paper §2.2).
        Returns bytes read (recomputed pages included once re-materialized)."""
        s = self.sessions[session_id]
        total = 0.0
        for page in s.pages:
            if page.dropped:
                self._rematerialize(s, page)
            if page.region_id is not None:
                self.mem.read_region(page.region_id,
                                     page.n_tokens * self.kv_bytes_token,
                                     sequential=True)
                total += page.n_tokens * self.kv_bytes_token
        return total

    def read_pages(self, session_id: int, page_bytes: Sequence[float]) -> float:
        """Meter the paged kernel's actual per-page read stream
        (DESIGN.md §10): ``page_bytes[i]`` is the byte count the kernel's
        DMA pulled from the session's i-th page this step — computed by
        the engine from the layer stack and each layer's window, so tier
        traffic is charged for exactly what the gather touched (zero for
        pages outside every window) instead of a synthetic whole-cache
        read. Dropped pages the kernel touched are re-materialized first.
        Returns total bytes metered."""
        s = self.sessions[session_id]
        total = 0.0
        for page, nbytes in zip(s.pages, page_bytes):
            if nbytes <= 0:
                continue
            if page.dropped:
                self._rematerialize(s, page)
            if page.region_id is not None:
                self.mem.read_region(page.region_id, nbytes, sequential=True)
                total += nbytes
        return total

    def close_session(self, session_id: int) -> None:
        s = self.sessions.pop(session_id, None)
        if s is None:
            return
        if s.radix_node is not None:
            self.radix.unlock(s.radix_node)
        for page in s.pages:
            self._unref_page(page)

    def evict_prefixes(self, max_n: Optional[int] = None) -> int:
        """Capacity/retention policy hook: leaf-LRU-evict up to ``max_n``
        unlocked radix leaves (all of them when None). Returns the count."""
        n = 0
        while (max_n is None or n < max_n) and self._evict_one_prefix_leaf():
            n += 1
        return n

    # ------------------------------------------------------------------
    def live_pages(self) -> int:
        return sum(len(s.pages) for s in self.sessions.values())

    def live_kv_bytes(self) -> float:
        """Bytes of KV the live sessions pin. (Reporting/diagnostics; the
        cluster router's load tiebreak reads the tier's allocator
        utilization, which counts these pages physically.)"""
        return sum(s.tokens for s in self.sessions.values()) * self.kv_bytes_token

    def radix_kv_bytes(self) -> float:
        """Bytes of KV resident in the radix prefix tree (directory-owned
        hot prefixes included) — a prefix_report figure. The cluster
        router does not walk the tree: its tiebreak reads the tier's
        allocator utilization, which already counts these pages."""
        return sum(p.n_tokens for node in self.radix.nodes()
                   for p in node.pages) * self.kv_bytes_token

    def live_tokens(self) -> int:
        return sum(s.tokens for s in self.sessions.values())

    def pressure_report(self) -> dict:
        rep = self.pressure.as_dict()
        rep["dropped_allocs"] = self.dropped_allocs
        return rep

    def prefix_report(self) -> dict:
        rep = {
            "hits": self.prefix_hits,
            "hits_migrated": self.prefix_hits_migrated,
            "tokens_reused": self.prefix_tokens_reused,
            "tail_hits": self.tail_hits,
            "tail_tokens_copied": self.tail_tokens_copied,
            "tail_copy_bytes": self.tail_copy_bytes,
            "radix_nodes": self.radix.n_nodes(),
            "radix_tokens": self.radix.total_tokens(),
            "radix_pages": self.radix.total_pages(),
            "radix_kv_bytes": self.radix_kv_bytes(),
            "evictions": self.pressure.prefix_evictions,
        }
        rep.update(self.lifecycle.stats.as_dict())
        return rep
