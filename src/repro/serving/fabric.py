"""Shared-fabric interconnect topology for the fleet plane (DESIGN.md §13).

PR 3–9 modeled the interconnect as one independent serialized link per
*receiver* (``_link_busy_until``): a donor could feed any number of
receivers at full line rate simultaneously, and the fleet-wide core was
infinite — replication storms were free parallelism. This module replaces
that with the smallest topology that makes contention real:

- **per-replica NIC links** — every replica has one full-duplex NIC: an
  *up* (egress) link and a *down* (ingress) link, each serializing at
  ``link_gbps``. Two concurrent exports from the same donor now queue on
  the donor's up-link even when their receivers differ.
- **a bisection-bandwidth core** — the switch core carries at most
  ``bisection_gbps`` of aggregate traffic, modeled as
  ``floor(bisection / link)`` virtual channels each at line rate (a
  transfer occupies exactly one channel: NIC rate is the per-flow cap, so
  a fractional channel can never help). Defaults to half-bisection
  (``link_gbps * max(1, n_replicas // 2)``), the classic oversubscribed
  fat-tree shape.

``reserve`` is first-come-first-served at call time: a transfer starts at
the earliest instant its donor up-link, receiver down-link, and one core
channel are all free, and holds all three for ``nbytes / rate``. The
speculative replicator deliberately does *not* reserve while the fabric
is hot (``free_at() > now``): it re-defers instead, so a demand migration
that arrives in the gap reserves first — that asymmetry is the whole
admission-control/preemption story (tested in ``tests/test_fabric.py``).

Determinism: every quantity is derived from reserve-call order, which the
event queue makes content-derived; channel selection tie-breaks on index.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

_EPS = 1e-12


class Fabric:
    """Per-replica NIC up/down links plus a bisection-limited core."""

    def __init__(self, n_replicas: int, link_gbps: float,
                 bisection_gbps: Optional[float] = None):
        if link_gbps <= 0:
            raise ValueError(f"link_gbps must be positive, got {link_gbps}")
        if bisection_gbps is None:
            bisection_gbps = link_gbps * max(1, n_replicas // 2)
        if bisection_gbps < link_gbps:
            raise ValueError(
                f"bisection ({bisection_gbps} GB/s) below a single link "
                f"({link_gbps} GB/s): no transfer could ever run")
        self.n_replicas = n_replicas
        self.link_gbps = float(link_gbps)
        self.bisection_gbps = float(bisection_gbps)
        self.n_channels = max(1, int(bisection_gbps / link_gbps))
        self._up: Dict[int, float] = {}    # donor egress busy-until
        self._down: Dict[int, float] = {}  # receiver ingress busy-until
        self._core: List[float] = [0.0] * self.n_channels
        # ledgers — every reserved byte is metered here exactly once
        self.transfers = 0
        self.bytes_total = 0
        self.busy_s = 0.0        # sum of transfer durations
        self.queue_wait_s = 0.0  # sum of (start - requested) waits
        self.up_bytes: Dict[int, int] = {}
        self.down_bytes: Dict[int, int] = {}

    # -- capacity queries ---------------------------------------------------

    def free_at(self, src: int, dst: int, t: float) -> float:
        """Earliest instant a ``src -> dst`` transfer requested at ``t``
        could start (no reservation made)."""
        return max(t, self._up.get(src, 0.0), self._down.get(dst, 0.0),
                   min(self._core))

    def hot(self, src: int, dst: int, t: float) -> bool:
        """True when a ``src -> dst`` transfer requested now would queue —
        the replicator's admission-control signal."""
        return self.free_at(src, dst, t) > t + _EPS

    # -- reservation --------------------------------------------------------

    def reserve(self, src: int, dst: int, nbytes: int,
                t: float) -> Tuple[float, float]:
        """Reserve the path for ``nbytes`` requested at ``t``; returns
        ``(start, done)`` and holds up-link, down-link and one core
        channel for the duration."""
        dur = nbytes / (self.link_gbps * 1e9)
        chan = min(range(self.n_channels), key=lambda i: (self._core[i], i))
        start = max(t, self._up.get(src, 0.0), self._down.get(dst, 0.0),
                    self._core[chan])
        done = start + dur
        self._up[src] = done
        self._down[dst] = done
        self._core[chan] = done
        self.transfers += 1
        self.bytes_total += int(nbytes)
        self.busy_s += dur
        self.queue_wait_s += start - t
        self.up_bytes[src] = self.up_bytes.get(src, 0) + int(nbytes)
        self.down_bytes[dst] = self.down_bytes.get(dst, 0) + int(nbytes)
        return start, done

    # -- reporting ----------------------------------------------------------

    def report(self) -> dict:
        return {
            "link_gbps": self.link_gbps,
            "bisection_gbps": self.bisection_gbps,
            "n_channels": self.n_channels,
            "transfers": self.transfers,
            "bytes": self.bytes_total,
            "busy_s": self.busy_s,
            "queue_wait_s": self.queue_wait_s,
            "up_bytes": dict(sorted(self.up_bytes.items())),
            "down_bytes": dict(sorted(self.down_bytes.items())),
        }
