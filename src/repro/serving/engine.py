"""Serving engine: continuous batching over fixed decode slots, with every
byte routed through the MRM memory control plane.

Compute path: the real JAX model (prefill per admitted request, one batched
decode step per engine step over `max_slots` slots with per-slot positions).
Memory control plane: weights live in a `weights` region of the chosen tier
(written once at deploy, read wholesale every step — §2.2); KV pages go
through `PagedKVManager` (DCM retention = expected session lifetime);
refresh/migrate/drop deadlines are serviced as simulation time advances.

Step time (simulation) is modelled from the tier's read bandwidth and the
bytes each phase actually moved — so tokens/s and tokens/J reflect the
memory system under test, which is exactly the paper's figure of merit.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.simulator import MemorySystem
from repro.models import transformer as tfm
from repro.serving.kv_cache import PagedKVManager
from repro.serving.scheduler import ContinuousBatchScheduler, Request


@dataclass
class EngineConfig:
    max_slots: int = 4
    max_cache_len: int = 256
    max_prefills_per_step: int = 2
    weight_tier: str = "mrm"
    kv_tier: str = "mrm"
    page_tokens: int = 64
    expected_session_s: float = 600.0
    eos_token: int = 1
    greedy: bool = True
    prefix_caching: bool = True  # share page-aligned prompt prefixes [53]


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, mem: MemorySystem,
                 ecfg: EngineConfig, account_cfg: Optional[ModelConfig] = None):
        """``account_cfg`` decouples the memory-accounting scale from the
        compute scale: CPU tests run a reduced model for real token
        generation while the control plane meters the *deployment-size*
        config's weight/KV byte streams (the paper's figures of merit)."""
        self.cfg = cfg
        self.acct_cfg = account_cfg or cfg
        self.params = params
        self.mem = mem
        self.ecfg = ecfg
        self.sched = ContinuousBatchScheduler(ecfg.max_slots,
                                              ecfg.max_prefills_per_step)
        self.kv = PagedKVManager(self.acct_cfg, mem, ecfg.kv_tier,
                                 ecfg.page_tokens, ecfg.expected_session_s)

        # deploy weights into the weight tier (written once — §2 of paper)
        counts = self.acct_cfg.param_counts()
        self.weight_bytes = counts["total"] * 2  # bf16
        self.active_weight_bytes = counts["active"] * 2
        self.weight_region = mem.write_region(
            ecfg.weight_tier, "weights", self.weight_bytes,
            expected_lifetime_s=mem.devices[ecfg.weight_tier].tech.retention_s)

        # fixed decode slots
        B = ecfg.max_slots
        self.caches = tfm.init_caches(cfg, B, ecfg.max_cache_len,
                                      jnp.dtype(cfg.dtype))
        self.positions = np.full((B,), -1, np.int64)  # last written position
        self.last_tokens = np.zeros((B, 1) if cfg.n_codebooks == 1
                                    else (B, 1, cfg.n_codebooks), np.int32)
        self.outputs: Dict[int, list] = {}
        self._prefill_jit: Dict[int, callable] = {}
        self._decode_jit = jax.jit(
            lambda p, c, t, pos: tfm.decode(cfg, p, c, t, pos))
        self.tokens_generated = 0
        self.steps = 0

    # ------------------------------------------------------------------
    def submit(self, prompt_tokens: list, max_new_tokens: int) -> int:
        rid = len(self.outputs)
        self.outputs[rid] = []
        self.sched.submit(Request(rid, prompt_tokens, max_new_tokens,
                                  self.mem.now))
        return rid

    # ------------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        b = 16
        while b < n:
            b *= 2
        return min(b, self.ecfg.max_cache_len)

    def _prefill_fn(self, length: int):
        if length not in self._prefill_jit:
            cfg, ecfg = self.cfg, self.ecfg

            def fn(p, batch):
                return tfm.prefill(cfg, p, batch,
                                   max_cache_len=ecfg.max_cache_len)

            self._prefill_jit[length] = jax.jit(fn)
        return self._prefill_jit[length]

    def _insert_slot(self, slot: int, new_caches) -> None:
        """Copy a B=1 prefill cache into decode-slot `slot`."""
        def ins(dst, src):
            return dst.at[:, slot].set(src[:, 0])

        def walk(dst, src):
            if isinstance(dst, dict):
                return {k: walk(dst[k], src[k]) for k in dst}
            if isinstance(dst, (tuple, list)):
                return type(dst)(walk(d, s) for d, s in zip(dst, src))
            return ins(dst, src)

        self.caches = walk(self.caches, new_caches)

    def _prefix_len(self) -> int:
        return self.cfg.n_meta_tokens + (self.cfg.n_frontend_tokens
                                         if self.cfg.frontend == "vision" else 0)

    # ------------------------------------------------------------------
    def step(self) -> dict:
        """One engine step: admissions (prefill) + one decode round."""
        ecfg = self.ecfg
        bytes_moved = 0.0

        # --- admissions (prefill phase) ----------------------------------
        for slot, req in self.sched.admissions():
            toks = np.asarray(req.prompt_tokens, np.int32)
            L = toks.shape[0]
            pad = self._bucket(L) - L
            # left-pad with token 0: padded keys are masked only by causality,
            # acceptable for the functional demo; real serving uses bucketed
            # compilation exactly like this but with an attention prefix mask.
            padded = np.pad(toks, [(pad, 0)] + [(0, 0)] * (toks.ndim - 1))
            batch = {"tokens": jnp.asarray(padded)[None]}
            if self.cfg.frontend == "vision":
                batch["image_embeds"] = jnp.zeros(
                    (1, self.cfg.n_frontend_tokens, self.cfg.d_model),
                    jnp.dtype(self.cfg.dtype))
            logits, caches1 = self._prefill_fn(padded.shape[0])(self.params, batch)
            self._insert_slot(slot, caches1)
            next_tok = self._sample(logits)
            self.last_tokens[slot] = next_tok
            self.positions[slot] = self._prefix_len() + padded.shape[0] - 1
            req.prefilled_at = self.mem.now
            self.outputs[req.request_id].append(int(np.asarray(next_tok).flat[0]))
            req.generated += 1
            self.tokens_generated += 1

            # memory control plane: prefill writes the prompt's KV — unless
            # a shared prefix already holds the page-aligned leading pages
            pkey = None
            if ecfg.prefix_caching:
                pkey = "p:" + str(hash(padded.tobytes()))
            sess = self.kv.open_session(req.request_id, prefix_key=pkey)
            new_tokens = (padded.shape[0] + self._prefix_len()) - sess.tokens
            self.kv.append_tokens(req.request_id, max(new_tokens, 0))
            if pkey is not None:
                self.kv.register_prefix(req.request_id, pkey)
            self.mem.read_region(self.weight_region, self.active_weight_bytes)
            bytes_moved += self.active_weight_bytes

        # --- decode round --------------------------------------------------
        slots = self.sched.decode_slots()
        if slots:
            pos = jnp.asarray(np.maximum(self.positions + 1, 0), jnp.int32)
            logits, self.caches = self._decode_jit(
                self.params, self.caches, jnp.asarray(self.last_tokens), pos)
            next_np = np.asarray(self._sample(logits))
            self.mem.read_region(self.weight_region, self.active_weight_bytes)
            bytes_moved += self.active_weight_bytes

            finished: List[int] = []
            for slot in slots:
                req = self.sched.active[slot]
                tok = next_np[slot]
                self.positions[slot] += 1
                self.last_tokens[slot] = tok
                self.outputs[req.request_id].append(int(np.asarray(tok).flat[0]))
                req.generated += 1
                self.tokens_generated += 1
                bytes_moved += self.kv.read_all(req.request_id)
                self.kv.append_tokens(req.request_id, 1)
                done = (req.generated >= req.max_new_tokens or
                        (self.cfg.n_codebooks == 1 and
                         int(np.asarray(tok).flat[0]) == ecfg.eos_token))
                if done:
                    finished.append(slot)
            for slot in finished:
                req = self.sched.finish(slot, self.mem.now)
                self.kv.close_session(req.request_id)
                self.positions[slot] = -1

        # --- advance simulated time by the modelled step latency ----------
        tier = self.mem.devices[ecfg.kv_tier].tech
        step_s = max(bytes_moved / (tier.read_bw_gbps * 1e9), 1e-4)
        self.mem.advance(step_s)
        self.steps += 1
        return {"step_s": step_s, "bytes": bytes_moved,
                "active": len(self.sched.active), "queued": len(self.sched.queue)}

    def _sample(self, logits):
        if self.cfg.n_codebooks > 1:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, K)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------------
    def redeploy_weights(self) -> None:
        """Model update (paper §2/§3: bulk weight overwrite): release the
        old weight region and write the new one — the wear/endurance
        accounting of Figure 1's weight-update bars, from the system."""
        self.mem.release_region(self.weight_region)
        self.weight_region = self.mem.write_region(
            self.ecfg.weight_tier, "weights", self.weight_bytes,
            expected_lifetime_s=self.mem.devices[
                self.ecfg.weight_tier].tech.retention_s)

    # ------------------------------------------------------------------
    def run_until_idle(self, max_steps: int = 10000) -> dict:
        while not self.sched.idle and self.steps < max_steps:
            self.step()
        return self.report()

    def report(self) -> dict:
        rep = self.mem.report()
        total_energy = rep["total_energy_j"]
        # steady-state read:write ratio: exclude the one-time model-deploy
        # write (it amortizes to ~0 over a device lifetime — §2.2's >1000:1
        # claim is about the per-token decode stream)
        reads = sum(d.stats.read_bytes for d in self.mem.devices.values())
        writes = sum(d.stats.write_bytes for d in self.mem.devices.values())
        steady_writes = max(writes - self.weight_bytes, 1e-9)
        return {
            "steps": self.steps,
            "tokens_generated": self.tokens_generated,
            "finished": self.sched.stats.finished,
            "sim_time_s": self.mem.now,
            "tokens_per_s": self.tokens_generated / max(self.mem.now, 1e-9),
            "energy_per_token_j": total_energy / max(self.tokens_generated, 1),
            "steady_rw_ratio": reads / steady_writes,
            "memory": rep,
            "kv_live_pages": self.kv.live_pages(),
            "dropped_allocs": self.kv.dropped_allocs,
            "prefix_hits": self.kv.prefix_hits,
            "prefix_tokens_reused": self.kv.prefix_tokens_reused,
        }
