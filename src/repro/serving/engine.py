"""Serving engine: continuous batching over fixed decode slots, with every
byte routed through the MRM memory control plane.

The engine is an orchestrator over two subsystems that talk through an
explicit :class:`StepPlan` / :class:`StepReport` interface:

- :class:`ComputeBackend` — the JAX compute path: per-slot ring caches,
  per-length jit first-chunk prefill, chunked-prefill continuation
  (``extend``), and one batched decode step per engine step with per-slot
  positions.
- :class:`MemoryPlane`  — the MRM control plane: weights live in a region
  of the chosen tier (written once at deploy, read wholesale every model
  pass — §2.2); KV pages go through :class:`PagedKVManager` (DCM retention
  = expected session lifetime, capacity pressure resolved by an explicit
  eviction/spill/recompute policy); refresh/migrate/drop deadlines are
  serviced as simulation time advances.

Prefix reuse is *real* in both planes (DESIGN.md §6): at admission the
prompt is matched against the radix prefix tree; on a hit the matched
page-aligned tokens are attached in the memory plane (no KV writes) AND
skipped in the compute plane — the slot's caches are seeded from the
donor's published cache snapshot and prefill continues via ``extend`` from
the seeded boundary. A hit therefore cuts prefill chunks, metered KV
writes, and step latency together. **Every** prompt runs *unpadded* on the
one chunked path (DESIGN.md §5): token ``i`` sits at position
``prefix_len + i`` for every request whatever the flags, so shared
prefixes are position-aligned across prompt lengths (multi-turn chat,
shared system prompts, RAG fan-out all match) — "whole-prompt" prefill is
simply the maximal first chunk of the same path. A match may also end
mid-page: with ``tail_copy`` the sub-page tail is copied into the
borrower's own page (metered read + write, DESIGN.md §9) and extend
resumes from the exact token boundary.

Compute reuse covers every mixer family (DESIGN.md §8): attention and MLA
snapshots are *positional* (ring caches masked by stored positions — one
snapshot serves any shorter page-aligned boundary), SSM and hybrid
snapshots are *point* captures of the recurrent state, taken mid-prefill
at page-aligned boundaries (the prompt's last page boundary, plus the
request's own match boundary when sharing was observed there) and valid
only at exactly the boundary they were captured at.

Chunked prefill: prompts longer than ``chunk_tokens`` (or, with
``chunk_tokens=None``, longer than the smallest per-layer ring) are fed to
the model in pieces interleaved with decode rounds, bounding inter-token
latency for resident sessions and admitting prompts beyond
``max_cache_len`` — the ring caches keep the attention window's tail.

Step time (simulation) is modelled per tier from the bytes each phase
actually moved and each tier's read/write bandwidth (tiers progress in
parallel; the slowest tier bounds the step) — so tokens/s and tokens/J
reflect the memory system under test, which is exactly the paper's figure
of merit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.simulator import MemorySystem
from repro.models import transformer as tfm
from repro.serving.kv_cache import PagedKVManager
from repro.serving.radix import PrefixMatch
from repro.serving.scheduler import ContinuousBatchScheduler, Request


@dataclass
class EngineConfig:
    max_slots: int = 4
    max_cache_len: int = 256
    max_prefills_per_step: int = 2
    weight_tier: str = "mrm"
    kv_tier: str = "mrm"
    page_tokens: int = 64
    expected_session_s: float = 600.0
    eos_token: int = 1
    greedy: bool = True
    # radix prefix reuse [53]: match page-aligned prompt prefixes, share
    # their KV pages, and skip their prefill compute (prompts always run
    # unpadded so prefixes stay position-aligned across lengths)
    prefix_caching: bool = True
    # sub-page tail reuse (DESIGN.md §9): a match ending mid-page copies
    # the shared tail into the borrower's page and extend resumes from
    # the exact token boundary (positional stacks)
    tail_copy: bool = True
    # chunked prefill: feed prompts in `chunk_tokens` pieces interleaved
    # with decode rounds (None = one maximal chunk per prompt, clamped to
    # the smallest per-layer ring — the same code path)
    chunk_tokens: Optional[int] = None
    # capacity-pressure policy for the KV tier (see PagedKVManager):
    # "evict-lru" | "spill" | "recompute" | "none" (legacy silent drops)
    kv_pressure_policy: str = "evict-lru"
    kv_spill_tier: Optional[str] = None
    kv_high_watermark: Optional[float] = 0.92
    # reuse -> retention programming (paper §4): a radix node reused
    # `radix_hot_threshold` times is promoted to `radix_hot_retention_s`
    # DCM retention, placed in `radix_hot_tier` when set ("auto" lets
    # core.tiering.solve_placement pick it); unlocked leaves idle past
    # `radix_cold_ttl_s` decay (spill when a spill tier exists, else drop)
    radix_hot_threshold: int = 4
    radix_hot_retention_s: float = 3600.0
    radix_hot_tier: Optional[str] = None
    radix_cold_ttl_s: Optional[float] = None
    # pressure-driven demotion (DESIGN.md §9): a hot node is re-programmed
    # back to short retention (metered) before leaf eviction may reach it
    demote_on_pressure: bool = False
    # regression guard (the PR 4 clobbering class): verify after every
    # decode round that no cache family of an inactive slot was written
    audit_decode_masking: bool = False
    # paged compute plane (DESIGN.md §10): run extend and decode directly
    # on the pages PagedKVManager owns — a radix or migrated prefix hit
    # is a page-table splice (zero copy bytes) and tier reads meter the
    # kernel's actual per-page gather stream. Universal across mixer
    # families: attention/MLA compute on KV pages, SSM/hybrid on pooled
    # point-state pages (conv + SSD state at page-boundary capture
    # points) drawn from the same free-list.
    paged_kernel: bool = False
    # paged-attention kernel block shape / DMA pipeline depth overrides
    # (None = the autotuner's cached best config for this geometry;
    # kernels/paged_attention/tune.py)
    kernel_block_q: Optional[int] = None
    kernel_block_kv: Optional[int] = None
    kernel_buffers: Optional[int] = None
    # reliability plane (DESIGN.md §11): inject age-driven bit flips into
    # the paged KV/state pages of decoding sessions, anchored so a page
    # exactly at its programmed retention sees this RBER (None = off).
    # Whether flips are corrected follows the MemorySystem's ecc_profile:
    # under an active profile, critical flips land only on uncorrectable
    # blocks and near-deadline pages scrub-on-read instead (metered).
    inject_rber: Optional[float] = None
    inject_seed: int = 0
    # abandonment (DESIGN.md §12): queued requests older than this are
    # dropped before admission — the user hung up before first token.
    # Sessions already holding slots always run to completion (None = off).
    abandon_after_s: Optional[float] = None


# ---------------------------------------------------------------------------
# StepPlan / StepReport: the contract between scheduler, compute and memory
# ---------------------------------------------------------------------------


@dataclass
class PrefillChunk:
    """One piece of a prompt scheduled for this step."""
    slot: int
    request_id: int
    tokens: np.ndarray
    offset: int    # absolute start position (incl. meta/frontend prefix)
    first: bool    # creates the slot's caches (runs full prefill)
    last: bool     # completes the prompt (samples the first output token)


@dataclass
class StepPlan:
    """What this engine step will do: the scheduler builds it, the
    ComputeBackend executes it, the MemoryPlane meters it."""
    prefill: List[PrefillChunk] = field(default_factory=list)
    decode: List[int] = field(default_factory=list)  # slots


@dataclass
class StepReport:
    """What an engine step did, with the per-tier byte/latency breakdown."""
    step_s: float = 0.0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    finished: int = 0
    bytes_by_tier: Dict[str, dict] = field(default_factory=dict)

    @property
    def bytes(self) -> float:
        return sum(t["read_bytes"] + t["write_bytes"]
                   for t in self.bytes_by_tier.values())


@dataclass
class SnapshotHandle:
    """A donor slot's cache snapshot with its metered backing region.

    The compute-plane arrays used to be held as unmetered Python-side JAX
    arrays (ROADMAP: snapshot memory accounting); they are now carved from
    the KV tier budget — a metered region write at publication (actual
    array bytes, compute scale: the acct-scale KV bytes already live in
    the paged manager, metering both would double-count the same state),
    released when the owning radix node leaves the tree. The manager
    releases via duck-typed ``release()`` so it stays payload-agnostic.

    ``kind``/``tokens`` are the per-architecture validity contract
    (DESIGN.md §8): a ``"positional"`` snapshot (attention KV, MLA latent
    cache) covers every page-aligned boundary up to ``tokens`` because
    stale entries stay position-masked; a ``"point"`` snapshot (SSM
    recurrent state, hybrid union) is valid *only* at exactly ``tokens``
    absolute positions (incl. the meta/frontend prefix)."""
    caches: object
    nbytes: float
    mem: MemorySystem
    region_id: Optional[int]
    kind: str = "positional"
    tokens: int = 0

    def release(self) -> None:
        if self.region_id is not None:
            self.mem.release_region(self.region_id)
            self.region_id = None

    @property
    def live(self) -> bool:
        return self.region_id is not None


@dataclass
class _SlotPrefill:
    """Continuation state of a (possibly radix-shortened) chunked prefill:
    how far into the prompt the slot's caches already reach — a prefix hit
    starts `done` at the seeded boundary (which, with sub-page tail reuse,
    need not be page-aligned) instead of 0.

    For point-snapshot stacks (SSM/hybrid, DESIGN.md §8) the prefill also
    carries up to two page-aligned *capture points* (prompt-index space):
    ``snap_match_at`` — the observed-share boundary (this request's own
    match), whose snapshot is attached to the matched radix node as soon
    as the prefill crosses it — and ``snap_end_at`` — the speculative
    last page boundary of the prompt, published with the prompt's
    registration. ``next_chunk`` splits chunks at these points so the
    recurrent state is capturable exactly there."""
    req: Request
    tokens: np.ndarray            # prompt tokens (always unpadded)
    chunk: int
    key: Optional[np.ndarray]     # radix key: prefix_len sentinels + tokens
    match: Optional[PrefixMatch]
    done: int = 0   # tokens of `tokens` already in the slot's caches
    grid: Optional[int] = None            # point stacks: page-aligned chunking
    snap_match_at: Optional[int] = None   # point capture: match boundary
    snap_end_at: Optional[int] = None     # point capture: last page boundary
    point_caches: object = None           # the end-boundary capture

    def next_chunk(self, slot: int, prefix_len: int) -> PrefillChunk:
        end = min(self.done + self.chunk, len(self.tokens))
        if self.grid:
            # point-snapshot stacks chunk on the position-space page grid:
            # recurrent-state arithmetic depends on the chunk partition, so
            # every engine must cut prompts identically (seeded resumption
            # stays bit-equal to a cold run) and every capture boundary
            # lands exactly on a chunk end (DESIGN.md §8)
            nxt = ((prefix_len + self.done) // self.grid + 1) * self.grid \
                - prefix_len
            end = min(end, max(nxt, self.done + 1))
        return PrefillChunk(slot, self.req.request_id,
                            self.tokens[self.done:end],
                            offset=prefix_len + self.done,
                            first=self.done == 0,
                            last=end == len(self.tokens))


# ---------------------------------------------------------------------------
# ComputeBackend: the JAX half
# ---------------------------------------------------------------------------


class ComputeBackend:
    """Real-model compute over fixed decode slots: per-length jit
    first-chunk prefill (prompts are never padded — the compile cache is
    keyed by the exact chunk length), chunked-prefill continuation
    (extend), batched decode. Owns the dense ring caches and per-slot
    positions/tokens; knows nothing about tiers, pages or retention."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 paged: bool = False):
        self.cfg = cfg
        self.ecfg = ecfg
        self.params = params
        self.paged = paged
        B = ecfg.max_slots
        self.positions = np.full((B,), -1, np.int64)  # last written position
        self.last_tokens = np.zeros((B, 1) if cfg.n_codebooks == 1
                                    else (B, 1, cfg.n_codebooks), np.int32)
        self._prefill_jit: Dict[int, callable] = {}
        self._extend_jit: Dict[int, callable] = {}
        self.seed_copy_bytes = 0.0  # ring-path donor seeding copy traffic
        if paged:
            # paged compute plane (DESIGN.md §10): one pooled page array per
            # cache family, owned here, indexed by PagedKVManager pages via
            # Page.compute_page. Page 0 is the reserved null page (gathered
            # for padded table slots; auto-masked by slot-derived positions)
            self.caches = None
            self.page_tokens = ecfg.page_tokens
            n0 = max(16, 1 + B * -(-ecfg.max_cache_len // ecfg.page_tokens))
            self.paged_caches = tfm.init_paged_caches(
                cfg, n0, ecfg.page_tokens, jnp.dtype(cfg.dtype))
            self._free = list(range(n0 - 1, 0, -1))  # pop() -> lowest id
            self._paged_first_jit: Dict[tuple, callable] = {}
            self._paged_extend_jit: Dict[tuple, callable] = {}
            self._paged_decode_jit: Dict[int, callable] = {}
        else:
            self.caches = tfm.init_caches(cfg, B, ecfg.max_cache_len,
                                          jnp.dtype(cfg.dtype))
            self._decode_jit = jax.jit(
                lambda p, c, t, pos, act: tfm.decode(cfg, p, c, t, pos,
                                                     active=act))

    # -- per-length jit caches -----------------------------------------
    def _prefill_fn(self, length: int):
        if length not in self._prefill_jit:
            cfg, ecfg = self.cfg, self.ecfg

            def fn(p, batch):
                return tfm.prefill(cfg, p, batch,
                                   max_cache_len=ecfg.max_cache_len)

            self._prefill_jit[length] = jax.jit(fn)
        return self._prefill_jit[length]

    def _extend_fn(self, length: int):
        if length not in self._extend_jit:
            cfg = self.cfg
            # offset is a traced argument: one executable per chunk length
            self._extend_jit[length] = jax.jit(
                lambda p, c, t, off: tfm.extend(cfg, p, c, t, off))
        return self._extend_jit[length]

    # -- paged compute-page pool (DESIGN.md §10) -----------------------
    @staticmethod
    def table_width(n_pages: int) -> int:
        """Power-of-2 page-table width bucket (bounds jit retraces)."""
        return max(1, 1 << (max(1, n_pages) - 1).bit_length())

    def _grow_pool(self) -> None:
        """Double the page pool — zeros appended on the page axis of every
        cache-family leaf. jit'd steps retrace on the new pool shape."""
        grown = []

        def widen(a):
            pad = jnp.zeros(a.shape[:1] + (a.shape[1],) + a.shape[2:],
                            a.dtype)
            grown.append(a.shape[1])
            return jnp.concatenate([a, pad], axis=1)

        self.paged_caches = jax.tree.map(widen, self.paged_caches)
        old = grown[0]
        self._free.extend(range(2 * old - 1, old - 1, -1))

    def alloc_page(self) -> int:
        if not self._free:
            self._grow_pool()
        return self._free.pop()

    def free_page(self, pid: int) -> None:
        """Return a compute page to the pool. No zeroing needed: a reused
        page's stale rows sit above the new owner's written length, where
        slot-derived key positions exceed every query position (masked)."""
        self._free.append(pid)

    def copy_page_rows(self, src: int, dst: int, n: int) -> None:
        """Copy rows [0, n) of compute page `src` into `dst` across every
        cache family — the sub-page tail seeding primitive (DESIGN.md §9):
        the only bytes a prefix hit ever copies on the paged plane."""
        self.paged_caches = jax.tree.map(
            lambda a: a.at[:, dst, :n].set(a[:, src, :n]), self.paged_caches)

    def export_pages(self, ids: List[int]):
        """Host-side copy of the listed compute pages (page axis first in
        each leaf slice) — the migration wire format."""
        idx = np.asarray(ids, np.int32)
        return jax.tree.map(lambda a: np.asarray(a[:, idx]),
                            self.paged_caches)

    def import_pages(self, ids: List[int], data) -> None:
        idx = jnp.asarray(np.asarray(ids, np.int32))
        self.paged_caches = jax.tree.map(
            lambda a, d: a.at[:, idx].set(jnp.asarray(d, a.dtype)),
            self.paged_caches, data)

    def pages_compatible(self, data) -> bool:
        """Foreign page data is adoptable only when its tree structure and
        per-page leaf shapes/dtypes match this pool exactly."""
        try:
            if (jax.tree.structure(data)
                    != jax.tree.structure(self.paged_caches)):
                return False
        except Exception:
            return False
        return all(
            d.shape[0] == a.shape[0] and d.shape[2:] == a.shape[2:]
            and d.dtype == a.dtype
            for d, a in zip(jax.tree.leaves(data),
                            jax.tree.leaves(self.paged_caches)))

    def _paged_first_fn(self, length: int, W: int):
        key = (length, W)
        if key not in self._paged_first_jit:
            cfg, pt = self.cfg, self.page_tokens
            self._paged_first_jit[key] = jax.jit(
                lambda p, c, batch, tbl: tfm.paged_prefill(cfg, p, batch,
                                                           c, tbl,
                                                           page_tokens=pt))
        return self._paged_first_jit[key]

    def _paged_extend_fn(self, length: int, W: int):
        key = (length, W)
        if key not in self._paged_extend_jit:
            cfg, pt = self.cfg, self.page_tokens
            self._paged_extend_jit[key] = jax.jit(
                lambda p, c, t, off, tbl: tfm.paged_extend(cfg, p, c, t,
                                                           off, tbl,
                                                           page_tokens=pt))
        return self._paged_extend_jit[key]

    def _paged_decode_fn(self, W: int):
        if W not in self._paged_decode_jit:
            cfg, pt = self.cfg, self.page_tokens
            self._paged_decode_jit[W] = jax.jit(
                lambda p, c, t, pos, tbl, act: tfm.paged_decode(
                    cfg, p, c, t, pos, tbl, active=act, page_tokens=pt))
        return self._paged_decode_jit[W]

    # -- slot cache plumbing -------------------------------------------
    def _insert_slot(self, slot: int, new_caches) -> None:
        """Copy a B=1 cache tree into decode-slot `slot`."""
        self.caches = jax.tree.map(
            lambda dst, src: dst.at[:, slot].set(src[:, 0]),
            self.caches, new_caches)

    def _extract_slot(self, slot: int):
        """View decode-slot `slot` as a B=1 cache tree (for extend)."""
        return jax.tree.map(lambda a: a[:, slot:slot + 1], self.caches)

    def snapshot_slot(self, slot: int):
        """Immutable B=1 snapshot of a slot's ring caches (jax arrays are
        immutable, so the sliced tree is a stable donor handle)."""
        return self._extract_slot(slot)

    def seed_slot(self, slot: int, snapshot) -> None:
        """Seed a slot's ring caches from a donor snapshot (prefix hit).
        Donor entries beyond the matched prefix are harmless: masking is
        position-based (`cache_pos <= cur`), so stale positions stay masked
        until this request overwrites them via extend/decode.

        This is the ring path's per-hit copy cost — every hit rewrites a
        full per-slot cache tree. The paged plane replaces it with a
        page-table splice (zero copy bytes); ``seed_copy_bytes`` is the
        comparator the paged_kernel benchmark sweeps against."""
        self.seed_copy_bytes += float(sum(
            a.size * a.dtype.itemsize for a in jax.tree.leaves(snapshot)))
        self._insert_slot(slot, snapshot)

    def prefix_len(self) -> int:
        return self.cfg.n_meta_tokens + (self.cfg.n_frontend_tokens
                                         if self.cfg.frontend == "vision" else 0)

    def sample(self, logits):
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    # -- StepPlan execution --------------------------------------------
    def run_prefill_chunk(self, ck: PrefillChunk,
                          page_table: Optional[np.ndarray] = None
                          ) -> Optional[np.ndarray]:
        """Execute one prefill chunk. Returns the sampled next token when
        the chunk completes the prompt, else None. On the paged plane the
        chunk computes in place on the pool pages listed in ``page_table``
        (the request's session pages) — no per-slot ring insert."""
        toks = np.asarray(ck.tokens, np.int32)
        if self.paged:
            assert page_table is not None
            tbl = jnp.asarray(page_table, jnp.int32)[None]
            W = int(tbl.shape[1])
            if ck.first:
                batch = {"tokens": jnp.asarray(toks)[None]}
                if self.cfg.frontend == "vision":
                    batch["image_embeds"] = jnp.zeros(
                        (1, self.cfg.n_frontend_tokens, self.cfg.d_model),
                        jnp.dtype(self.cfg.dtype))
                logits, self.paged_caches = self._paged_first_fn(
                    toks.shape[0], W)(self.params, self.paged_caches,
                                      batch, tbl)
            else:
                logits, self.paged_caches = self._paged_extend_fn(
                    toks.shape[0], W)(self.params, self.paged_caches,
                                      jnp.asarray(toks)[None], ck.offset,
                                      tbl)
        elif ck.first:
            batch = {"tokens": jnp.asarray(toks)[None]}
            if self.cfg.frontend == "vision":
                batch["image_embeds"] = jnp.zeros(
                    (1, self.cfg.n_frontend_tokens, self.cfg.d_model),
                    jnp.dtype(self.cfg.dtype))
            logits, caches1 = self._prefill_fn(toks.shape[0])(self.params, batch)
            self._insert_slot(ck.slot, caches1)
        else:
            caches1 = self._extract_slot(ck.slot)
            logits, caches1 = self._extend_fn(toks.shape[0])(
                self.params, caches1, jnp.asarray(toks)[None], ck.offset)
            self._insert_slot(ck.slot, caches1)
        if not ck.last:
            return None
        tok = np.asarray(self.sample(logits))
        self.last_tokens[ck.slot] = tok
        self.positions[ck.slot] = ck.offset + toks.shape[0] - 1
        return tok

    def run_decode(self, slots: List[int],
                   page_tables: Optional[np.ndarray] = None,
                   audit_pages: Optional[List[int]] = None) -> np.ndarray:
        """One batched decode round over `slots` (other rows' caches are
        left untouched via the active mask — a mid-prefill slot must not be
        clobbered). Returns the sampled tokens for all B rows. On the
        paged plane ``page_tables`` is the (B, W) compute-page table
        (inactive rows all-null) and ``audit_pages`` lists compute pages
        the round must not write (other sessions' pages)."""
        B = self.ecfg.max_slots
        act = np.zeros((B,), bool)
        act[slots] = True
        inactive = [s for s in range(B) if not act[s]]
        before = None
        if self.paged:
            assert page_tables is not None
            if self.ecfg.audit_decode_masking and audit_pages:
                idx = np.asarray(audit_pages, np.int32)
                before = [np.asarray(leaf[:, idx])
                          for leaf in jax.tree.leaves(self.paged_caches)]
            pos = jnp.asarray(np.maximum(self.positions + 1, 0), jnp.int32)
            tbl = jnp.asarray(page_tables, jnp.int32)
            logits, self.paged_caches = self._paged_decode_fn(
                int(tbl.shape[1]))(self.params, self.paged_caches,
                                   jnp.asarray(self.last_tokens), pos, tbl,
                                   jnp.asarray(act))
            if before is not None:
                # paged variant of the clobbering guard: a decode round
                # writes exactly one row of each active session's open
                # page — shared (sealed) pages and other sessions' pages
                # must come back bit-identical
                idx = np.asarray(audit_pages, np.int32)
                for b, leaf in zip(before,
                                   jax.tree.leaves(self.paged_caches)):
                    after = np.asarray(leaf[:, idx])
                    assert np.array_equal(b, after, equal_nan=True), \
                        "decode wrote another session's compute page " \
                        "(paged masking regression)"
        else:
            if self.ecfg.audit_decode_masking and inactive:
                before = [np.asarray(leaf[:, inactive])
                          for leaf in jax.tree.leaves(self.caches)]
            pos = jnp.asarray(np.maximum(self.positions + 1, 0), jnp.int32)
            logits, self.caches = self._decode_jit(
                self.params, self.caches, jnp.asarray(self.last_tokens), pos,
                jnp.asarray(act))
            if before is not None:
                # regression guard for the PR 4 clobbering class: with the
                # padded whole-prompt path gone, chunked prefill interleaves
                # with decode for every stack — a decode round must not write
                # ANY cache family (ring KV, MLA latents, conv/SSD state) of
                # a slot it did not decode
                for b, leaf in zip(before, jax.tree.leaves(self.caches)):
                    after = np.asarray(leaf[:, inactive])
                    assert np.array_equal(b, after, equal_nan=True), \
                        "decode wrote an inactive slot's cache (active-slot " \
                        "masking regression)"
        next_np = np.asarray(self.sample(logits))
        for slot in slots:
            self.positions[slot] += 1
            self.last_tokens[slot] = next_np[slot]
        return next_np

    def free_slot(self, slot: int) -> None:
        self.positions[slot] = -1


# ---------------------------------------------------------------------------
# MemoryPlane: the MRM control-plane half
# ---------------------------------------------------------------------------


def choose_hot_tier(mem: MemorySystem, cfg: ModelConfig,
                    ecfg: EngineConfig) -> Optional[str]:
    """Pick the tier hot (frequently reused) prefix KV should live in, via
    the paper-§4 placement solver: a read-heavy, rarely-rewritten,
    long-lived data class over the engine's actual tiers. Returns a tier
    *name*, or None when the solve is infeasible. solve_placement speaks
    technology names, so each tier's tech is aliased to its tier name —
    two tiers sharing one technology stay distinguishable."""
    import dataclasses

    from repro.core.memclass import YEAR
    from repro.core.tiering import DataClassProfile, Tier, solve_placement

    tiers = [Tier(tech=dataclasses.replace(d.tech, name=name),
                  capacity_bytes=d.capacity)
             for name, d in mem.devices.items()]
    size = 0.25 * mem.devices[ecfg.kv_tier].capacity
    hot = DataClassProfile(
        name="kv_prefix_hot", size_bytes=size,
        read_bw_bytes_s=size,                        # reread ~once per second
        write_bw_bytes_s=size / ecfg.radix_hot_retention_s,  # rewritten per retention
        lifetime_s=ecfg.radix_hot_retention_s, soft_state=True)
    res = solve_placement([hot], tiers, device_life_s=5 * YEAR)
    if not res.feasible:
        return None
    name = res.assignment["kv_prefix_hot"]
    return name if name in mem.devices else None


class MemoryPlane:
    """Weight regions + paged KV + per-tier step metering. All placement,
    retention and pressure decisions live here; the accounting scale
    (``acct_cfg``) is decoupled from the compute scale."""

    def __init__(self, acct_cfg: ModelConfig, mem: MemorySystem,
                 ecfg: EngineConfig, paged: bool = False):
        self.cfg = acct_cfg
        self.mem = mem
        self.ecfg = ecfg
        hot_tier = ecfg.radix_hot_tier
        if hot_tier == "auto":
            hot_tier = choose_hot_tier(mem, acct_cfg, ecfg)
        elif hot_tier is not None and hot_tier not in mem.devices:
            raise ValueError(f"radix_hot_tier {hot_tier!r} is not a tier "
                             f"({sorted(mem.devices)})")
        self.hot_tier = hot_tier
        # point-state pages ride on KV pages only on the paged plane; the
        # ring path meters recurrent state through the engine's
        # SnapshotHandle regions instead (charging both would double-count)
        state_bp = float(acct_cfg.state_bytes_per_page()) if paged else 0.0
        self.kv = PagedKVManager(acct_cfg, mem, ecfg.kv_tier,
                                 ecfg.page_tokens, ecfg.expected_session_s,
                                 spill_tier=ecfg.kv_spill_tier,
                                 policy=ecfg.kv_pressure_policy,
                                 high_watermark=ecfg.kv_high_watermark,
                                 hot_threshold=ecfg.radix_hot_threshold,
                                 hot_retention_s=ecfg.radix_hot_retention_s,
                                 hot_tier=hot_tier,
                                 cold_ttl_s=ecfg.radix_cold_ttl_s,
                                 tail_copy=ecfg.tail_copy,
                                 demote_on_pressure=ecfg.demote_on_pressure,
                                 state_bytes_page=state_bp)
        counts = acct_cfg.param_counts()
        self.weight_bytes = counts["total"] * 2  # bf16
        self.active_weight_bytes = counts["active"] * 2
        # deploy weights into the weight tier (written once — §2 of paper)
        self.weight_region = self._deploy()
        self._snap = None

    def _deploy(self) -> Optional[int]:
        return self.mem.write_region(
            self.ecfg.weight_tier, "weights", self.weight_bytes,
            expected_lifetime_s=self.mem.devices[
                self.ecfg.weight_tier].tech.retention_s)

    def redeploy_weights(self) -> None:
        """Model update (paper §2/§3: bulk weight overwrite): release the
        old weight region and write the new one — the wear/endurance
        accounting of Figure 1's weight-update bars, from the system."""
        self.mem.release_region(self.weight_region)
        self.weight_region = self._deploy()

    # -- per-step metering ---------------------------------------------
    def begin_step(self) -> None:
        self._snap = self.mem.snapshot()

    def weight_pass(self) -> None:
        """One model pass streams the active weights from the weight tier."""
        self.mem.read_region(self.weight_region, self.active_weight_bytes)

    def finish_step(self):
        """Per-tier step latency: each tier's traffic at its own read/write
        bandwidth, tiers in parallel -> the slowest bounds the step."""
        return self.mem.step_latency_since(self._snap)

    def report(self) -> dict:
        return self.mem.report()


# ---------------------------------------------------------------------------
# ServeEngine: the orchestrator
# ---------------------------------------------------------------------------


class ServeEngine:
    """One replica's orchestrator: plans each step (prefill chunks + one
    decode round), executes it against the :class:`ComputeBackend` and
    :class:`MemoryPlane`, and advances the simulated clock by the modelled
    per-tier step latency.

    Invariants the tests rely on:

    - **Hit/cold equivalence** — a prefix hit (seeded slot + extend from
      the boundary) and a cross-replica migrated hit decode bit-identically
      (fp32) to a cold start; at least one prompt token always computes.
    - **Snapshot accounting** — every published compute snapshot is a
      metered region in the KV tier (``SnapshotHandle``), released when
      its radix node leaves the tree; ``live_snapshot_bytes`` never leaks.
    - **Point-capture validity** — a ``kind="point"`` snapshot is only
      published at a page-aligned boundary the slot's caches exactly
      reached, and only seeded when the borrower's match covers it
      (DESIGN.md §8).
    """

    def __init__(self, cfg: ModelConfig, params, mem: MemorySystem,
                 ecfg: EngineConfig, account_cfg: Optional[ModelConfig] = None):
        """``account_cfg`` decouples the memory-accounting scale from the
        compute scale: CPU tests run a reduced model for real token
        generation while the control plane meters the *deployment-size*
        config's weight/KV byte streams (the paper's figures of merit)."""
        self.cfg = cfg
        self.acct_cfg = account_cfg or cfg
        self.params = params
        self.mem = mem
        self.ecfg = ecfg
        # how this stack's prefix snapshots may be reused (DESIGN.md §8):
        # "positional" (attention/MLA) or "point" (SSM/hybrid)
        self.snapshot_kind = tfm.snapshot_kind(cfg)
        # paged compute plane (DESIGN.md §10), universal across mixer
        # families: positional stacks compute on KV pages, point stacks
        # (SSM/hybrid) on pooled state pages capturing the recurrent state
        # at every page boundary — so a radix or migrated hit is a
        # page-table splice for all four families
        self.paged = bool(ecfg.paged_kernel) and tfm.supports_extend(cfg)
        if (ecfg.kernel_block_q or ecfg.kernel_block_kv
                or ecfg.kernel_buffers):
            # pin the Pallas launch config for this page geometry: the
            # explicit overrides land in the autotuner's config cache,
            # which every ragged_paged_attention launch consults
            from repro.kernels.paged_attention.tune import (KernelConfig,
                                                            best_config,
                                                            set_config)
            base = best_config(ecfg.page_tokens, cfg.resolved_head_dim)
            set_config(ecfg.page_tokens, cfg.resolved_head_dim,
                       KernelConfig(
                           block_q=ecfg.kernel_block_q or base.block_q,
                           block_kv=ecfg.kernel_block_kv or base.block_kv,
                           num_buffers=(ecfg.kernel_buffers
                                        or base.num_buffers)))
        self.sched = ContinuousBatchScheduler(ecfg.max_slots,
                                              ecfg.max_prefills_per_step)
        self.backend = ComputeBackend(cfg, params, ecfg, paged=self.paged)
        self.memplane = MemoryPlane(self.acct_cfg, mem, ecfg,
                                    paged=self.paged)
        self.kernel_read_bytes = 0.0   # paged: metered kernel page gathers
        if self.paged:
            # every memory-plane page owns one compute page for its life —
            # a radix hit shares the Page object, hence the compute page:
            # zero copy bytes
            self.memplane.kv.on_page_alloc = self._on_page_alloc
            self.memplane.kv.on_page_release = self._on_page_release
            # per-layer (kv_bytes_per_token, window, state_bytes) at the
            # accounting scale: the analytic model of the kernel's per-page
            # read stream — positional layers gather token rows, point
            # layers additionally pull one state-page snapshot per step
            self._acct_layers = []
            state_lb = float(self.acct_cfg.ssm_state_bytes_layer())
            for spec in self.acct_cfg.layer_specs():
                if spec.kind == "mla":
                    lb, sb = (self.acct_cfg.kv_lora_rank
                              + self.acct_cfg.qk_rope_dim) * 2, 0.0
                elif spec.kind == "attn":
                    lb, sb = (2 * self.acct_cfg.n_kv_heads
                              * self.acct_cfg.resolved_head_dim * 2), 0.0
                elif spec.kind == "hybrid":
                    lb = (2 * self.acct_cfg.n_kv_heads
                          * self.acct_cfg.resolved_head_dim * 2)
                    sb = state_lb
                else:                      # ssm: no KV token stream
                    lb, sb = 0.0, state_lb
                self._acct_layers.append((float(lb), spec.window, sb))
        # fault injection (DESIGN.md §11): age-driven flips over the paged
        # compute plane, sampled against each page's tracked region
        self.faults = None
        if ecfg.inject_rber:
            from repro.core.faults import FaultInjector
            self.faults = FaultInjector(mem, ecfg.inject_rber,
                                        seed=ecfg.inject_seed)
        self.outputs: Dict[int, list] = {}
        self._inflight: Dict[int, _SlotPrefill] = {}  # slot -> chunk state
        self._prep_cache: Dict[int, tuple] = {}  # rid -> (tokens, chunk, key)
        self.tokens_generated = 0
        self.steps = 0
        self.prefill_chunks_run = 0
        self.prefill_tokens_computed = 0   # tokens that ran through the model
        self.prefill_tokens_skipped = 0    # tokens a radix hit skipped
        self.prefix_compute_hits = 0       # admissions seeded from a donor
        self.snapshots_published = 0       # metered donor snapshots created
        self._snap_spec = None             # cached foreign-snapshot template

    # -- legacy surface (kept stable for callers/tests) ----------------
    @property
    def kv(self) -> PagedKVManager:
        return self.memplane.kv

    @property
    def weight_bytes(self) -> float:
        return self.memplane.weight_bytes

    @property
    def active_weight_bytes(self) -> float:
        return self.memplane.active_weight_bytes

    @property
    def weight_region(self):
        return self.memplane.weight_region

    @property
    def caches(self):
        return self.backend.caches

    @property
    def positions(self):
        return self.backend.positions

    @property
    def last_tokens(self):
        return self.backend.last_tokens

    def redeploy_weights(self) -> None:
        self.memplane.redeploy_weights()

    # ------------------------------------------------------------------
    def submit(self, prompt_tokens: list, max_new_tokens: int,
               migrated_tokens: int = 0, at: Optional[float] = None,
               admit_after: Optional[float] = None) -> int:
        """``migrated_tokens`` marks how many leading tokens a cross-replica
        migration just grafted into this replica's tree for this request —
        the scheduler counts them as a match for prefix-aware admission
        even if the grafted leaf is evicted before the request is picked.

        ``at`` stamps an explicit arrival time (event-driven drivers
        submit from a fleet clock that may be ahead of this replica's);
        ``admit_after`` defers admission — an event-mode migration lands
        its pages at the link's delivery time and the triggering request
        waits for them, so its TTFT pays queue wait + transfer.

        Any prompt length is admissible: there is one unpadded chunked
        path (DESIGN.md §5), and a prompt longer than the smallest
        per-layer ring is simply split into ring-bounded chunks even with
        ``chunk_tokens=None`` — the ring caches keep the attention
        window's tail, exactly as decode does."""
        rid = len(self.outputs)
        self.outputs[rid] = []
        submitted = self.mem.now if at is None else at
        self.sched.submit(Request(rid, prompt_tokens, max_new_tokens,
                                  submitted,
                                  migrated_tokens=migrated_tokens,
                                  admit_after=(submitted if admit_after is None
                                               else admit_after)))
        return rid

    # ------------------------------------------------------------------
    def _min_ring_len(self) -> int:
        """Smallest per-layer cache ring (windowed layers have rings of
        cache_len_for(window, max_cache_len) < max_cache_len)."""
        from repro.models.attention import cache_len_for
        return min(cache_len_for(spec.window, self.ecfg.max_cache_len)
                   for spec in self.cfg.layer_specs())

    def _chunk_plan(self, toks: np.ndarray) -> tuple:
        """(tokens, chunk) for a prompt — **never padded** (DESIGN.md §5):
        token i sits at position prefix_len + i for every request, so
        shared prefixes are position-aligned and radix-matchable across
        prompt lengths (the tail chunk compiles per distinct length;
        acceptable for the sim). ``chunk_tokens=None`` means one maximal
        chunk on the same path. Either way the chunk is clamped to the
        smallest per-layer ring — a larger chunk would collide intra-chunk
        ring slots (duplicate scatter indices) — and once the prompt
        overflows the ring it is halved so each extend still sees the
        previous chunks' tail."""
        ecfg = self.ecfg
        L = toks.shape[0]
        min_ring = self._min_ring_len()
        chunk = L if ecfg.chunk_tokens is None else ecfg.chunk_tokens
        chunk = min(chunk, min_ring)
        if L + self.backend.prefix_len() > min_ring:
            chunk = min(chunk, max(16, min_ring // 2))
        return toks, max(1, min(chunk, L))

    def _radix_key(self, toks: np.ndarray) -> np.ndarray:
        """Radix tokens in *position space*: the meta/frontend prefix is a
        run of sentinel tokens shared by every request on this engine, so
        page boundaries in the tree line up with KV page boundaries."""
        plen = self.backend.prefix_len()
        if plen == 0:
            return toks
        sent = np.full((plen,) + toks.shape[1:], -1, toks.dtype)
        return np.concatenate([sent, toks], axis=0)

    def _prep(self, req: Request) -> tuple:
        """(tokens, chunk, radix_key) for a request, memoized while it sits
        in the queue (prefix-aware admission rescoring would otherwise
        rebuild the arrays per scheduling round)."""
        ent = self._prep_cache.get(req.request_id)
        if ent is None:
            toks = np.asarray(req.prompt_tokens, np.int32)
            toks, chunk = self._chunk_plan(toks)
            key = self._radix_key(toks) if self.ecfg.prefix_caching else None
            ent = (toks, chunk, key)
            self._prep_cache[req.request_id] = ent
        return ent

    def radix_key_for(self, prompt_tokens: list) -> Optional[np.ndarray]:
        """Position-space radix key for a raw prompt (sentinel meta prefix
        + unpadded tokens) — the key the tree, the fleet prefix directory
        and cross-replica migration all share. None with prefix caching
        off."""
        if not self.ecfg.prefix_caching:
            return None
        return self._radix_key(np.asarray(prompt_tokens, np.int32))

    def prefix_match_len(self, prompt_tokens: list) -> int:
        """Longest radix-matchable prefix (in position-space tokens) this
        engine holds for `prompt_tokens` — side-effect-free; the cluster
        router and prefix-aware scheduler score with this."""
        key = self.radix_key_for(prompt_tokens)
        return 0 if key is None else self.kv.match_len(key)

    def _point_snapshot_for(self, node, max_tokens: int
                            ) -> Optional[SnapshotHandle]:
        """Deepest live *point* snapshot usable at a match ending at
        ``node`` with ``max_tokens`` matched positions: a handle on the
        node's ancestor path or in its subtree is sound iff its boundary
        ``tokens`` is covered both by the borrower's match (the state
        integrates only tokens the borrower shares) and by the holder's
        own root path (the tree vouches for exactly that run — a
        registration truncated by unsealed/dropped pages may sit above
        its snapshot's boundary). The deepest such handle skips the most
        compute. Tree traversal lives with the tree
        (:meth:`RadixKVIndex.payload_candidates`)."""
        best = None
        for h, depth in self.kv.radix.payload_candidates(node):
            if (isinstance(h, SnapshotHandle) and h.live and h.kind == "point"
                    and h.tokens <= min(max_tokens, depth)
                    and (best is None or h.tokens > best.tokens)):
                best = h
        return best

    def _compute_reuse(self, match: PrefixMatch, toks: np.ndarray) -> tuple:
        """(tokens of the prompt the compute plane may skip, the snapshot
        to seed from, sub-page tail tokens used). Requires a donor
        snapshot valid at a boundary covering the whole meta/frontend
        region (extend cannot restart mid-meta). At least one token always
        runs — the last position's logits seed the first sampled token.

        Positional stacks (attention/MLA) seed from a payload whose token
        history covers the *resumption point*: with a sub-page tail
        (DESIGN.md §9) that must be a payload in the tail child's subtree
        (every prompt below it shares the tail run), so extend resumes
        from the exact token boundary ``match.tokens + tail``; otherwise
        the nearest payload at or below the match serves the page-aligned
        boundary — stale entries beyond it stay masked. Point stacks
        (SSM/hybrid) seed only from a snapshot captured at an
        exactly-shared page-aligned boundary (DESIGN.md §8) — the deepest
        one at or under the match length wins; a mid-page boundary never
        has a capture, so tails stay memory-plane-only there (i.e.
        unused)."""
        plen = self.backend.prefix_len()
        L = toks.shape[0]
        if match.tokens == 0 or not tfm.supports_extend(self.cfg):
            return 0, None, 0
        if self.paged:
            # paged plane: the matched pages ARE the compute state — no
            # donor snapshot exists or is needed. The hit is a page-table
            # splice; only a sub-page tail copies (page rows, DESIGN.md §9).
            # Point stacks have no mid-page state snapshot, so tails stay
            # off and resumption is clamped DOWN to the last page boundary
            # (the state page there holds the exact boundary state)
            tail = (self.kv.tail_available(match)
                    if self.ecfg.tail_copy
                    and self.snapshot_kind == "positional" else 0)
            reuse = max(0, min(match.tokens + tail - plen, L - 1))
            tail = max(0, min(tail, reuse - (match.tokens - plen)))
            if self.snapshot_kind == "point":
                pt = self.ecfg.page_tokens
                reuse = max(0, ((plen + reuse) // pt) * pt - plen)
            return (reuse, None, tail) if reuse else (0, None, 0)
        if self.snapshot_kind == "positional":
            payload, tail = None, 0
            avail = self.kv.tail_available(match)
            if self.ecfg.tail_copy and avail:
                p = self.kv.radix.subtree_payload(match.tail_node)
                if (isinstance(p, SnapshotHandle) and p.live
                        and p.tokens >= match.tokens + avail):
                    payload, tail = p, avail
            if payload is None:
                payload = match.payload
            if payload is None:
                return 0, None, 0
            reuse = max(0, min(match.tokens + tail - plen, L - 1))
            # the one-token-always-computes clamp may land the resumption
            # point back inside the tail; only the tokens actually skipped
            # past the page boundary are worth copying in the memory plane
            tail = max(0, min(tail, reuse - (match.tokens - plen)))
            return (reuse, payload, tail) if reuse else (0, None, 0)
        snap = self._point_snapshot_for(match.node,
                                        min(match.tokens, plen + L - 1))
        if snap is None or snap.tokens <= plen:
            return 0, None, 0
        return snap.tokens - plen, snap, 0

    def _plan_point_captures(self, st: _SlotPrefill, reuse: int) -> None:
        """Decide where a point-snapshot stack captures its recurrent
        state (page-aligned absolute boundaries, DESIGN.md §8): at this
        request's own match boundary — sharing *observed* there, so the
        next borrower skips what this one had to recompute — and
        speculatively at the prompt's last page boundary (serves multi-
        turn/RAG traffic that extends this prompt). Boundaries the seeded
        prefix already covers, or that an attention ring could not replay
        from, are skipped."""
        plen = self.backend.prefix_len()
        pt = self.ecfg.page_tokens
        end_b = ((plen + len(st.tokens)) // pt) * pt
        match_b = st.match.tokens if st.match is not None else 0
        if (match_b > plen and match_b - plen > reuse
                and match_b - plen <= len(st.tokens) - 1
                and self._point_boundary_ok(match_b)):
            st.snap_match_at = match_b - plen
        # the end capture is skipped only when the match capture already
        # covers that exact boundary — NOT whenever the boundaries merely
        # coincide: a full-prompt page-aligned match (match capture
        # ineligible, at least one token must compute) with no usable
        # snapshot would otherwise never acquire one
        if (end_b > plen and end_b - plen > reuse
                and (end_b - plen) != st.snap_match_at
                and self._point_boundary_ok(end_b)):
            st.snap_end_at = end_b - plen

    def _point_boundary_ok(self, boundary: int) -> bool:
        """A point capture at absolute position ``boundary`` is replayable
        iff every attention ring in the stack still holds what a resumed
        borrower would attend to: a full window (ring == window) always
        does; a global or window-truncated ring must hold all of
        [0, boundary)."""
        from repro.models.attention import cache_len_for
        for spec in self.cfg.layer_specs():
            if spec.kind == "ssm":
                continue
            ring = cache_len_for(spec.window, self.ecfg.max_cache_len)
            if spec.window is not None and spec.window <= ring:
                continue
            if boundary > ring:
                return False
        return True

    def _admit(self, slot: int, req: Request) -> _SlotPrefill:
        ecfg = self.ecfg
        toks, chunk, key = self._prep(req)
        self._prep_cache.pop(req.request_id, None)
        match = None
        reuse, snap, tail = 0, None, 0
        if ecfg.prefix_caching:
            match = self.kv.match_prefix(key)
            reuse, snap, tail = self._compute_reuse(match, toks)
        # point stacks always chunk on the position-space page grid — the
        # partition, not just the tokens, determines the recurrent
        # state's rounding, so warm/cold/migrated runs must all cut
        # prompts the same way (there is only one prompt layout now)
        grid = ecfg.page_tokens if self.snapshot_kind == "point" else None
        st = _SlotPrefill(req=req, tokens=toks, chunk=chunk,
                          key=key, match=match, done=reuse, grid=grid)
        if ecfg.prefix_caching and key is not None \
                and self.snapshot_kind == "point" and not self.paged:
            # ring path only: the paged plane captures point state in its
            # page pool at EVERY page boundary as a side effect of compute
            # (state pages, DESIGN.md §10) — no snapshot planning needed
            self._plan_point_captures(st, reuse)
        if reuse:
            # the hit is real in the compute plane: on the ring path, seed
            # the slot's caches from the donor snapshot (a full cache-tree
            # copy); on the paged plane there is nothing to copy — the
            # matched pages are spliced into the session's table below
            if snap is not None:
                self.backend.seed_slot(slot, snap.caches)
            self.prefix_compute_hits += 1
            self.prefill_tokens_skipped += reuse
            req.prompt_pos = min(reuse, req.prompt_len)
        # open (and pin) the KV session at admission so matched radix
        # nodes cannot be evicted between planning and execution; the
        # compute-vetted tail is copied into the session's own page there
        self.kv.open_session(req.request_id, match=match, tail_tokens=tail)
        if self.paged and tail:
            # the memory plane just copied the tail into the session's own
            # open page; mirror it on the compute plane — the ONLY copy a
            # paged hit performs, and only for mid-page resumption
            src = match.tail_node.pages[0].compute_page
            dst = self.kv.sessions[req.request_id].pages[-1].compute_page
            if src is not None and dst is not None:
                self.backend.copy_page_rows(src, dst, tail)
        self._inflight[slot] = st
        self.sched.mark_prefilling(slot)
        return st

    def _plan_step(self) -> StepPlan:
        """Scheduler half of the step: decide which prefill chunks run and
        which slots decode. In-flight chunked prefills continue first
        (bounding time-to-first-token for admitted requests), then new
        admissions fill the remaining prefill budget — preferring queued
        requests that share a hot prefix (prefix-aware admission)."""
        plan = StepPlan()
        if self.ecfg.abandon_after_s is not None:
            # queued sessions older than the timeout hung up before first
            # token; sweep them before admission so they never take a slot
            self.sched.abandon_timed_out(self.mem.now,
                                         self.ecfg.abandon_after_s)
        prefix_len = self.backend.prefix_len()
        budget = self.ecfg.max_prefills_per_step
        for slot in sorted(self._inflight):
            if budget <= 0:
                break
            plan.prefill.append(self._inflight[slot].next_chunk(slot, prefix_len))
            budget -= 1
        if budget > 0:
            match_len = (self._sched_match_len if self.ecfg.prefix_caching
                         else None)
            for slot, req in self.sched.admissions(limit=budget,
                                                   match_len=match_len,
                                                   now=self.mem.now):
                st = self._admit(slot, req)
                plan.prefill.append(st.next_chunk(slot, prefix_len))
                budget -= 1
        plan.decode = self.sched.decode_slots()
        return plan

    def _sched_match_len(self, req: Request) -> int:
        _, _, key = self._prep(req)
        return self.kv.match_len(key)

    # -- compute-plane snapshots & cross-replica migration -------------
    @staticmethod
    def _tree_nbytes(caches) -> float:
        return float(sum(a.size * a.dtype.itemsize
                         for a in jax.tree.leaves(caches)))

    def _publish_snapshot(self, caches, kind: str = "positional",
                          tokens: int = 0) -> Optional[SnapshotHandle]:
        """Carve a donor cache snapshot out of the KV tier budget (metered
        write). If the tier has no headroom the snapshot is not published
        — the prefix still shares pages, it just cannot donate compute.
        Never a pressure-ledger event: a snapshot is an optional
        acceleration, not required state. ``kind``/``tokens`` record the
        per-architecture validity contract (DESIGN.md §8)."""
        nbytes = self._tree_nbytes(caches)
        rid = self.mem.write_region(self.ecfg.kv_tier, "kv:snapshot", nbytes,
                                    expected_lifetime_s=self.ecfg.expected_session_s)
        if rid is None:
            return None
        self.snapshots_published += 1
        return SnapshotHandle(caches, nbytes, self.mem, rid,
                              kind=kind, tokens=tokens)

    def _donation_fn(self, st: _SlotPrefill, slot: int):
        """The payload factory a finished prompt registers with its prefix
        (resolved by the manager only if the deepest node's payload slot
        is free, so a metered snapshot region is never written for
        nothing).

        Positional stacks donate the slot's final ring caches — valid for
        any shorter page-aligned borrower via position masking — unless
        the prompt overflowed the smallest ring and wrapped it (the early
        positions a shorter borrower needs are gone; pages still publish
        for memory-plane reuse). Point stacks donate the state captured at
        the prompt's last page boundary, when the prefill passed through
        one (DESIGN.md §8)."""
        plen = self.backend.prefix_len()
        if self.paged:
            # paged plane: the registered pages are compute-ready as-is —
            # a donor snapshot would duplicate state the tree already owns
            # (satellite of DESIGN.md §10: snapshot_bytes stays 0)
            return None
        if self.snapshot_kind == "positional":
            if not (tfm.supports_extend(self.cfg)
                    and plen + len(st.tokens) <= self._min_ring_len()):
                return None
            return lambda: self._publish_snapshot(
                self.backend.snapshot_slot(slot), kind="positional",
                tokens=plen + len(st.tokens))
        if st.point_caches is None or st.snap_end_at is None:
            return None
        caches, tokens = st.point_caches, plen + st.snap_end_at
        return lambda: self._publish_snapshot(caches, kind="point",
                                              tokens=tokens)

    def _attach_match_snapshot(self, st: _SlotPrefill, slot: int) -> None:
        """Observed-share capture (point stacks): this request matched a
        prefix in the memory plane but no point snapshot existed at its
        boundary, so it had to recompute the shared run — capture the
        state now that its prefill crossed exactly that boundary and hang
        it off the matched node (pinned by this session, so it cannot have
        been evicted), turning the *next* borrower's match into a real
        compute skip."""
        node = st.match.node if st.match is not None else None
        if node is None or node.parent is None or node.payload is not None:
            return
        handle = self._publish_snapshot(
            self.backend.snapshot_slot(slot), kind="point",
            tokens=self.backend.prefix_len() + st.done)
        if handle is not None:
            node.payload = handle

    def _snapshot_compatible(self, caches) -> bool:
        """A foreign snapshot is seedable only when its tree matches this
        backend's per-slot cache template exactly (identical replicas).
        The template spec (structure + leaf shapes/dtypes) is derived once
        from the resident caches — no per-import slot materialization."""
        if self._snap_spec is None:
            self._snap_spec = (
                jax.tree.structure(self.backend.caches),
                [((a.shape[0], 1) + a.shape[2:], a.dtype)
                 for a in jax.tree.leaves(self.backend.caches)])
        structure, leaves = self._snap_spec
        if structure != jax.tree.structure(caches):
            return False
        return all(a.shape == shape and a.dtype == dtype
                   for a, (shape, dtype) in zip(jax.tree.leaves(caches),
                                                leaves))

    def export_prefix(self, key_tokens) -> Optional[dict]:
        """Donor half of a cross-replica prefix migration: match the
        longest published prefix of ``key_tokens`` (position-space), read
        its pages and covering snapshot out of this replica's tiers
        (metered reads — the transfer is not free for the donor), and
        return the page metadata + compute snapshot for the receiver."""
        if not self.ecfg.prefix_caching:
            return None
        # non-bumping walk: a migration probe is not local reuse — it must
        # not feed the donor's hit counts / hot promotion / LRU order (the
        # traffic is being moved AWAY) nor inflate the hit count it exports
        m = self.kv.radix.match(key_tokens, self.mem.now,
                                bump_hits=False, bump_lru=False)
        if m.tokens == 0:
            return None
        kv_bytes = 0.0
        for p in m.pages:
            # paged point stacks: the page's region also carries its
            # recurrent-state snapshot, which the transfer ships too
            nb = p.n_tokens * self.kv.kv_bytes_token + self.kv.state_bytes_page
            if p.region_id is not None and nb > 0:
                self.mem.read_region(p.region_id, nb, sequential=True)
            kv_bytes += nb
        # per-kind snapshot resolution (DESIGN.md §8): positional — the
        # nearest payload below the match covers it via position masking;
        # point — the deepest snapshot at a boundary the match covers
        if self.snapshot_kind == "point":
            handle = self._point_snapshot_for(m.node, m.tokens)
        else:
            handle = (m.payload if isinstance(m.payload, SnapshotHandle)
                      and m.payload.live else None)
        caches, snap_bytes, skind, stok = None, 0.0, "positional", 0
        if handle is not None:
            self.mem.read_region(handle.region_id, handle.nbytes)
            caches, snap_bytes = handle.caches, handle.nbytes
            skind, stok = handle.kind, handle.tokens
        out = {"tokens": np.asarray(key_tokens)[:m.tokens],
               "n_tokens": m.tokens, "kv_bytes": kv_bytes,
               "caches": caches, "snapshot_bytes": snap_bytes,
               "snap_kind": skind, "snap_tokens": stok,
               "hot": m.node.hot, "hits": m.node.hits}
        if self.paged:
            # paged plane: the pages themselves are the compute state — no
            # snapshot exists; ship the matched compute pages instead. The
            # page-read metering above already charged the transfer.
            ids = [p.compute_page for p in m.pages]
            if all(i is not None for i in ids):
                out["page_data"] = self.backend.export_pages(ids)
                out["page_tokens"] = self.kv.page_tokens
        return out

    def import_prefix(self, tokens, caches=None, hot: bool = False,
                      hits: int = 0, snap_kind: str = "positional",
                      snap_tokens: int = 0, page_data=None,
                      page_tokens: Optional[int] = None) -> dict:
        """Receiver half: adopt the pages (metered writes into this
        replica's tiers; a donor-hot prefix lands in the hot tier with
        long retention — placement re-solved on arrival) and re-publish
        the donor's compute snapshot under a locally-metered handle. A
        *point* snapshot is only republished when the adoption kept every
        token up to its boundary — a truncated adoption cannot vouch for
        tokens beyond what was grafted (DESIGN.md §8).

        Paged receivers take ``page_data``/``page_tokens`` instead of a
        snapshot: the donor's compute pages are written straight into the
        pool pages the adoption allocated — a later local hit on the
        grafted prefix is then a zero-copy page-table splice. Data that
        does not match this replica's page geometry or cache families is
        rejected *before* adoption (a graft this engine cannot compute on
        would poison later hits)."""
        if self.paged:
            if (page_data is None or page_tokens != self.kv.page_tokens
                    or not self.backend.pages_compatible(page_data)):
                return {"new_tokens": 0, "total_tokens": 0,
                        "snapshot_bytes": 0.0}
            new_tokens, total, node = self.kv.adopt_prefix(tokens, hot=hot,
                                                           hits=hits)
            inserted = self.kv._last_adopt_pages
            if inserted:
                # the graft kept pages [dup, total) — slice the donor data
                # to the pages actually inserted and write them in place
                pt = self.kv.page_tokens
                dup_pages = (total - new_tokens) // pt
                ids = [p.compute_page for p in inserted]
                data = jax.tree.map(
                    lambda a: a[:, dup_pages:dup_pages + len(ids)],
                    page_data)
                self.backend.import_pages(ids, data)
            return {"new_tokens": new_tokens, "total_tokens": total,
                    "snapshot_bytes": 0.0}
        new_tokens, total, node = self.kv.adopt_prefix(tokens, hot=hot,
                                                       hits=hits)
        snap_bytes = 0.0
        if (node is not None and node.payload is None and caches is not None
                and tfm.supports_extend(self.cfg)
                and (snap_kind != "point" or 0 < snap_tokens <= total)
                and self._snapshot_compatible(caches)):
            handle = self._publish_snapshot(caches, kind=snap_kind,
                                            tokens=snap_tokens)
            if handle is not None:
                node.payload = handle
                snap_bytes = handle.nbytes
        return {"new_tokens": new_tokens, "total_tokens": total,
                "snapshot_bytes": snap_bytes}

    def live_snapshot_bytes(self) -> float:
        """Bytes of metered donor snapshots currently resident in the KV
        tier (the engine-report ``snapshot_bytes`` figure)."""
        return sum(n.payload.nbytes for n in self.kv.radix.nodes()
                   if isinstance(n.payload, SnapshotHandle) and n.payload.live)

    # -- paged compute plane (DESIGN.md §10) ---------------------------
    def _on_page_alloc(self, page) -> None:
        page.compute_page = self.backend.alloc_page()

    def _on_page_release(self, page) -> None:
        if page.compute_page is not None:
            self.backend.free_page(page.compute_page)
            page.compute_page = None

    def _session_table(self, rid: int) -> np.ndarray:
        """The request's compute-page table, padded with null page 0 to the
        power-of-2 width bucket. Table slot j covers absolute positions
        [j*page_tokens, (j+1)*page_tokens) — shared radix pages appear at
        the same slots for every borrower (zero-copy hit)."""
        pages = self.kv.sessions[rid].pages
        W = self.backend.table_width(len(pages))
        tbl = np.zeros((W,), np.int32)
        for j, p in enumerate(pages):
            tbl[j] = p.compute_page if p.compute_page is not None else 0
        return tbl

    def _decode_tables(self, slots: List[int]) -> tuple:
        """(B, W) compute-page tables for a decode round (inactive rows
        all-null) plus the audit list: compute pages resident in sessions
        that are NOT decoding this round and not shared with one that is —
        a decode write landing there is the paged clobbering class."""
        B = self.ecfg.max_slots
        rows, own = {}, set()
        for slot in slots:
            rid = self.sched.active[slot].request_id
            rows[slot] = self._session_table(rid)
            own.update(int(p) for p in rows[slot])
        W = max(r.shape[0] for r in rows.values()) if rows else 1
        tables = np.zeros((B, W), np.int32)
        for slot, r in rows.items():
            tables[slot, :r.shape[0]] = r
        audit = None
        if self.ecfg.audit_decode_masking:
            audit = sorted({
                int(p.compute_page) for s in self.kv.sessions.values()
                for p in s.pages
                if p.compute_page is not None and p.compute_page not in own})
        return tables, audit

    def _meter_paged_reads(self, rid: int, q0: int, q1: int) -> None:
        """Meter the paged kernel's page-gather read stream for one step of
        request ``rid`` whose queries occupy absolute positions [q0, q1):
        a global layer streams every page up to the last query's page; a
        windowed layer skips pages wholly below every query's window
        (lowest reachable position q0 - window + 1); a point layer pulls
        exactly one recurrent-state snapshot — the previous page's
        boundary state when q0 opens a page, else the open page's running
        state (nothing for an empty history: that read is null page 0).
        Bytes are charged at the accounting scale per layer, against each
        page's actual tier — replacing the ring path's synthetic
        whole-history read_all."""
        pages = self.kv.sessions[rid].pages
        pt = self.kv.page_tokens
        hi = -(-q1 // pt)  # pages the kernel gathers: [lo_layer, hi)
        rs = q0 // pt - 1 if q0 % pt == 0 else q0 // pt  # state-read slot
        page_bytes = [0.0] * len(pages)
        for lb, w, sb in self._acct_layers:
            if lb:
                lo = 0 if w is None else max(0, q0 - w + 1) // pt
                for j in range(lo, min(hi, len(pages))):
                    page_bytes[j] += pt * lb
            if sb and 0 <= rs < len(pages):
                page_bytes[rs] += sb
        self.kernel_read_bytes += self.kv.read_pages(rid, page_bytes)

    def _inject_faults(self, slots: List[int]) -> None:
        """Reliability-plane injection point (DESIGN.md §11): before the
        decode kernel gathers its pages, visit every page of every decoding
        session and let the fault injector act on its tracked region's age.
        Near-deadline pages under an active ECC profile scrub-on-read
        (corrected + re-armed, metered through the lifecycle); otherwise
        sampled flips land in the compute page in place, so corruption
        propagates through the real decode math."""
        if self.faults is None or not self.paged:
            return
        import jax
        protected = getattr(self.mem, "ecc_profile", "off") != "off"
        for slot in slots:
            rid = self.sched.active[slot].request_id
            sess = self.kv.sessions.get(rid)
            if sess is None:
                continue
            for page in sess.pages:
                if page.region_id is None or page.compute_page is None:
                    continue
                r = self.mem.region(page.region_id)
                if r is None:
                    continue
                self.faults.stats.pages_visited += 1
                # scrub-on-read is retention servicing: with --no-refresh
                # (service_refresh=False) the controller neither refreshes
                # nor scrubs, so over-aged corruption lands un-corrected
                if (protected and self.mem.service_refresh
                        and self.faults.wants_scrub(r)):
                    if self.kv.lifecycle.scrub(page):
                        self.faults.note_scrub()
                        continue
                pid = int(page.compute_page)
                data = self.backend.export_pages([pid])
                leaves, treedef = jax.tree.flatten(data)
                hit = False
                out_leaves = []
                for leaf in leaves:
                    flipped, _ = self.faults.corrupt(leaf, r, protected)
                    out_leaves.append(leaf if flipped is None else flipped)
                    hit = hit or flipped is not None
                if hit:
                    self.backend.import_pages(
                        [pid], jax.tree.unflatten(treedef, out_leaves))

    def _account_chunk_kv(self, st: _SlotPrefill, ck: PrefillChunk) -> None:
        """This chunk's tokens enter the paged KV — unless a shared prefix
        already holds them (prefix reuse is counted once at open)."""
        target = ck.offset + len(ck.tokens)  # kv tokens incl. meta/frontend
        cur = self.kv.sessions[ck.request_id].tokens
        if target > cur:
            self.kv.append_tokens(ck.request_id, target - cur)

    # ------------------------------------------------------------------
    def step(self) -> dict:
        """One engine step: prefill chunks + one decode round, metered."""
        plan = self._plan_step()
        self.memplane.begin_step()
        rpt = StepReport()
        first_token_reqs: List[Request] = []

        # --- prefill phase (whole prompts or chunks) ------------------
        for ck in plan.prefill:
            st = self._inflight[ck.slot]
            if self.paged:
                # pages must exist BEFORE compute: the kernel writes this
                # chunk's KV into the session's own pages in place
                self._account_chunk_kv(st, ck)
                tok = self.backend.run_prefill_chunk(
                    ck, page_table=self._session_table(ck.request_id))
            else:
                tok = self.backend.run_prefill_chunk(ck)
            self.memplane.weight_pass()
            if self.paged:
                # meter the kernel's actual page-gather stream: queries at
                # [q0, q1) — the first chunk embeds the meta prefix, so its
                # oldest query is position 0
                q0 = 0 if ck.first else ck.offset
                self._meter_paged_reads(ck.request_id, q0,
                                        ck.offset + len(ck.tokens))
            self.prefill_chunks_run += 1
            self.sched.stats.prefill_chunks += 1
            if not self.paged:
                self._account_chunk_kv(st, ck)
            st.done += len(ck.tokens)
            st.req.prompt_pos = min(st.done, st.req.prompt_len)
            # point-snapshot stacks: the recurrent state is only capturable
            # at the boundary itself (chunks were split to land here)
            if st.snap_match_at is not None and st.done == st.snap_match_at:
                self._attach_match_snapshot(st, ck.slot)
                st.snap_match_at = None
            if (st.snap_end_at is not None and st.done == st.snap_end_at
                    and st.point_caches is None):
                st.point_caches = self.backend.snapshot_slot(ck.slot)
            rpt.prefill_tokens += len(ck.tokens)
            self.prefill_tokens_computed += len(ck.tokens)
            if ck.last:
                req = st.req
                req.prefilled_at = self.mem.now
                first_token_reqs.append(req)
                self.outputs[req.request_id].append(int(np.asarray(tok).flat[0]))
                req.generated += 1
                self.tokens_generated += 1
                if st.key is not None:
                    self.kv.register_prefix(req.request_id, st.key,
                                            payload=self._donation_fn(st, ck.slot))
                self.sched.mark_decoding(ck.slot)
                del self._inflight[ck.slot]

        # --- decode round ---------------------------------------------
        if plan.decode:
            if self.paged:
                # the new token's page must exist before the kernel writes
                # its KV row in place
                for slot in plan.decode:
                    self.kv.append_tokens(
                        self.sched.active[slot].request_id, 1)
                self._inject_faults(plan.decode)
                tables, audit = self._decode_tables(plan.decode)
                next_np = self.backend.run_decode(plan.decode,
                                                  page_tables=tables,
                                                  audit_pages=audit)
            else:
                next_np = self.backend.run_decode(plan.decode)
            self.memplane.weight_pass()
            finished: List[int] = []
            for slot in plan.decode:
                req = self.sched.active[slot]
                tok = next_np[slot]
                self.outputs[req.request_id].append(int(np.asarray(tok).flat[0]))
                req.generated += 1
                self.tokens_generated += 1
                rpt.decode_tokens += 1
                self.sched.stats.decode_tokens += 1
                if self.paged:
                    # one query at the just-written position: the kernel
                    # gathered the session's pages, not a synthetic
                    # whole-history read
                    p = int(self.backend.positions[slot])
                    self._meter_paged_reads(req.request_id, p, p + 1)
                else:
                    self.kv.read_all(req.request_id)
                    self.kv.append_tokens(req.request_id, 1)
                done = (req.generated >= req.max_new_tokens or
                        (self.cfg.n_codebooks == 1 and
                         int(np.asarray(tok).flat[0]) == self.ecfg.eos_token))
                if done:
                    finished.append(slot)
            for slot in finished:
                req = self.sched.finish(slot, self.mem.now)
                self.kv.close_session(req.request_id)
                self.backend.free_slot(slot)
                rpt.finished += 1

        # --- advance simulated time by the modelled step latency ------
        step_s, per_tier = self.memplane.finish_step()
        self.mem.advance(step_s)
        # the first token is out when the step that computed it completes:
        # TTFT includes this step's modelled latency
        for req in first_token_reqs:
            req.first_token_at = self.mem.now
        self.kv.maintain()   # cold-leaf decay runs on the advanced clock
        self.steps += 1
        rpt.step_s = step_s
        rpt.bytes_by_tier = per_tier
        return {"step_s": step_s, "bytes": rpt.bytes,
                "bytes_by_tier": rpt.bytes_by_tier,
                "prefill_tokens": rpt.prefill_tokens,
                "decode_tokens": rpt.decode_tokens,
                "finished": rpt.finished,
                "active": len(self.sched.active),
                "queued": len(self.sched.queue)}

    # ------------------------------------------------------------------
    def run_until_idle(self, max_steps: int = 10000,
                       on_stall: str = "raise") -> dict:
        """Step until the scheduler drains. Exhausting ``max_steps`` with
        work still queued/resident is *non-quiescence*: an explicit
        :class:`~repro.serving.events.NonQuiescentError` by default, or —
        with ``on_stall="report"`` — the report with ``quiesced=False``
        (the PR 1–8 behavior silently returned a truncated report)."""
        from repro.serving.events import NonQuiescentError
        start = self.steps
        while not self.sched.idle and self.steps - start < max_steps:
            self.step()
        rep = self.report()
        if not self.sched.idle and on_stall != "report":
            raise NonQuiescentError(
                f"engine not quiescent after {max_steps} steps: "
                f"{len(self.sched.queue)} queued, "
                f"{len(self.sched.active)} resident", rep)
        return rep

    def report(self) -> dict:
        rep = self.memplane.report()
        total_energy = rep["total_energy_j"]
        # steady-state read:write ratio: exclude the one-time model-deploy
        # write (it amortizes to ~0 over a device lifetime — §2.2's >1000:1
        # claim is about the per-token decode stream)
        reads = sum(d.stats.read_bytes for d in self.mem.devices.values())
        writes = sum(d.stats.write_bytes for d in self.mem.devices.values())
        steady_writes = max(writes - self.weight_bytes, 1e-9)
        snapshot_bytes = self.live_snapshot_bytes()
        prefix = self.kv.prefix_report()
        prefix["compute_hits"] = self.prefix_compute_hits
        prefix["tokens_skipped_compute"] = self.prefill_tokens_skipped
        prefix["snapshot_kind"] = self.snapshot_kind
        prefix["hot_tier"] = self.memplane.hot_tier
        prefix["snapshots_published"] = self.snapshots_published
        prefix["snapshot_bytes"] = snapshot_bytes
        return {
            "steps": self.steps,
            "kernel_read_bytes": self.kernel_read_bytes,
            "seed_copy_bytes": self.backend.seed_copy_bytes,
            "tokens_generated": self.tokens_generated,
            "finished": self.sched.stats.finished,
            "abandoned": self.sched.stats.abandoned,
            "quiesced": self.sched.idle,
            "pending_requests": len(self.sched.queue) + len(self.sched.active),
            "sim_time_s": self.mem.now,
            "tokens_per_s": self.tokens_generated / max(self.mem.now, 1e-9),
            "energy_per_token_j": total_energy / max(self.tokens_generated, 1),
            "steady_rw_ratio": reads / steady_writes,
            "memory": rep,
            "kv_live_pages": self.kv.live_pages(),
            "snapshot_bytes": snapshot_bytes,
            "dropped_allocs": self.kv.dropped_allocs,
            "pressure": self.kv.pressure_report(),
            "prefill_chunks": self.prefill_chunks_run,
            "prefill_tokens_computed": self.prefill_tokens_computed,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
            "prefix_hits": self.kv.prefix_hits,
            "prefix_tokens_reused": self.kv.prefix_tokens_reused,
            "prefix": prefix,
            "latency": latency_percentiles(self.sched.latency),
            "reliability": self._reliability_report(),
        }

    def _reliability_report(self) -> dict:
        """The reliability plane's ledger (DESIGN.md §11): ECC profile,
        per-tier check-bit / scrub traffic, and — when injection is on —
        the fault injector's flip/correction/uncorrectable counters."""
        out = {
            "ecc_profile": getattr(self.mem, "ecc_profile", "off"),
            "tiers": {
                n: {"ecc_read_bytes": d.stats.ecc_read_bytes,
                    "ecc_write_bytes": d.stats.ecc_write_bytes,
                    "scrub_read_bytes": d.stats.scrub_read_bytes,
                    "n_scrubs": d.stats.n_scrubs,
                    "scrub_rewrites": d.wear.scrub_rewrites}
                for n, d in self.mem.devices.items()},
        }
        if self.faults is not None:
            out["injection"] = self.faults.stats.as_dict()
            out["inject_rber"] = self.faults.rber
        return out


def latency_percentiles(records: List[dict]) -> dict:
    """TTFT/ITL percentiles over finished-request latency records (the
    cluster frontend pools records across replicas through this too)."""
    out = {"n": len(records)}
    ttft = [r["ttft"] for r in records if r["ttft"] is not None]
    itl = [r["itl"] for r in records if r["itl"] is not None]
    for name, xs in (("ttft", ttft), ("itl", itl)):
        for p in (50, 95, 99):
            out[f"{name}_p{p}"] = (float(np.percentile(xs, p)) if xs else None)
    return out
