"""Deterministic synthetic data pipeline.

Host-sharded, step-indexed, and fully resumable: batch contents are a pure
function of (seed, step, host) — restart from a checkpoint at step N and the
stream continues identically, which the fault-tolerance tests rely on.

The synthetic corpus is a mixture of short/long "documents" drawn from a
hash-based stream with mild Markov structure (so tiny models can actually
reduce loss), packed into fixed-length rows with next-token labels and
document-boundary masking.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 1234
    doc_len_lo: int = 16
    doc_len_hi: int = 192
    n_hosts: int = 1
    host_id: int = 0


def _doc_tokens(rng: np.random.Generator, length: int, vocab: int,
                base: np.ndarray) -> np.ndarray:
    """A 'document': 2nd-order pattern over a CORPUS-SHARED base table, so
    the structure generalizes across fresh batches (loss can decrease on
    held-out steps, not just on memorized ones). Documents differ by their
    random starting state."""
    out = np.empty(length, np.int64)
    x = int(rng.integers(2, vocab))
    for i in range(length):
        x = int(base[(x + i) % len(base)] + (x * 31 + i) % 7) % vocab
        out[i] = max(x, 2)
    return out


class SyntheticPipeline:
    """Iterator of {tokens, labels} batches for one host."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig):
        self.cfg = cfg
        self.dc = dc
        assert dc.global_batch % dc.n_hosts == 0
        self.host_batch = dc.global_batch // dc.n_hosts
        # corpus-level pattern table (function of the seed only; the second
        # component is a fixed tag — str.__hash__ is process-salted and
        # would break cross-process determinism)
        self._base = np.random.default_rng(
            (dc.seed, 0xC0DE)).integers(2, cfg.vocab_size, size=16)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        dc, cfg = self.dc, self.cfg
        B, S = self.host_batch, dc.seq_len
        K = cfg.n_codebooks
        shape = (B, S + 1) if K == 1 else (B, S + 1, K)
        toks = np.zeros(shape, np.int64)
        for b in range(B):
            rng = np.random.default_rng(
                (dc.seed, step, dc.host_id, b))  # pure function of indices
            row = np.zeros((S + 1, K), np.int64)
            fill = 0
            while fill < S + 1:
                L = int(rng.integers(dc.doc_len_lo, dc.doc_len_hi))
                L = min(L, S + 1 - fill)
                for k in range(K):
                    row[fill:fill + L, k] = _doc_tokens(rng, L, cfg.vocab_size,
                                                        self._base)
                if fill + L < S + 1:
                    row[fill + L - 1, :] = 1  # EOS boundary
                fill += L
            toks[b] = row if K > 1 else row[:, 0]
        tokens = toks[:, :-1]
        labels = toks[:, 1:].copy()
        return {"tokens": tokens.astype(np.int32),
                "labels": labels.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1
