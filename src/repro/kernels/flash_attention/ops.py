"""Public jit'd wrapper: (B, S, H, D) GQA layout -> flash kernel layout."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bh


@functools.partial(jax.jit, static_argnames=("scale", "cap", "window", "causal",
                                             "q_block", "kv_block", "interpret"))
def flash_attention(q, k, v, *, scale: float, cap: Optional[float] = None,
                    window: Optional[int] = None, causal: bool = True,
                    q_block: int = 512, kv_block: int = 512,
                    interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    # fold (B, Hkv, G) -> BH; replicate k/v over the group dim
    qf = q.reshape(B, Sq, Hkv, G, D).transpose(0, 2, 3, 1, 4).reshape(B * Hkv * G, Sq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * Hkv * G, Skv, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * Hkv * G, Skv, D)
    out = flash_attention_bh(qf, kf, vf, scale=scale, cap=cap, window=window,
                             causal=causal, q_block=q_block, kv_block=kv_block,
                             interpret=interpret)
    return out.reshape(B, Hkv, G, Sq, D).transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
