"""Flash-attention Pallas TPU kernel (prefill / training forward).

Tiling: grid = (BH, nq, nkv) with the kv axis innermost ("arbitrary" —
sequential), so each (batch*kv-head, q-block) streams its KV blocks
HBM->VMEM in order while the online-softmax state (m, l, acc) lives in VMEM
scratch. Q blocks are (q_block, head_dim) MXU-aligned tiles; the causal /
sliding-window mask is computed from program ids, never materialized in HBM.

This is the TPU-native expression of the paper's "IO is sequential and
predictable" observation applied to attention compute.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, cap: Optional[float], window: Optional[int],
                  causal: bool, q_block: int, kv_block: int, nkv: int,
                  kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (q_block, d)
    k = k_ref[0]  # (kv_block, d)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap

    qpos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
    kpos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
    mask = kpos < kv_len
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > (qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nkv - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bh(q, k, v, *, scale: float, cap: Optional[float] = None,
                       window: Optional[int] = None, causal: bool = True,
                       q_block: int = 512, kv_block: int = 512,
                       kv_len: Optional[int] = None,
                       interpret: bool = True):
    """q: (BH, Sq, D); k/v: (BH, Skv, D) -> (BH, Sq, D).

    BH folds batch x kv-head x group; D should be a multiple of 128 on real
    TPUs (interpret mode accepts any size for the test sweeps).
    """
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    assert Sq % q_block == 0 and Skv % kv_block == 0
    nq = Sq // q_block
    nkv = Skv // kv_block
    kv_len = Skv if kv_len is None else kv_len

    kernel = functools.partial(
        _flash_kernel, scale=scale, cap=cap, window=window, causal=causal,
        q_block=q_block, kv_block=kv_block, nkv=nkv, kv_len=kv_len)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, q_block, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, kv_block, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kv_block, D), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),    # m
            pltpu.VMEM((q_block,), jnp.float32),    # l
            pltpu.VMEM((q_block, D), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(q, k, v)
