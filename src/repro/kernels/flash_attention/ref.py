"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q, k, v, *, scale: float, cap: Optional[float] = None,
                  window: Optional[int] = None, causal: bool = True,
                  kv_len: Optional[int] = None):
    """q: (BH, Sq, D); k/v: (BH, Skv, D) -> (BH, Sq, D). Naive softmax."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > (qpos - window)
    if kv_len is not None:
        mask &= kpos < kv_len
    s = jnp.where(mask[None], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(-1, keepdims=True)
    l = jnp.where(l == 0.0, 1.0, l)
    return jnp.einsum("bqk,bkd->bqd", p / l, v.astype(jnp.float32)).astype(q.dtype)
