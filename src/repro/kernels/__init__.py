"""Pallas TPU kernels for the workload's compute hot-spots.

The paper's workload analysis (§2.2: decode reads all weights + the whole
KV cache per token, sequentially and predictably) identifies attention as
the IO hot-spot; the Pallas kernels express that insight TPU-natively:
block-granular HBM->VMEM streaming with MXU-aligned tiles.

Each kernel package has:
- ``kernel.py`` — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
- ``ops.py``    — jit'd public wrapper (layout plumbing, defaults)
- ``ref.py``    — pure-jnp oracle used by the allclose test sweeps

On this CPU container kernels are validated with ``interpret=True``; the
model's dry-run path uses the pure-XLA implementations (DESIGN.md §4).
"""
