"""Mamba2 SSD chunk-scan Pallas TPU kernel.

Grid walks (batch*head, chunk) with the chunk axis sequential; the carried
SSM state (headdim x state) lives in VMEM scratch across chunk iterations.
Each step computes the intra-chunk quadratic term with the cumulative decay
mask built in-register from the dt block, adds the carried-state
contribution, and updates the state — the SSD algorithm's chunk recurrence
with one HBM read per operand block (sequential, predictable: the same IO
shape the paper's MRM targets).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, da_ref, b_ref, c_ref, y_ref, state_ref, *,
                n_chunks: int, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xdt = xdt_ref[0].astype(jnp.float32)  # (L, P) x*dt
    da = da_ref[0].astype(jnp.float32)    # (L,)   dt*A (log-decay)
    b = b_ref[0].astype(jnp.float32)      # (L, N)
    c = c_ref[0].astype(jnp.float32)      # (L, N)

    cs = jnp.cumsum(da)                        # (L,)
    seg = cs[:, None] - cs[None, :]            # seg(l, s) = sum_{s+1..l}
    L = da.shape[0]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(tri, jnp.exp(seg), 0.0)  # (L, L)

    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    y_in = jax.lax.dot_general(cb * decay, xdt, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (L, P)

    state = state_ref[...]  # (P, N)
    y_off = jax.lax.dot_general(c, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (L, P)
    y_off = y_off * jnp.exp(cs)[:, None]

    tail = jnp.exp(cs[-1] - cs)  # (L,)
    new_state = jax.lax.dot_general(xdt * tail[:, None], b,
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = state * jnp.exp(cs[-1]) + new_state
    y_ref[0] = (y_in + y_off).astype(y_ref.dtype)


def ssd_scan_bh(xdt, da, b, c, *, chunk: int = 256, interpret: bool = True):
    """xdt: (BH, S, P) (x pre-multiplied by dt); da: (BH, S) log-decays;
    b/c: (BH, S, N). Returns y (BH, S, P). S must be divisible by chunk."""
    BH, S, P = xdt.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk

    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b_, ci: (b_, ci, 0)),
            pl.BlockSpec((1, chunk), lambda b_, ci: (b_, ci)),
            pl.BlockSpec((1, chunk, N), lambda b_, ci: (b_, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b_, ci: (b_, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b_, ci: (b_, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xdt, da, b, c)
