"""Public jit'd wrapper for the SSD chunk-scan kernel.

Takes the model-layer layout (B, S, H, P) + per-head dt/A and grouped B/C,
folds (batch, head) into the kernel's BH axis."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_bh


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, *, chunk: int = 256, interpret: bool = True):
    """x: (B, S, H, P); dt: (B, S, H) post-softplus; a: (H,) negative;
    b/c: (B, S, G, N), H % G == 0. Returns y (B, S, H, P)."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    xdt = (x * dt[..., None]).transpose(0, 2, 1, 3).reshape(B * H, S, P)
    da = (dt * a[None, None, :]).transpose(0, 2, 1).reshape(B * H, S)
    bh = jnp.repeat(b, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    ch = jnp.repeat(c, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    y = ssd_scan_bh(xdt.astype(jnp.float32), da.astype(jnp.float32),
                    bh.astype(jnp.float32), ch.astype(jnp.float32),
                    chunk=chunk, interpret=interpret)
    return y.reshape(B, H, S, P).transpose(0, 2, 1, 3).astype(x.dtype)
