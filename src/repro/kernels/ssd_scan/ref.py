"""Pure-jnp oracle for the SSD chunk-scan kernel: the naive O(S) sequential
state-space recurrence (token by token), independently implemented from the
chunked algorithm so the test sweep cross-validates both."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(xdt, da, b, c):
    """xdt: (BH, S, P); da: (BH, S); b/c: (BH, S, N) -> y (BH, S, P).

    state_t = exp(da_t) * state_{t-1} + b_t (outer) xdt_t
    y_t     = state_t @ c_t
    """
    BH, S, P = xdt.shape
    N = b.shape[-1]

    def step(state, xs):
        x_t, da_t, b_t, c_t = xs
        state = state * jnp.exp(da_t)[:, None, None] + \
            x_t[:, :, None].astype(jnp.float32) * b_t[:, None, :].astype(jnp.float32)
        y_t = jnp.einsum("bpn,bn->bp", state, c_t.astype(jnp.float32))
        return state, y_t

    xs = (xdt.transpose(1, 0, 2), da.transpose(1, 0),
          b.transpose(1, 0, 2), c.transpose(1, 0, 2))
    state0 = jnp.zeros((BH, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2).astype(xdt.dtype)
