"""Public jit'd wrapper for the decode kernel: (B, 1, H, D) GQA layout."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_bh


@functools.partial(jax.jit, static_argnames=("scale", "cap", "window",
                                             "page_size", "interpret"))
def decode_attention(q, k_cache, v_cache, cache_pos, cur_pos, *, scale: float,
                     cap: Optional[float] = None, window: Optional[int] = None,
                     page_size: int = 512, interpret: bool = True):
    """q: (B, 1, H, D); caches: (B, C, Hkv, D); cache_pos: (B, C);
    cur_pos: scalar or (B,). -> (B, 1, H, D)."""
    B, _, H, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qf = q.reshape(B, Hkv, G, D).reshape(B * Hkv, G, D)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, C, D)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, C, D)
    posf = jnp.repeat(cache_pos[:, None, :], Hkv, axis=1).reshape(B * Hkv, C)
    cur = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32).reshape(-1, 1) if
                           jnp.ndim(cur_pos) else jnp.full((B, 1), cur_pos, jnp.int32),
                           (B, Hkv)).reshape(B * Hkv)
    out = decode_attention_bh(qf, kf, vf, posf, cur, scale=scale, cap=cap,
                              window=window, page_size=page_size,
                              interpret=interpret)
    return out.reshape(B, Hkv, G, D).reshape(B, 1, H, D)
