"""Ring-cache decode attention as a view onto the paged kernel.

The old standalone decode kernel is gone: a (B, C, Hkv, D) ring cache is
just B contiguous runs of ``C / page_size`` pages whose stored position
plane (``cache_pos``, -1 for empty rows) supplies the masking, so decode
here reshapes the ring into the paged fused-KV layout and dispatches one
single-query-per-sequence grid of ``repro.kernels.paged_attention``.
There is exactly one decode read path in the repo.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.ops import ragged_paged_attention


@functools.partial(jax.jit, static_argnames=("scale", "cap", "window",
                                             "page_size", "interpret"))
def decode_attention(q, k_cache, v_cache, cache_pos, cur_pos, *, scale: float,
                     cap: Optional[float] = None, window: Optional[int] = None,
                     page_size: int = 512, interpret: bool = True):
    """q: (B, 1, H, D); caches: (B, C, Hkv, D); cache_pos: (B, C);
    cur_pos: scalar or (B,). -> (B, 1, H, D)."""
    B, _, H, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    ps = min(page_size, C)
    if C % ps:
        ps = C                                           # one page per ring
    n_per = C // ps
    # fused head-interleaved pages: K at 2h, V at 2h+1
    kv = jnp.stack([k_cache, v_cache], axis=3)           # (B, C, Hkv, 2, D)
    kv_pages = kv.reshape(B, C, 2 * Hkv, D).reshape(B * n_per, ps,
                                                    2 * Hkv, D)
    kv_pos = jnp.asarray(cache_pos, jnp.int32).reshape(B * n_per, ps)
    page_table = jnp.arange(B * n_per, dtype=jnp.int32).reshape(B, n_per)
    cu = jnp.arange(B + 1, dtype=jnp.int32)
    kv_lens = jnp.full((B,), C, jnp.int32)
    cur = jnp.asarray(cur_pos, jnp.int32)
    q_pos = (cur.reshape(-1) if cur.ndim else
             jnp.full((B,), cur, jnp.int32))
    q_pos = jnp.broadcast_to(q_pos, (B,))
    out = ragged_paged_attention(
        q.reshape(B, H, D), kv_pages, page_table, cu, kv_lens,
        scale=scale, cap=cap, window=window, q_pos=q_pos,
        kv_pos_pages=kv_pos, max_q_len=1, interpret=interpret)
    return out.reshape(B, 1, H, D)
