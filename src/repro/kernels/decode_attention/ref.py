"""Pure-jnp oracle for the decode-attention kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

NEG_INF = -2.0e38


def decode_attention_ref(q, k_pages, v_pages, pos, cur_pos, *, scale: float,
                         cap: Optional[float] = None,
                         window: Optional[int] = None):
    """q: (BH, G, D); k/v_pages: (BH, C, D); pos: (BH, C); cur_pos: (BH,)."""
    s = jnp.einsum("bgd,bcd->bgc", q.astype(jnp.float32),
                   k_pages.astype(jnp.float32)) * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    cur = cur_pos[:, None]
    valid = (pos >= 0) & (pos <= cur)
    if window is not None:
        valid &= pos > (cur - window)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(-1, keepdims=True)
    l = jnp.where(l == 0.0, 1.0, l)
    return jnp.einsum("bgc,bcd->bgd", p / l,
                      v_pages.astype(jnp.float32)).astype(q.dtype)
