"""Decode-attention Pallas TPU kernel: one query token vs a paged KV cache.

This kernel is the direct TPU expression of the paper's central IO claim:
decode reads the *entire* KV cache sequentially, page by page, for a single
appended vector. The grid walks (batch*kv-head, page) with pages streamed
HBM->VMEM as (page_size, head_dim) blocks — exactly the block-granular,
predictable read stream MRM is designed to serve — while the G grouped
queries ride along in VMEM scratch with online-softmax state.

Masking is position-based against a stored-positions page (ring-buffer
caches, windowed layers) so the kernel serves both dense and windowed
layers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, cur_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   scale: float, cap: Optional[float], window: Optional[int],
                   n_pages: int):
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]          # (G, D)
    k = k_ref[0]          # (page, D)
    v = v_ref[0]
    pos = pos_ref[0]      # (page,) stored absolute positions
    cur = cur_ref[0]      # scalar current position

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (G, page)
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    valid = (pos >= 0) & (pos <= cur)
    if window is not None:
        valid &= pos > (cur - window)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = m_new

    @pl.when(pi == n_pages - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_bh(q, k_pages, v_pages, pos, cur_pos, *,
                        scale: float, cap: Optional[float] = None,
                        window: Optional[int] = None, page_size: int = 512,
                        interpret: bool = True):
    """q: (BH, G, D) grouped queries; k/v_pages: (BH, C, D) cache;
    pos: (BH, C) stored positions; cur_pos: (BH,) int32. -> (BH, G, D)."""
    BH, G, D = q.shape
    C = k_pages.shape[1]
    page_size = min(page_size, C)
    assert C % page_size == 0
    n_pages = C // page_size

    kernel = functools.partial(_decode_kernel, scale=scale, cap=cap,
                               window=window, n_pages=n_pages)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_pages),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda b, pi: (b, 0, 0)),
            pl.BlockSpec((1, page_size, D), lambda b, pi: (b, pi, 0)),
            pl.BlockSpec((1, page_size, D), lambda b, pi: (b, pi, 0)),
            pl.BlockSpec((1, page_size), lambda b, pi: (b, pi)),
            pl.BlockSpec((1,), lambda b, pi: (b,)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, pi: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),     # m
            pltpu.VMEM((G,), jnp.float32),     # l
            pltpu.VMEM((G, D), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k_pages, v_pages, pos, cur_pos)
