"""Ragged paged-attention Pallas TPU kernel.

One grid step per sequence (``grid = (S,)``). The three ragged
descriptors — ``cu_q_lens``, ``kv_lens``, ``page_table`` — ride in
scalar-prefetch SMEM so each step can size its own work before its body
runs. KV pages stay in ``ANY`` memory (HBM); the kernel pulls them one
page at a time into a two-slot VMEM buffer with ``make_async_copy``,
starting page ``i+1``'s DMA before computing on page ``i`` so the gather
overlaps the MXU work. Queries and outputs live whole in VMEM: each step
dynamically slices its own ``max_q``-row block, and since steps run in
ascending sequence order, the garbage rows a short sequence writes past
its true length are overwritten by the next sequence's block (the host
wrapper pads by ``max_q`` rows and slices them off).

Softmax math matches ``ref.paged_attention_rows`` shape-for-shape: fp32
online accumulation per KV head with explicit zeroing of masked
probabilities, so fully-masked (padding) pages leave the accumulator
bit-identical.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _attend_page(qf, kv, kpos, qpos, m, l, acc, *, scale, cap, window):
    """One page of online softmax for one KV head.

    qf: (N, D) fp32 query block (N = max_q * G rows); kv: (ps, 2, D)
    this head's fused page slab; kpos: (ps,) key positions; qpos: (N, 1)
    query positions; m/l: (N, 1) fp32; acc: (N, D) fp32."""
    k = kv[:, 0, :].astype(jnp.float32)                  # (ps, D)
    v = kv[:, 1, :].astype(jnp.float32)
    s = jax.lax.dot_general(qf, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    kp = kpos[None, :]                                   # (1, ps)
    valid = (kp >= 0) & (kp <= qpos)
    if window is not None:
        valid &= kp > (qpos - window)
    s = jnp.where(valid, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
    # explicit zeroing (not just exp of NEG_INF): when every page so far
    # was masked, m_new == NEG_INF and exp(s - m_new) would be 1
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m - m_new)
    l = l * corr + p.sum(axis=-1, keepdims=True)
    acc = acc * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return m_new, l, acc


def _kernel(cu_ref, kvlen_ref, tbl_ref, q_ref, kv_ref, o_ref,
            kbuf, ksem, m_s, l_s, acc_s,
            *, ps, max_q, Hkv, G, D, scale, cap, window,
            qpos_ref=None, kvpos_ref=None, pbuf=None, psem=None):
    has_pos = kvpos_ref is not None
    s = pl.program_id(0)
    q0 = cu_ref[s]
    qlen = cu_ref[s + 1] - q0
    kv_len = kvlen_ref[s]
    n_pages = jax.lax.div(kv_len + ps - 1, ps)

    def page_copy(i, slot):
        return pltpu.make_async_copy(
            kv_ref.at[tbl_ref[s, i]], kbuf.at[slot], ksem.at[slot])

    def pos_copy(i, slot):
        return pltpu.make_async_copy(
            kvpos_ref.at[tbl_ref[s, i]], pbuf.at[slot], psem.at[slot])

    @pl.when(n_pages > 0)
    def _warmup():
        page_copy(0, 0).start()
        if has_pos:
            pos_copy(0, 0).start()

    qblk = q_ref[pl.ds(q0, max_q)]                       # (max_q, Hq, D)
    if has_pos:
        qpos = qpos_ref[pl.ds(q0, max_q)].reshape(max_q, 1)
        qpos = jnp.broadcast_to(qpos, (max_q, G)).reshape(max_q * G, 1)
    else:
        qpos = (kv_len - qlen
                + jax.lax.broadcasted_iota(jnp.int32, (max_q, G), 0))
        qpos = qpos.reshape(max_q * G, 1)

    m_s[...] = jnp.full_like(m_s[...], NEG_INF)
    l_s[...] = jnp.zeros_like(l_s[...])
    acc_s[...] = jnp.zeros_like(acc_s[...])

    def body(i, _):
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < n_pages)
        def _prefetch():
            page_copy(i + 1, 1 - slot).start()
            if has_pos:
                pos_copy(i + 1, 1 - slot).start()

        page_copy(i, slot).wait()
        if has_pos:
            pos_copy(i, slot).wait()
            kpos = pbuf[slot]
        else:
            kpos = i * ps + jax.lax.broadcasted_iota(jnp.int32, (ps,), 0)
        kv = kbuf[slot]                                  # (ps, 2*Hkv, D)
        for h in range(Hkv):
            qh = qblk[:, h * G:(h + 1) * G, :].astype(jnp.float32)
            qh = qh.reshape(max_q * G, D)
            m_new, l_new, a_new = _attend_page(
                qh, kv[:, 2 * h:2 * h + 2, :], kpos, qpos,
                m_s[h], l_s[h], acc_s[h],
                scale=scale, cap=cap, window=window)
            m_s[h] = m_new
            l_s[h] = l_new
            acc_s[h] = a_new
        return 0

    jax.lax.fori_loop(0, n_pages, body, 0)

    outs = []
    for h in range(Hkv):
        l = l_s[h]
        o = acc_s[h] / jnp.where(l == 0.0, 1.0, l)
        outs.append(o.reshape(max_q, G, D))
    out = jnp.concatenate(outs, axis=1).astype(o_ref.dtype)
    o_ref[pl.ds(q0, max_q)] = out


@functools.partial(
    jax.jit,
    static_argnames=("scale", "cap", "window", "max_q_len", "interpret"))
def ragged_paged_attention_pallas(q_pad, kv_pages, page_table, cu_q_lens,
                                  kv_lens, *, scale: float,
                                  cap: Optional[float] = None,
                                  window: Optional[int] = None,
                                  max_q_len: int = 1,
                                  q_pos_pad=None, kv_pos_pages=None,
                                  interpret: bool = False):
    """Pallas entry. ``q_pad`` must be (T + max_q_len, Hq, D) — padded so
    every sequence's ``max_q_len`` block load stays in bounds; callers go
    through ``ops.ragged_paged_attention`` which pads and re-slices."""
    Tpad, Hq, D = q_pad.shape
    _, ps, H2, _ = kv_pages.shape
    Hkv = H2 // 2
    G = Hq // Hkv
    S = page_table.shape[0]
    max_q = max_q_len
    has_pos = kv_pos_pages is not None

    scratch = [
        pltpu.VMEM((2, ps, H2, D), kv_pages.dtype),      # kbuf
        pltpu.SemaphoreType.DMA((2,)),                   # ksem
        pltpu.VMEM((Hkv, max_q * G, 1), jnp.float32),    # m_s
        pltpu.VMEM((Hkv, max_q * G, 1), jnp.float32),    # l_s
        pltpu.VMEM((Hkv, max_q * G, D), jnp.float32),    # acc_s
    ]
    q_spec = pl.BlockSpec((Tpad, Hq, D), lambda s, *_: (0, 0, 0))
    if has_pos:
        in_specs = [
            q_spec,
            pl.BlockSpec(memory_space=pltpu.ANY),        # kv_pages
            pl.BlockSpec((Tpad,), lambda s, *_: (0,)),       # q_pos
            pl.BlockSpec(memory_space=pltpu.ANY),        # kv_pos_pages
        ]
        args = [q_pad, kv_pages,
                jnp.asarray(q_pos_pad, jnp.int32),
                jnp.asarray(kv_pos_pages, jnp.int32)]
        scratch += [
            pltpu.VMEM((2, ps), jnp.int32),              # pbuf
            pltpu.SemaphoreType.DMA((2,)),               # psem
        ]
    else:
        in_specs = [q_spec, pl.BlockSpec(memory_space=pltpu.ANY)]
        args = [q_pad, kv_pages]

    kernel = functools.partial(
        _kernel, ps=ps, max_q=max_q, Hkv=Hkv, G=G, D=D, scale=scale,
        cap=cap, window=window)

    def wrapped(cu_ref, kvlen_ref, tbl_ref, q_ref, kv_ref, *rest):
        if has_pos:
            qpos_ref, kvpos_ref, o_ref = rest[0], rest[1], rest[2]
            kbuf, ksem, m_s, l_s, acc_s, pbuf, psem = rest[3:]
            kernel(cu_ref, kvlen_ref, tbl_ref, q_ref, kv_ref, o_ref,
                   kbuf, ksem, m_s, l_s, acc_s,
                   qpos_ref=qpos_ref, kvpos_ref=kvpos_ref,
                   pbuf=pbuf, psem=psem)
        else:
            o_ref = rest[0]
            kbuf, ksem, m_s, l_s, acc_s = rest[1:]
            kernel(cu_ref, kvlen_ref, tbl_ref, q_ref, kv_ref, o_ref,
                   kbuf, ksem, m_s, l_s, acc_s)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(S,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((Tpad, Hq, D), lambda s, *_: (0, 0, 0)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        wrapped,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tpad, Hq, D), q_pad.dtype),
        interpret=interpret,
    )(jnp.asarray(cu_q_lens, jnp.int32),
      jnp.asarray(kv_lens, jnp.int32),
      jnp.asarray(page_table, jnp.int32),
      *args)
