"""Ragged paged-attention Pallas TPU kernel — grouped, null-skipping grid.

The grid is ``(S, QB, NB)``: per sequence, ``QB`` query-row tiles of
``block_q`` rows × ``NB`` kv-page blocks of ``block_kv`` table slots
(TPU grids run sequentially row-major, so for a fixed (sequence, q-tile)
the page blocks arrive back-to-back and the fp32 online-softmax
accumulators live in VMEM scratch across them: initialized at the first
block, finalized and written out at the last). The three ragged
descriptors — ``cu_q_lens``, ``kv_lens``, ``page_table`` — ride in
scalar-prefetch SMEM so every step sizes its own work before its body
runs.

Each page block first *compacts* its useful table slots into an SMEM
list: slots outside the q-tile's reachable page range (causal upper
bound, sliding-window lower bound — slot-derived key positions make both
computable from the grid alone) and null-page slots (page id 0, the
reserved all-zeros page) are dropped without issuing a DMA. A block
whose list is empty is skipped entirely — on sparse tables (mostly-null
rows) the gather stream shrinks to the pages that actually hold keys,
which is the read-bandwidth term the MRM tier is sized by. The surviving
pages stream through an ``num_buffers``-deep (2–4) VMEM copy pipeline:
buffer ``i % num_buffers`` computes while up to ``num_buffers - 1``
later pages are in flight.

Skipping is bit-neutral by the same argument that makes padding pages
safe: a fully-masked page contributes ``m_new == m``, ``p == 0``,
``corr == 1``, leaving (m, l, acc) bit-identical — so the kernel matches
``ref.paged_attention_rows`` (which masks null/out-of-range slots
explicitly) bit-for-bit in fp32. With ``skip_blocks=False`` the kernel
degenerates to the ungrouped PR 6 gather — every slot up to the
sequence's page count is pulled and masked in-register — which is the
baseline the kernel bench meters the skip win against.

Queries and outputs live whole in VMEM; each (sequence, q-tile) step
dynamically slices its ``block_q``-row window. Steps run in ascending
sequence order, so the garbage rows a short sequence's tiles write past
its true length are overwritten by the next sequence (the host wrapper
pads by ``QB * block_q`` rows and slices them off).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _attend_page(qf, kv, kpos, qpos, m, l, acc, *, scale, cap, window,
                 null=None):
    """One page of online softmax for one KV head.

    qf: (N, D) fp32 query block (N = block_q * G rows); kv: (ps, 2, D)
    this head's fused page slab; kpos: (ps,) key positions; qpos: (N, 1)
    query positions; m/l: (N, 1) fp32; acc: (N, D) fp32. ``null`` (traced
    scalar bool) masks the whole page — the ungrouped baseline attends
    null pages it did not skip and must zero them in-register."""
    k = kv[:, 0, :].astype(jnp.float32)                  # (ps, D)
    v = kv[:, 1, :].astype(jnp.float32)
    s = jax.lax.dot_general(qf, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    kp = kpos[None, :]                                   # (1, ps)
    valid = (kp >= 0) & (kp <= qpos)
    if window is not None:
        valid &= kp > (qpos - window)
    if null is not None:
        valid &= jnp.logical_not(null)
    s = jnp.where(valid, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
    # explicit zeroing (not just exp of NEG_INF): when every page so far
    # was masked, m_new == NEG_INF and exp(s - m_new) would be 1
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m - m_new)
    l = l * corr + p.sum(axis=-1, keepdims=True)
    acc = acc * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return m_new, l, acc


def _kernel(cu_ref, kvlen_ref, tbl_ref, q_ref, kv_ref, o_ref,
            kbuf, ksem, plist, m_s, l_s, acc_s,
            *, ps, block_q, block_kv, nbuf, n_blocks, Hkv, G, D,
            scale, cap, window, skip_blocks,
            qpos_ref=None, kvpos_ref=None, pbuf=None, psem=None):
    has_pos = kvpos_ref is not None
    s = pl.program_id(0)
    qb = pl.program_id(1)
    nb = pl.program_id(2)
    q0 = cu_ref[s]
    qlen = cu_ref[s + 1] - q0
    kv_len = kvlen_ref[s]
    n_pages = jax.lax.div(kv_len + ps - 1, ps)
    q_lo = qb * block_q                  # tile rows: [q_lo, q_lo+block_q)

    # page range this q-tile can reach. Slot-derived key positions make
    # the bounds computable without touching a page: causal — no key
    # beyond the tile's last query position; window — no key below the
    # tile's first reachable position. Explicit-position mode (ring
    # layouts: slot 0 is a real page, positions arbitrary) gathers the
    # full range and lets the in-register mask decide.
    if skip_blocks and not has_pos:
        last_qpos = jnp.minimum(kv_len - qlen + q_lo + block_q, kv_len) - 1
        hi = jnp.minimum(n_pages, jax.lax.div(last_qpos, ps) + 1)
        if window is not None:
            first_qpos = kv_len - qlen + q_lo
            lo = jnp.maximum(first_qpos - window + 1, 0) // ps
        else:
            lo = jnp.int32(0)
    else:
        lo, hi = jnp.int32(0), n_pages

    @pl.when((q_lo < qlen) & (nb * block_kv < hi))
    def _tile():
        # -- compact this block's useful slots into SMEM ----------------
        blk0 = jnp.maximum(nb * block_kv, lo)
        blk1 = jnp.minimum(nb * block_kv + block_kv, hi)

        def scan(j, cnt):
            keep = jnp.logical_and(j >= blk0, j < blk1)
            if skip_blocks and not has_pos:
                keep &= tbl_ref[s, j] != 0

            @pl.when(keep)
            def _():
                plist[cnt] = j
            return cnt + keep.astype(jnp.int32)

        nnz = jax.lax.fori_loop(nb * block_kv,
                                jnp.minimum(nb * block_kv + block_kv, hi),
                                scan, 0)

        def page_copy(i, slot):
            return pltpu.make_async_copy(
                kv_ref.at[tbl_ref[s, plist[i]]], kbuf.at[slot],
                ksem.at[slot])

        def pos_copy(i, slot):
            return pltpu.make_async_copy(
                kvpos_ref.at[tbl_ref[s, plist[i]]], pbuf.at[slot],
                psem.at[slot])

        # -- warm the pipeline: up to nbuf-1 pages in flight ------------
        for b in range(nbuf - 1):
            @pl.when(b < nnz)
            def _(b=b):
                page_copy(b, b).start()
                if has_pos:
                    pos_copy(b, b).start()

        qblk = q_ref[pl.ds(q0 + q_lo, block_q)]          # (block_q, Hq, D)
        if has_pos:
            qpos = qpos_ref[pl.ds(q0 + q_lo, block_q)].reshape(block_q, 1)
            qpos = jnp.broadcast_to(qpos, (block_q, G)).reshape(
                block_q * G, 1)
        else:
            qpos = (kv_len - qlen + q_lo
                    + jax.lax.broadcasted_iota(jnp.int32, (block_q, G), 0))
            qpos = qpos.reshape(block_q * G, 1)

        @pl.when(nb * block_kv <= lo)
        def _init():
            # first page block this tile sees (blocks below lo were
            # skipped whole): reset the accumulators
            m_s[...] = jnp.full_like(m_s[...], NEG_INF)
            l_s[...] = jnp.zeros_like(l_s[...])
            acc_s[...] = jnp.zeros_like(acc_s[...])

        def body(i, _):
            slot = jax.lax.rem(i, nbuf)

            @pl.when(i + nbuf - 1 < nnz)
            def _prefetch():
                nxt = i + nbuf - 1
                page_copy(nxt, jax.lax.rem(nxt, nbuf)).start()
                if has_pos:
                    pos_copy(nxt, jax.lax.rem(nxt, nbuf)).start()

            page_copy(i, slot).wait()
            j = plist[i]
            if has_pos:
                pos_copy(i, slot).wait()
                kpos = pbuf[slot]
            else:
                kpos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (ps,), 0)
            null = None
            if not skip_blocks and not has_pos:
                null = tbl_ref[s, j] == 0
            kv = kbuf[slot]                              # (ps, 2*Hkv, D)
            for h in range(Hkv):
                qh = qblk[:, h * G:(h + 1) * G, :].astype(jnp.float32)
                qh = qh.reshape(block_q * G, D)
                m_new, l_new, a_new = _attend_page(
                    qh, kv[:, 2 * h:2 * h + 2, :], kpos, qpos,
                    m_s[h], l_s[h], acc_s[h],
                    scale=scale, cap=cap, window=window, null=null)
                m_s[h] = m_new
                l_s[h] = l_new
                acc_s[h] = a_new
            return 0

        jax.lax.fori_loop(0, nnz, body, 0)

        @pl.when((nb == n_blocks - 1) | (nb * block_kv + block_kv >= hi))
        def _finalize():
            outs = []
            for h in range(Hkv):
                l = l_s[h]
                o = acc_s[h] / jnp.where(l == 0.0, 1.0, l)
                outs.append(o.reshape(block_q, G, D))
            out = jnp.concatenate(outs, axis=1).astype(o_ref.dtype)
            o_ref[pl.ds(q0 + q_lo, block_q)] = out


@functools.partial(
    jax.jit,
    static_argnames=("scale", "cap", "window", "max_q_len", "block_q",
                     "block_kv", "num_buffers", "skip_blocks", "interpret"))
def ragged_paged_attention_pallas(q_pad, kv_pages, page_table, cu_q_lens,
                                  kv_lens, *, scale: float,
                                  cap: Optional[float] = None,
                                  window: Optional[int] = None,
                                  max_q_len: int = 1,
                                  block_q: Optional[int] = None,
                                  block_kv: Optional[int] = None,
                                  num_buffers: int = 2,
                                  skip_blocks: bool = True,
                                  q_pos_pad=None, kv_pos_pages=None,
                                  interpret: bool = False):
    """Pallas entry. ``q_pad`` must be padded with at least
    ``ceil(max_q_len / block_q) * block_q`` extra rows so every q-tile's
    block load stays in bounds; callers go through
    ``ops.ragged_paged_attention`` which pads and re-slices.
    ``block_q``/``block_kv``/``num_buffers`` default to the autotuner's
    cached best config for this (page_size, head_dim) geometry;
    ``skip_blocks=False`` selects the ungrouped full-gather baseline."""
    from .tune import best_config

    Tpad, Hq, D = q_pad.shape
    _, ps, H2, _ = kv_pages.shape
    Hkv = H2 // 2
    G = Hq // Hkv
    S, W = page_table.shape
    max_q = max_q_len
    has_pos = kv_pos_pages is not None

    cfg = best_config(ps, D)
    bq = max(1, min(block_q or cfg.block_q, max_q))
    bkv = max(1, min(block_kv or cfg.block_kv, W))
    nbuf = max(2, min(num_buffers or cfg.num_buffers, 4))
    QB = -(-max_q // bq)
    NB = -(-W // bkv)

    scratch = [
        pltpu.VMEM((nbuf, ps, H2, D), kv_pages.dtype),   # kbuf
        pltpu.SemaphoreType.DMA((nbuf,)),                # ksem
        pltpu.SMEM((bkv,), jnp.int32),                   # plist (compacted)
        pltpu.VMEM((Hkv, bq * G, 1), jnp.float32),       # m_s
        pltpu.VMEM((Hkv, bq * G, 1), jnp.float32),       # l_s
        pltpu.VMEM((Hkv, bq * G, D), jnp.float32),       # acc_s
    ]
    q_spec = pl.BlockSpec((Tpad, Hq, D), lambda s, qb, nb, *_: (0, 0, 0))
    if has_pos:
        in_specs = [
            q_spec,
            pl.BlockSpec(memory_space=pltpu.ANY),        # kv_pages
            pl.BlockSpec((Tpad,), lambda s, qb, nb, *_: (0,)),   # q_pos
            pl.BlockSpec(memory_space=pltpu.ANY),        # kv_pos_pages
        ]
        args = [q_pad, kv_pages,
                jnp.asarray(q_pos_pad, jnp.int32),
                jnp.asarray(kv_pos_pages, jnp.int32)]
        scratch += [
            pltpu.VMEM((nbuf, ps), jnp.int32),           # pbuf
            pltpu.SemaphoreType.DMA((nbuf,)),            # psem
        ]
    else:
        in_specs = [q_spec, pl.BlockSpec(memory_space=pltpu.ANY)]
        args = [q_pad, kv_pages]

    kernel = functools.partial(
        _kernel, ps=ps, block_q=bq, block_kv=bkv, nbuf=nbuf, n_blocks=NB,
        Hkv=Hkv, G=G, D=D, scale=scale, cap=cap, window=window,
        skip_blocks=skip_blocks)

    def wrapped(cu_ref, kvlen_ref, tbl_ref, q_ref, kv_ref, *rest):
        if has_pos:
            qpos_ref, kvpos_ref, o_ref = rest[0], rest[1], rest[2]
            kbuf, ksem, plist, m_s, l_s, acc_s, pbuf, psem = rest[3:]
            kernel(cu_ref, kvlen_ref, tbl_ref, q_ref, kv_ref, o_ref,
                   kbuf, ksem, plist, m_s, l_s, acc_s,
                   qpos_ref=qpos_ref, kvpos_ref=kvpos_ref,
                   pbuf=pbuf, psem=psem)
        else:
            o_ref = rest[0]
            kbuf, ksem, plist, m_s, l_s, acc_s = rest[1:]
            kernel(cu_ref, kvlen_ref, tbl_ref, q_ref, kv_ref, o_ref,
                   kbuf, ksem, plist, m_s, l_s, acc_s)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(S, QB, NB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((Tpad, Hq, D),
                               lambda s, qb, nb, *_: (0, 0, 0)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        wrapped,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tpad, Hq, D), q_pad.dtype),
        interpret=interpret,
    )(jnp.asarray(cu_q_lens, jnp.int32),
      jnp.asarray(kv_lens, jnp.int32),
      jnp.asarray(page_table, jnp.int32),
      *args)


def pages_gathered(page_table, cu_q_lens, kv_lens, *, page_size: int,
                   max_q_len: int, block_q: Optional[int] = None,
                   block_kv: Optional[int] = None,
                   window: Optional[int] = None,
                   skip_blocks: bool = True) -> int:
    """Host-side replica of the kernel's gather decisions: the number of
    page DMAs the grid issues (the achieved page-read stream the kernel
    bench meters, and the analytic twin of the engine's per-page read
    accounting). Slot-derived positions only."""
    import numpy as np

    from .tune import best_config

    tbl = np.asarray(page_table)
    cu = np.asarray(cu_q_lens)
    kvl = np.asarray(kv_lens)
    S, W = tbl.shape
    ps = page_size
    cfg = best_config(ps, 0)
    bq = max(1, min(block_q or cfg.block_q, max(1, max_q_len)))
    total = 0
    for s in range(S):
        qlen = int(cu[s + 1] - cu[s])
        kv_len = int(kvl[s])
        n_pages = -(-kv_len // ps)
        for q_lo in range(0, max(1, max_q_len), bq):
            if q_lo >= qlen:
                continue
            if skip_blocks:
                last_qpos = min(kv_len - qlen + q_lo + bq, kv_len) - 1
                hi = min(n_pages, last_qpos // ps + 1)
                lo = (max(0, kv_len - qlen + q_lo - window + 1) // ps
                      if window is not None else 0)
                total += int(np.count_nonzero(tbl[s, lo:hi]))
            else:
                total += n_pages
    return total
