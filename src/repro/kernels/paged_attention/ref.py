"""Pure-jnp reference for ragged paged attention, plus the paged-plane
layout helpers the models share.

Layout contract (DESIGN.md §10): the unit of KV storage is a *page* of
``page_size`` consecutive token positions with K and V fused
head-interleaved —

    kv_pages: (n_pages, page_size, 2 * n_kv_heads, head_dim)

where head ``h``'s key rows sit at index ``2*h`` and its value rows at
``2*h + 1`` (one contiguous DMA per page streams both). A sequence is a
row of a ``page_table`` (int32 page ids): table slot ``j`` covers absolute
positions ``[j*page_size, (j+1)*page_size)``, so key positions are derived
from the slot index — no stored-position array. Null table entries
(page id 0, the reserved all-zeros page) are masked wherever they sit:
trailing padding is masked for free (slot-derived positions exceed every
causal query position) and interior nulls — sparse tables — are masked
by page id, matching the grouped kernel grid that skips them without a
gather.

The attention core scans the table one page at a time with an online
softmax whose accumulator is *exactly* invariant to trailing padding
pages (a fully-masked page contributes p == 0 and a rescale factor of 1),
so outputs are bit-identical across page-table widths and batch
compositions — the property the serving engine's prefix-hit-vs-cold
bit-equality tests rest on.

An optional ``kv_pos_pages`` (n_pages, page_size) int32 plane overrides
the slot-derived positions (-1 = empty row); this is how the legacy
ring-cache decode path folds into the same kernel grid
(``repro.kernels.decode_attention``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Layout helpers (the write half of the paged compute plane)
# ---------------------------------------------------------------------------


def interleave_kv(k, v):
    """(B, S, Hkv, D) k/v -> fused head-interleaved (B, S, 2*Hkv, D):
    head h's key at index 2h, its value at 2h+1."""
    B, S, Hkv, D = k.shape
    return jnp.stack([k, v], axis=3).reshape(B, S, 2 * Hkv, D)


def split_kv(kv):
    """Inverse of :func:`interleave_kv`: (..., 2*Hkv, D) -> (k, v)."""
    return kv[..., 0::2, :], kv[..., 1::2, :]


def write_tokens_to_pages(kv_pages, kv_new, positions, page_table,
                          active=None):
    """Scatter fused-KV rows into the paged plane.

    kv_pages: (P, ps, 2*Hkv, D) pool; kv_new: (B, S, 2*Hkv, D);
    positions: (B, S) absolute token positions; page_table: (B, W) int32.
    Rows whose position falls past the table width, or whose ``active``
    flag is False, are dropped (written nowhere) via an out-of-bounds
    scatter index — a mid-prefill slot's pages are never clobbered by a
    batched decode write."""
    P, ps = kv_pages.shape[0], kv_pages.shape[1]
    positions = jnp.asarray(positions, jnp.int32)
    W = page_table.shape[1]
    slot = positions // ps                               # (B, S) table slots
    row = positions % ps
    ok = (positions >= 0) & (slot < W)
    if active is not None:
        ok &= jnp.asarray(active, bool).reshape(-1, 1)
    pid = jnp.take_along_axis(page_table, jnp.clip(slot, 0, W - 1), axis=1)
    pid = jnp.where(ok, pid, P)                          # OOB -> dropped
    return kv_pages.at[pid, row].set(kv_new.astype(kv_pages.dtype),
                                     mode="drop")


# ---------------------------------------------------------------------------
# The attention core: rows form
# ---------------------------------------------------------------------------


def paged_attention_rows(q, kv_pages, page_table, q_pos, *, scale: float,
                         cap: Optional[float] = None,
                         window: Optional[int] = None,
                         kv_pos_pages=None):
    """Row-flattened paged attention — the one attend everything shares.

    q: (R, Hq, D) query rows; kv_pages: (P, ps, 2*Hkv, D); page_table:
    (R, W) int32 per-row tables; q_pos: (R,) absolute query positions.
    Extend flattens (B, S) to R = B*S rows, batched decode is R = B rows
    of one token each — both are just rows here. Returns (R, Hq, D) in
    the pool dtype.

    The page loop keeps a per-row online softmax in fp32; masked pages
    (padding, future positions, outside the window) contribute exactly
    zero and leave the accumulator bit-identical, so the result does not
    depend on the table's padded width."""
    q = jnp.asarray(q)
    kv_pages = jnp.asarray(kv_pages)
    page_table = jnp.asarray(page_table, jnp.int32)
    if kv_pos_pages is not None:
        kv_pos_pages = jnp.asarray(kv_pos_pages, jnp.int32)
    R, Hq, D = q.shape
    ps, H2 = kv_pages.shape[1], kv_pages.shape[2]
    Hkv = H2 // 2
    G = Hq // Hkv
    W = page_table.shape[1]
    qf = q.astype(jnp.float32).reshape(R, Hkv, G, D)
    qpos = jnp.asarray(q_pos, jnp.int32)

    m0 = jnp.full((R, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((R, Hkv, G), jnp.float32)
    a0 = jnp.zeros((R, Hkv, G, D), jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        pid = page_table[:, j]                           # (R,)
        kv = kv_pages[pid]                               # (R, ps, 2Hkv, D)
        k = kv[:, :, 0::2, :].astype(jnp.float32)        # (R, ps, Hkv, D)
        v = kv[:, :, 1::2, :].astype(jnp.float32)
        if kv_pos_pages is not None:
            kpos = kv_pos_pages[pid]                     # (R, ps)
        else:
            kpos = j * ps + jnp.arange(ps, dtype=jnp.int32)[None]
            kpos = jnp.broadcast_to(kpos, (R, ps))
        s = jnp.einsum("rhgd,rphd->rhgp", qf, k,
                       preferred_element_type=jnp.float32) * scale
        if cap is not None:
            s = jnp.tanh(s / cap) * cap
        valid = (kpos >= 0) & (kpos <= qpos[:, None])
        if window is not None:
            valid &= kpos > (qpos[:, None] - window)
        if kv_pos_pages is None:
            # slot-derived tables reserve page 0 as the null page: a null
            # slot *inside* the causal range (sparse tables) holds no
            # keys and must mask, exactly as the grouped kernel skips it
            valid &= (pid != 0)[:, None]
        vmask = valid[:, None, None, :]                  # (R, 1, 1, ps)
        s = jnp.where(vmask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # explicit zeroing (not just exp of NEG_INF): when every page so
        # far was masked, m_new == NEG_INF and exp(s - m_new) would be 1
        p = jnp.where(vmask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "rhgp,rphd->rhgd", p, v, preferred_element_type=jnp.float32)
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, W, body, (m0, l0, a0))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = acc / l_safe[..., None]
    return out.reshape(R, Hq, D).astype(kv_pages.dtype)


# ---------------------------------------------------------------------------
# Ragged reference (the kernel's oracle)
# ---------------------------------------------------------------------------


def ragged_paged_attention_ref(q, kv_pages, page_table, cu_q_lens, kv_lens,
                               *, scale: float, cap: Optional[float] = None,
                               window: Optional[int] = None,
                               q_pos=None, kv_pos_pages=None):
    """Bit-matching jnp reference for the ragged Pallas kernel.

    q: (T, Hq, D) queries of all sequences concatenated; cu_q_lens:
    (S+1,) int32 cumulative query lengths (T == cu_q_lens[-1]);
    kv_pages/page_table/kv_lens: per the module layout contract —
    ``page_table`` is (S, W), ``kv_lens`` (S,). Query i of sequence s
    sits at absolute position ``kv_lens[s] - q_len_s + i`` unless an
    explicit ``q_pos`` (T,) is given. Decode is every q_len == 1."""
    T = q.shape[0]
    cu = jnp.asarray(cu_q_lens, jnp.int32)
    kv_lens = jnp.asarray(kv_lens, jnp.int32)
    seg = jnp.searchsorted(cu[1:], jnp.arange(T, dtype=jnp.int32),
                           side="right")                 # (T,) sequence ids
    if q_pos is None:
        q_lens = cu[1:] - cu[:-1]
        q_pos = (kv_lens[seg] - q_lens[seg]
                 + jnp.arange(T, dtype=jnp.int32) - cu[seg])
    tbl = jnp.asarray(page_table, jnp.int32)[seg]        # (T, W)
    return paged_attention_rows(q, kv_pages, tbl, q_pos, scale=scale,
                                cap=cap, window=window,
                                kv_pos_pages=kv_pos_pages)
