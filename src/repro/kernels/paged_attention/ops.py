"""Host-side dispatch for ragged paged attention.

``ragged_paged_attention`` pads the flat query block by ``max_q_len``
rows (so the kernel's fixed-size per-sequence block loads stay in
bounds), routes to the Pallas kernel or the jnp reference, and slices
the padding back off. ``backend="auto"`` picks Pallas interpret mode off
TPU so CI exercises the exact kernel lowering on CPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import ragged_paged_attention_pallas
from .ref import ragged_paged_attention_ref


def ragged_paged_attention(q, kv_pages, page_table, cu_q_lens, kv_lens, *,
                           scale: float, cap: Optional[float] = None,
                           window: Optional[int] = None,
                           q_pos=None, kv_pos_pages=None,
                           max_q_len: Optional[int] = None,
                           backend: str = "auto",
                           interpret: Optional[bool] = None):
    """Attend T concatenated query rows against paged KV storage.

    q: (T, Hq, D); kv_pages: (P, ps, 2*Hkv, D) fused head-interleaved;
    page_table: (S, W) int32; cu_q_lens: (S+1,) int32 with
    cu_q_lens[-1] == T; kv_lens: (S,) int32. ``max_q_len`` must be a
    static bound on every per-sequence query length (defaults to T,
    which is always safe). ``q_pos``/``kv_pos_pages`` switch on explicit
    position tracking (ring-layout compatibility); both or neither.
    Returns (T, Hq, D) in q's dtype.
    """
    if (q_pos is None) != (kv_pos_pages is None):
        raise ValueError("q_pos and kv_pos_pages must be given together")
    if backend == "ref":
        return ragged_paged_attention_ref(
            q, kv_pages, page_table, cu_q_lens, kv_lens, scale=scale,
            cap=cap, window=window, q_pos=q_pos,
            kv_pos_pages=kv_pos_pages)
    if backend not in ("auto", "pallas"):
        raise ValueError(f"unknown backend: {backend!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    T = q.shape[0]
    max_q = T if max_q_len is None else int(max_q_len)
    max_q = max(1, max_q)
    q_pad = jnp.pad(q, ((0, max_q), (0, 0), (0, 0)))
    q_pos_pad = None
    if q_pos is not None:
        q_pos_pad = jnp.pad(jnp.asarray(q_pos, jnp.int32), (0, max_q))
    out = ragged_paged_attention_pallas(
        q_pad, kv_pages, page_table, cu_q_lens, kv_lens, scale=scale,
        cap=cap, window=window, max_q_len=max_q, q_pos_pad=q_pos_pad,
        kv_pos_pages=kv_pos_pages, interpret=interpret)
    return out[:T]
