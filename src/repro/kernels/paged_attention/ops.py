"""Host-side dispatch for ragged paged attention.

``ragged_paged_attention`` resolves the launch config (explicit
``block_q``/``block_kv``/``num_buffers`` overrides win, otherwise the
autotuner's cached best config for this page geometry), pads the flat
query block to a whole number of q-tiles (so every tile's fixed-size
block load stays in bounds), routes to the Pallas kernel or the jnp
reference, and slices the padding back off. ``backend="auto"`` picks
Pallas interpret mode off TPU so CI exercises the exact kernel lowering
on CPU; set ``REPRO_KERNEL_INTERPRET=0/1`` to force either mode without
touching call sites.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import ragged_paged_attention_pallas
from .ref import ragged_paged_attention_ref
from .tune import resolve_config

_INTERPRET: Optional[bool] = None


def _default_interpret() -> bool:
    """Process-wide default for Pallas interpret mode, resolved once:
    the ``REPRO_KERNEL_INTERPRET`` env var (0/1/true/false) wins,
    otherwise interpret off TPU. Cached because ``jax.default_backend()``
    walks the backend registry and this sits on the per-step decode
    path."""
    global _INTERPRET
    if _INTERPRET is None:
        env = os.environ.get("REPRO_KERNEL_INTERPRET", "").strip().lower()
        if env in ("1", "true", "yes", "on"):
            _INTERPRET = True
        elif env in ("0", "false", "no", "off"):
            _INTERPRET = False
        else:
            _INTERPRET = jax.default_backend() != "tpu"
    return _INTERPRET


def ragged_paged_attention(q, kv_pages, page_table, cu_q_lens, kv_lens, *,
                           scale: float, cap: Optional[float] = None,
                           window: Optional[int] = None,
                           q_pos=None, kv_pos_pages=None,
                           max_q_len: Optional[int] = None,
                           backend: str = "auto",
                           block_q: Optional[int] = None,
                           block_kv: Optional[int] = None,
                           num_buffers: Optional[int] = None,
                           skip_blocks: bool = True,
                           interpret: Optional[bool] = None):
    """Attend T concatenated query rows against paged KV storage.

    q: (T, Hq, D); kv_pages: (P, ps, 2*Hkv, D) fused head-interleaved;
    page_table: (S, W) int32; cu_q_lens: (S+1,) int32 with
    cu_q_lens[-1] == T; kv_lens: (S,) int32. ``max_q_len`` must be a
    static bound on every per-sequence query length (defaults to T,
    which is always safe). ``q_pos``/``kv_pos_pages`` switch on explicit
    position tracking (ring-layout compatibility); both or neither.
    ``block_q``/``block_kv``/``num_buffers`` override the autotuned
    kernel config; ``skip_blocks=False`` forces the ungrouped full-gather
    baseline (bench/parity use). Returns (T, Hq, D) in q's dtype.
    """
    if (q_pos is None) != (kv_pos_pages is None):
        raise ValueError("q_pos and kv_pos_pages must be given together")
    if backend == "ref":
        return ragged_paged_attention_ref(
            q, kv_pages, page_table, cu_q_lens, kv_lens, scale=scale,
            cap=cap, window=window, q_pos=q_pos,
            kv_pos_pages=kv_pos_pages)
    if backend not in ("auto", "pallas"):
        raise ValueError(f"unknown backend: {backend!r}")
    if interpret is None:
        interpret = _default_interpret()
    T = q.shape[0]
    ps = kv_pages.shape[1]
    max_q = T if max_q_len is None else int(max_q_len)
    max_q = max(1, max_q)
    cfg = resolve_config(ps, q.shape[-1], max_q, page_table.shape[1],
                         block_q, block_kv, num_buffers)
    pad = -(-max_q // cfg.block_q) * cfg.block_q
    q_pad = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
    q_pos_pad = None
    if q_pos is not None:
        q_pos_pad = jnp.pad(jnp.asarray(q_pos, jnp.int32), (0, pad))
    out = ragged_paged_attention_pallas(
        q_pad, kv_pages, page_table, cu_q_lens, kv_lens, scale=scale,
        cap=cap, window=window, max_q_len=max_q,
        block_q=cfg.block_q, block_kv=cfg.block_kv,
        num_buffers=cfg.num_buffers, skip_blocks=skip_blocks,
        q_pos_pad=q_pos_pad, kv_pos_pages=kv_pos_pages,
        interpret=interpret)
    return out[:T]
