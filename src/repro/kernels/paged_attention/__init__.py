"""Ragged paged attention: one kernel for prefill-extend and batched
decode, reading KV page-by-page straight off the paged plane."""
from .ops import ragged_paged_attention
from .ref import (
    interleave_kv,
    paged_attention_rows,
    ragged_paged_attention_ref,
    split_kv,
    write_tokens_to_pages,
)

__all__ = [
    "ragged_paged_attention",
    "ragged_paged_attention_ref",
    "paged_attention_rows",
    "interleave_kv",
    "split_kv",
    "write_tokens_to_pages",
]
