"""Block-shape / DMA-depth autotuning for the paged-attention kernel.

The grouped kernel has three free parameters — ``block_q`` (query rows
per tile), ``block_kv`` (page-table slots per block), ``num_buffers``
(DMA pipeline depth, 2–4) — whose best values depend on the page
geometry (page_size × head_dim fixes the VMEM slab a buffer holds) and
the accelerator generation, not on the workload. So they are tuned once
per ``(page_size, head_dim, arch)`` and cached:

* ``best_config(ps, D)`` — cheap lookup: explicit cache entry (from a
  prior ``autotune`` run, in-process or loaded from a JSON table) else a
  static heuristic default. Never runs the kernel.
* ``autotune(ps, D, ...)`` — sweeps a small candidate grid with the real
  kernel on synthetic ragged data, times each config, caches the winner,
  and optionally persists the table so later processes skip the sweep.

The heuristic default keeps the resident VMEM footprint
(``num_buffers`` KV slabs + fp32 accumulators) small enough for every
geometry the configs in this repo produce; the sweep exists for real
TPUs where deeper pipelines win once pages are large enough to hide
latency behind compute.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import asdict, dataclass
from typing import Dict, Optional, Tuple

DEFAULT_CACHE_PATH = os.environ.get("REPRO_KERNEL_TUNE_CACHE", "")

# candidate sweep: q rows per tile x table slots per block x DMA depth
CANDIDATE_BLOCK_Q = (8, 16, 32)
CANDIDATE_BLOCK_KV = (4, 8, 16)
CANDIDATE_BUFFERS = (2, 3, 4)


@dataclass(frozen=True)
class KernelConfig:
    block_q: int = 16
    block_kv: int = 8
    num_buffers: int = 2


_CACHE: Dict[Tuple[int, int, str], KernelConfig] = {}


def _arch() -> str:
    """Accelerator generation the tuned numbers belong to. Interpret-mode
    timings (CPU) are still self-consistent but are cached under their
    own key so they never masquerade as TPU results."""
    import jax

    try:
        return jax.devices()[0].device_kind.replace(" ", "-").lower()
    except Exception:
        return "cpu"


def best_config(page_size: int, head_dim: int,
                arch: Optional[str] = None) -> KernelConfig:
    """Cached best config for this geometry; heuristic default if the
    geometry was never tuned."""
    key = (int(page_size), int(head_dim), arch or _arch())
    hit = _CACHE.get(key)
    if hit is not None:
        return hit
    # heuristic: bigger pages already amortize DMA setup, so keep the
    # pipeline shallow; small pages want more in flight.
    if page_size and page_size <= 8:
        return KernelConfig(block_q=16, block_kv=8, num_buffers=3)
    return KernelConfig()


def resolve_config(page_size: int, head_dim: int, max_q_len: int,
                   table_width: int,
                   block_q: Optional[int] = None,
                   block_kv: Optional[int] = None,
                   num_buffers: Optional[int] = None) -> KernelConfig:
    """Effective config for one launch: explicit overrides win, the rest
    comes from the cache, and everything is clamped to the launch shape
    (a tile never exceeds max_q rows / the table width; depth 2-4)."""
    base = best_config(page_size, head_dim)
    bq = max(1, min(int(block_q or base.block_q), max(1, int(max_q_len))))
    bkv = max(1, min(int(block_kv or base.block_kv), max(1, int(table_width))))
    nb = max(2, min(int(num_buffers or base.num_buffers), 4))
    return KernelConfig(block_q=bq, block_kv=bkv, num_buffers=nb)


def set_config(page_size: int, head_dim: int, cfg: KernelConfig,
               arch: Optional[str] = None) -> None:
    _CACHE[(int(page_size), int(head_dim), arch or _arch())] = cfg


def load_table(path: str) -> int:
    """Merge a persisted tune table into the in-process cache; returns
    the number of entries loaded."""
    if not path or not os.path.exists(path):
        return 0
    with open(path) as f:
        data = json.load(f)
    n = 0
    for row in data.get("entries", []):
        _CACHE[(int(row["page_size"]), int(row["head_dim"]),
                str(row["arch"]))] = KernelConfig(
            block_q=int(row["block_q"]), block_kv=int(row["block_kv"]),
            num_buffers=int(row["num_buffers"]))
        n += 1
    return n


def save_table(path: str) -> None:
    entries = [
        {"page_size": ps, "head_dim": d, "arch": arch, **asdict(cfg)}
        for (ps, d, arch), cfg in sorted(_CACHE.items())
    ]
    with open(path, "w") as f:
        json.dump({"entries": entries}, f, indent=2, sort_keys=True)
        f.write("\n")


def _bench_case(page_size: int, head_dim: int, *, n_kv_heads: int = 2,
                group: int = 2, seqs: int = 3, pages_per_seq: int = 6,
                null_every: int = 3, seed: int = 0):
    """Synthetic ragged workload: a few sequences, sparse tables (every
    ``null_every``-th slot nulled) so the skip path is exercised."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(seed)
    ps, D = page_size, head_dim
    W = pages_per_seq
    n_pages = 1 + seqs * W
    kv = jnp.asarray(
        rng.standard_normal((n_pages, ps, 2 * n_kv_heads, D)),
        jnp.float32).at[0].set(0.0)
    tbl = np.zeros((seqs, W), np.int32)
    kvl = np.zeros((seqs,), np.int32)
    qls = []
    for s in range(seqs):
        used = W - s % 2                       # ragged page counts
        for j in range(used):
            if null_every and (j + 1) % null_every == 0:
                continue                        # sparse: leave slot null
            tbl[s, j] = 1 + s * W + j
        kvl[s] = used * ps
        qls.append(1 + (s * 5) % (2 * ps))      # ragged query lengths
    cu = np.concatenate([[0], np.cumsum(qls)]).astype(np.int32)
    T = int(cu[-1])
    q = jnp.asarray(
        rng.standard_normal((T, n_kv_heads * group, D)), jnp.float32)
    return q, kv, jnp.asarray(tbl), jnp.asarray(cu), jnp.asarray(kvl), \
        int(max(qls))


def autotune(page_size: int, head_dim: int, *, repeats: int = 3,
             cache_path: Optional[str] = None,
             candidates=None, verbose: bool = False) -> KernelConfig:
    """Time the candidate grid on a synthetic case, cache and return the
    winner. Runs in interpret mode off-TPU (timings then rank the Python
    pipeline, which is still monotone in gather count, and the cache key
    carries arch='cpu' so TPU runs retune)."""
    import jax

    from .kernel import ragged_paged_attention_pallas
    from .ops import _default_interpret

    q, kv, tbl, cu, kvl, max_q = _bench_case(page_size, head_dim)
    interpret = _default_interpret()
    D = q.shape[-1]
    best: Tuple[float, KernelConfig] = (float("inf"), best_config(
        page_size, head_dim))
    cand = candidates or [
        KernelConfig(bq, bkv, nb)
        for bq in CANDIDATE_BLOCK_Q for bkv in CANDIDATE_BLOCK_KV
        for nb in CANDIDATE_BUFFERS
    ]
    for cfg in cand:
        eff = resolve_config(page_size, D, max_q, tbl.shape[1],
                             cfg.block_q, cfg.block_kv, cfg.num_buffers)
        pad = -(-max_q // eff.block_q) * eff.block_q
        qp = jax.numpy.pad(q, ((0, pad), (0, 0), (0, 0)))

        def run():
            return ragged_paged_attention_pallas(
                qp, kv, tbl, cu, kvl, scale=1.0 / D ** 0.5,
                max_q_len=max_q, block_q=eff.block_q,
                block_kv=eff.block_kv, num_buffers=eff.num_buffers,
                interpret=interpret).block_until_ready()

        run()                                   # compile / warm
        t0 = time.perf_counter()
        for _ in range(repeats):
            run()
        dt = (time.perf_counter() - t0) / repeats
        if verbose:
            print(f"  tune ps={page_size} D={D} {eff}: {dt * 1e3:.2f} ms")
        if dt < best[0]:
            best = (dt, eff)
    set_config(page_size, D, best[1])
    path = cache_path if cache_path is not None else DEFAULT_CACHE_PATH
    if path:
        save_table(path)
    return best[1]


if DEFAULT_CACHE_PATH:
    load_table(DEFAULT_CACHE_PATH)
