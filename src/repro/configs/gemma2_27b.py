"""gemma2-27b — local+global alternating, logit softcaps [arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, head_dim=128,
sliding window 4096 on alternate layers, attn softcap 50, final softcap 30,
query scale (d_model/n_heads)^-0.5 = 144^-0.5, pre+post sublayer norms.
"""
from repro.configs.base import ModelConfig, register

GEMMA2_27B = register(ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    act="gelu",                    # GeGLU
    attn_softcap=50.0,
    final_softcap=30.0,
    q_scale=(4608 / 32) ** -0.5,   # query_pre_attn_scalar = 144
    window_pattern=(4096, None),   # local, global alternating
    post_norms=True,
    tie_embeddings=True,
    scale_embeddings=True,
    rope_theta=10000.0,
))
