"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434].

27L d_model=2048 16H, MLA kv_lora=512 (qk_nope 128, qk_rope 64, v 128,
no q-LoRA on Lite), MoE 64 routed top-6 + 2 shared, expert d_ff=1408,
first layer dense (d_ff=10944), vocab=102400.

NOTE (DESIGN.md §5): the assignment line says both "MoE 64e top-6" and
"2 shared+160 routed"; 160 is the V2-big count. We follow 64 routed
(the V2-Lite figure, consistent with "64e top-6").
"""
from repro.configs.base import ModelConfig, register

DEEPSEEK_V2_LITE = register(ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,             # the single leading dense layer
    vocab_size=102400,
    act="silu",
    kv_lora_rank=512,
    q_lora_rank=0,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    expert_d_ff=1408,
    first_dense_layers=1,
    rope_theta=10000.0,
))
