"""llama2-70b — the paper's own Figure-1 reference model (via Splitwise).

Not one of the 10 assigned archs; used by benchmarks/endurance_fig1.py to
reproduce the paper's KV-cache endurance-requirement computation.
80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=32000.
"""
from repro.configs.base import ModelConfig, register

LLAMA2_70B = register(ModelConfig(
    name="llama2-70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32000,
    act="silu",
    rope_theta=10000.0,
))
