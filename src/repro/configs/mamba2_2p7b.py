"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060].

64L d_model=2560 attention-free, vocab=50280, ssm_state=128.
d_inner = 2*2560 = 5120, headdim 64 -> 80 SSD heads, ngroups=1, conv=4.
"""
from repro.configs.base import ModelConfig, register

MAMBA2_2P7B = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
))
