"""mixtral-8x22b — 8 experts top-2, SWA [arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2,
sliding window 4096 (per assignment), head_dim=128.
"""
from repro.configs.base import ModelConfig, register

MIXTRAL_8X22B = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,            # == expert_d_ff; every MLP is MoE
    vocab_size=32768,
    act="silu",
    n_experts=8,
    moe_top_k=2,
    expert_d_ff=16384,
    window_pattern=(4096,),
    rope_theta=1_000_000.0,
))
