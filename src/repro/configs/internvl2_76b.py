"""internvl2-76b — InternViT + (Llama3-70B-class) backbone [arXiv:2404.16821].

Backbone only per the assignment: 80L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256. The InternViT frontend is a STUB — input_specs()
provides precomputed patch embeddings (256 tokens) prepended to the text.
"""
from repro.configs.base import ModelConfig, register

INTERNVL2_76B = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    act="silu",
    frontend="vision",
    n_frontend_tokens=256,
    rope_theta=500000.0,
))
