"""Architecture configs (``--arch <id>``). Importing this package registers
all assigned architectures plus the paper's reference model."""
from repro.configs.base import ModelConfig, LayerSpec, ScanGroup, REGISTRY, get_config, reduced, register
from repro.configs.shapes import SHAPES, ShapeConfig, get_shape, cells, LONG_CONTEXT_OK, LONG_CONTEXT_SKIP

# register all architectures
from repro.configs.mamba2_2p7b import MAMBA2_2P7B
from repro.configs.deepseek_7b import DEEPSEEK_7B
from repro.configs.gemma_2b import GEMMA_2B
from repro.configs.qwen3_8b import QWEN3_8B
from repro.configs.gemma2_27b import GEMMA2_27B
from repro.configs.mixtral_8x22b import MIXTRAL_8X22B
from repro.configs.deepseek_v2_lite_16b import DEEPSEEK_V2_LITE
from repro.configs.musicgen_large import MUSICGEN_LARGE
from repro.configs.hymba_1p5b import HYMBA_1P5B
from repro.configs.internvl2_76b import INTERNVL2_76B
from repro.configs.llama2_70b import LLAMA2_70B

ASSIGNED_ARCHS = (
    "mamba2-2.7b",
    "deepseek-7b",
    "gemma-2b",
    "qwen3-8b",
    "gemma2-27b",
    "mixtral-8x22b",
    "deepseek-v2-lite-16b",
    "musicgen-large",
    "hymba-1.5b",
    "internvl2-76b",
)

__all__ = [
    "ModelConfig", "LayerSpec", "ScanGroup", "REGISTRY", "get_config",
    "reduced", "register", "SHAPES", "ShapeConfig", "get_shape", "cells",
    "LONG_CONTEXT_OK", "LONG_CONTEXT_SKIP", "ASSIGNED_ARCHS",
]
