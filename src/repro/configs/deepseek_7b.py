"""deepseek-7b — llama-arch dense [arXiv:2401.02954].

30L d_model=4096 32H (GQA kv=32, i.e. MHA) d_ff=11008 vocab=102400.
"""
from repro.configs.base import ModelConfig, register

DEEPSEEK_7B = register(ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    act="silu",
    rope_theta=10000.0,
))
