"""gemma-2b — GeGLU, head_dim=256, MQA [arXiv:2403.08295].

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=256000.
"""
from repro.configs.base import ModelConfig, register

GEMMA_2B = register(ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    act="gelu",              # GeGLU
    tie_embeddings=True,
    scale_embeddings=True,   # embeddings scaled by sqrt(d_model)
    rope_theta=10000.0,
))
