"""Assigned input-shape sets.

LM transformer shapes are seq_len x global_batch. ``decode_*``/``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``. ``long_500k`` is only applicable to sub-quadratic archs
(SSM / hybrid / windowed attention) — see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs that may run long_500k (sub-quadratic / bounded-KV state)
LONG_CONTEXT_OK = frozenset({
    "mamba2-2.7b",      # SSM: O(1) state
    "hymba-1.5b",       # hybrid: SWA + 3 global layers
    "mixtral-8x22b",    # SWA window 4096 -> bounded KV
    "gemma2-27b",       # alternating local/global; global KV seq-sharded
})

# archs skipped for long_500k, with the DESIGN.md §Arch-applicability reason
LONG_CONTEXT_SKIP = {
    "deepseek-7b": "pure full attention (MHA)",
    "gemma-2b": "pure full attention (MQA, global)",
    "qwen3-8b": "pure full attention (GQA)",
    "deepseek-v2-lite-16b": "MLA is full attention over compressed KV",
    "musicgen-large": "pure full attention (MHA)",
    "internvl2-76b": "pure full attention (GQA)",
}


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def cells(arch_names):
    """Yield every applicable (arch, shape) dry-run cell."""
    for a in arch_names:
        for s in SHAPES.values():
            if s.name == "long_500k" and a not in LONG_CONTEXT_OK:
                continue
            yield a, s.name
