"""Model configuration system.

Every assigned architecture is expressed as a :class:`ModelConfig` — a frozen
dataclass rich enough to describe dense / GQA / MQA / MLA / MoE / SSM /
hybrid transformer families, per-layer attention patterns (sliding-window vs
global), and modality frontends (stubbed per the assignment).

Configs are registered in :data:`REGISTRY` and selected with ``--arch <id>``
throughout the launchers and benchmarks.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer specification
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    """One decoder layer's shape within the stack.

    ``kind``:
      - ``attn``   — self-attention (GQA/MQA/MHA) + MLP
      - ``mla``    — multi-head latent attention (DeepSeek-V2) + MLP
      - ``ssm``    — Mamba2 SSD block (no MLP when mlp == "none")
      - ``hybrid`` — parallel attention + SSM heads (Hymba)
    ``mlp``:
      - ``dense`` | ``moe`` | ``none``
    ``window``: sliding-window size (tokens) or ``None`` for global attention.
    """

    kind: str = "attn"
    mlp: str = "dense"
    window: Optional[int] = None

    def __post_init__(self):
        assert self.kind in ("attn", "mla", "ssm", "hybrid"), self.kind
        assert self.mlp in ("dense", "moe", "none"), self.mlp


@dataclass(frozen=True)
class ScanGroup:
    """A run of identical (or alternating) layers executed under lax.scan.

    ``unit`` is the tuple of LayerSpecs applied sequentially inside one scan
    step; ``repeats`` is the scan length. Total layers = len(unit) * repeats.
    """

    unit: Tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.unit) * self.repeats


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str = "unnamed"
    family: str = "dense"  # dense | moe | ssm | hybrid | audio | vlm

    # --- core dims -------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None  # default d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    vocab_pad_to: int = 512  # pad vocab so it shards over the model axis

    # --- attention options -------------------------------------------------
    qk_norm: bool = False  # qwen3: RMSNorm on per-head q and k
    attn_softcap: Optional[float] = None  # gemma2: tanh softcap on attn logits
    final_softcap: Optional[float] = None  # gemma2: tanh softcap on lm logits
    q_scale: Optional[float] = None  # override 1/sqrt(head_dim)
    rope_theta: float = 10000.0
    window_pattern: Optional[Tuple[Optional[int], ...]] = None  # cycled per layer
    global_layers: Tuple[int, ...] = ()  # indices forced global (hymba)

    # --- MLP options -------------------------------------------------------
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU) | gelu_plain (ungated)
    post_norms: bool = False  # gemma2: post-attention/post-ffn RMSNorms

    # --- embeddings --------------------------------------------------------
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma: embed * sqrt(d_model)

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # leading layers use dense MLP (deepseek-v2)
    router_aux_coef: float = 0.01

    # --- MLA (DeepSeek-V2) ---------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0  # 0 => direct q projection (V2-Lite)
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = False  # absorbed decode path (perf variant)

    # --- SSM (Mamba2 SSD) ----------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (Hymba) ------------------------------------------------------
    n_meta_tokens: int = 0

    # --- modality frontends (stubs per assignment) ---------------------------
    frontend: Optional[str] = None  # None | "audio" | "vision"
    n_codebooks: int = 1  # musicgen: embeddings summed / heads per codebook
    n_frontend_tokens: int = 0  # vision: patch tokens prepended

    # --- numerics / training --------------------------------------------------
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: str = "full"  # none | dots | full
    q_chunk: int = 512  # chunked-attention block sizes (pure-XLA flash)
    kv_chunk: int = 1024

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_to)

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    # ------------------------------------------------------------------
    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        """Full per-layer spec list (length == num_layers)."""
        specs = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                specs.append(LayerSpec(kind="ssm", mlp="none"))
                continue
            kind = "hybrid" if self.family == "hybrid" else (
                "mla" if self.kv_lora_rank else "attn")
            if self.n_experts and i >= self.first_dense_layers:
                mlp = "moe"
            else:
                mlp = "dense" if self.d_ff else "none"
            window = None
            if self.window_pattern:
                window = self.window_pattern[i % len(self.window_pattern)]
            if i in self.global_layers:
                window = None
            specs.append(LayerSpec(kind=kind, mlp=mlp, window=window))
        return tuple(specs)

    def scan_groups(self) -> Tuple[ScanGroup, ...]:
        """Group consecutive identical layers (or repeating units) for scan.

        Greedy: find the shortest repeating unit (length 1 or 2) from the
        current position. Alternating local/global (gemma2) becomes a
        2-layer unit; deepseek-v2's leading dense layer becomes its own
        group of repeats=1.
        """
        specs = list(self.layer_specs())
        groups = []
        i = 0
        n = len(specs)
        while i < n:
            # try unit length 1
            j = i
            while j < n and specs[j] == specs[i]:
                j += 1
            run1 = j - i
            # try unit length 2
            run2 = 0
            if i + 1 < n and specs[i + 1] != specs[i]:
                j = i
                while j + 1 < n and specs[j] == specs[i] and specs[j + 1] == specs[i + 1]:
                    j += 2
                run2 = (j - i) // 2
            if run2 * 2 > run1:
                groups.append(ScanGroup(unit=(specs[i], specs[i + 1]), repeats=run2))
                i += run2 * 2
            else:
                groups.append(ScanGroup(unit=(specs[i],), repeats=run1))
                i += run1
        assert sum(g.num_layers for g in groups) == n
        return tuple(groups)

    # ------------------------------------------------------------------
    # Analytic parameter counts (for MODEL_FLOPS and Fig-1 style analysis)
    # ------------------------------------------------------------------
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        if self.kv_lora_rank:  # MLA
            p = d * (self.kv_lora_rank + self.qk_rope_dim)  # kv down
            p += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)  # kv up
            if self.q_lora_rank:
                p += d * self.q_lora_rank
                p += self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
            else:
                p += d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
            p += self.n_heads * self.v_head_dim * d  # o proj
            return p
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        return q + kv + o

    def _dense_mlp_params(self, dff: int) -> int:
        mult = 3 if self.act in ("silu", "gelu") else 2  # gated vs plain
        return mult * self.d_model * dff

    def _ssm_params(self) -> int:
        if not self.ssm_state:
            return 0
        d, di, ng, ns = self.d_model, self.d_inner, self.ssm_ngroups, self.ssm_state
        nh = self.ssm_nheads
        in_proj = d * (2 * di + 2 * ng * ns + nh)  # z, x, B, C, dt
        conv = self.ssm_conv * (di + 2 * ng * ns)
        skip = nh * 2 + nh  # A_log, D, dt_bias
        out = di * d
        return in_proj + conv + skip + out

    def param_counts(self) -> dict:
        """Analytic totals: {'total': N, 'active': N_active, 'embed': E}."""
        spec_counts = {"total": 0, "active": 0}
        for spec in self.layer_specs():
            p_attn = 0
            if spec.kind in ("attn", "mla", "hybrid"):
                p_attn += self._attn_params()
            if spec.kind in ("ssm", "hybrid"):
                p_attn += self._ssm_params()
            p_mlp_total = p_mlp_active = 0
            if spec.mlp == "dense":
                p_mlp_total = p_mlp_active = self._dense_mlp_params(self.d_ff)
            elif spec.mlp == "moe":
                e = self._dense_mlp_params(self.expert_d_ff)
                p_mlp_total = self.n_experts * e + self.n_shared_experts * e
                p_mlp_active = self.moe_top_k * e + self.n_shared_experts * e
                p_mlp_total += self.d_model * self.n_experts  # router
                p_mlp_active += self.d_model * self.n_experts
            norms = 2 * self.d_model * (2 if self.post_norms else 1)
            spec_counts["total"] += p_attn + p_mlp_total + norms
            spec_counts["active"] += p_attn + p_mlp_active + norms
        embed = self.padded_vocab * self.d_model * self.n_codebooks
        head = 0 if self.tie_embeddings else self.padded_vocab * self.d_model * self.n_codebooks
        meta = self.n_meta_tokens * self.d_model
        total = spec_counts["total"] + embed + head + self.d_model + meta
        active = spec_counts["active"] + embed + head + self.d_model + meta
        return {"total": total, "active": active, "embed": embed}

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """KV-cache bytes appended per generated token (paper §2: the
        'self-attention vector' write)."""
        total = 0
        for spec in self.layer_specs():
            if spec.kind in ("attn", "hybrid"):
                total += 2 * self.n_kv_heads * self.resolved_head_dim * bytes_per_el
            elif spec.kind == "mla":
                total += (self.kv_lora_rank + self.qk_rope_dim) * bytes_per_el
        return total

    def ssm_state_bytes_layer(self, bytes_per_el: int = 2) -> int:
        """Bytes of one layer's recurrent-state page (paged compute plane,
        DESIGN.md §10): the depthwise-conv left context at model precision
        plus the SSD state, which is carried in fp32 regardless of the
        model dtype."""
        if not self.ssm_state:
            return 0
        conv_dim = self.d_inner + 2 * self.ssm_ngroups * self.ssm_state
        conv = (self.ssm_conv - 1) * conv_dim * bytes_per_el
        state = self.ssm_nheads * self.ssm_headdim * self.ssm_state * 4
        return conv + state

    def state_bytes_per_page(self, bytes_per_el: int = 2) -> int:
        """Recurrent-state bytes carried per KV-manager page across the
        whole stack (zero for pure attention/MLA stacks): point stacks
        (SSM/hybrid) pin one boundary state snapshot per page so a radix
        hit is a page-table splice for every mixer family."""
        per_layer = self.ssm_state_bytes_layer(bytes_per_el)
        n = sum(1 for spec in self.layer_specs()
                if spec.kind in ("ssm", "hybrid"))
        return per_layer * n

    def validate(self) -> None:
        assert self.num_layers > 0 and self.d_model > 0
        if self.family not in ("ssm",):
            assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.n_experts:
            assert self.moe_top_k > 0 and self.expert_d_ff > 0
        if self.family == "ssm":
            assert self.ssm_state > 0
            assert self.d_inner % self.ssm_headdim == 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    cfg.validate()
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # populate registry lazily
    from repro import configs as _c  # noqa: F401  (imports register all archs)

    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        vocab_pad_to=64,
        q_chunk=64,
        kv_chunk=64,
    )
    if cfg.n_experts:
        small.update(n_experts=4, moe_top_k=min(cfg.moe_top_k, 2), expert_d_ff=128,
                     n_shared_experts=min(cfg.n_shared_experts, 1),
                     first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.kv_lora_rank:
        small.update(kv_lora_rank=64, q_lora_rank=0, qk_nope_dim=32, qk_rope_dim=16,
                     v_head_dim=32, head_dim=None)
    if cfg.ssm_state:
        small.update(ssm_state=16, ssm_headdim=32, ssm_chunk=32)
    if cfg.window_pattern:
        wp = tuple(None if w is None else 64 for w in cfg.window_pattern)
        small.update(window_pattern=wp)
    if cfg.global_layers:
        small.update(global_layers=tuple(i for i in cfg.global_layers if i < 4))
    if cfg.n_meta_tokens:
        small.update(n_meta_tokens=8)
    if cfg.n_frontend_tokens:
        small.update(n_frontend_tokens=8)
    small.update(overrides)
    new = dataclasses.replace(cfg, **small)
    new.validate()
    return new
