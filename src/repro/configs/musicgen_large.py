"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=2048 (per codebook),
4 codebooks with the delay interleaving handled by the (stubbed) frontend;
the backbone sums codebook embeddings and emits 4 LM heads.
"""
from repro.configs.base import ModelConfig, register

MUSICGEN_LARGE = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    vocab_pad_to=128,
    act="gelu_plain",       # ungated MLP
    frontend="audio",
    n_codebooks=4,
    rope_theta=10000.0,
))
