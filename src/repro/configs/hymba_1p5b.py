"""hymba-1.5b — parallel attn+mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16,
128 meta tokens, SWA 2048 everywhere except global layers {0, 15, 31}.
Cross-layer KV sharing is NOT modelled (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, register

HYMBA_1P5B = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    act="silu",
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,       # d_inner = 3200 -> 50 SSD heads
    ssm_ngroups=1,
    window_pattern=(2048,),
    global_layers=(0, 15, 31),
    n_meta_tokens=128,
    rope_theta=10000.0,
))
