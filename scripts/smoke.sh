#!/usr/bin/env bash
# Smoke check: tier-1 tests + one fast serving benchmark with a JSON
# trajectory. Run from the repo root:  bash scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== serving benchmark (fast) =="
python -m benchmarks.run serving --json /tmp/smoke_serving.json
python - <<'EOF'
import json
rep = json.load(open("/tmp/smoke_serving.json"))
assert not rep["failures"], rep["failures"]
fleet = rep["suites"]["serving"]["replicas_2"]
assert fleet["dropped_allocs"] == 0, fleet
reuse = rep["suites"]["serving"]["prefix_reuse"]
assert reuse["prefill_cut"] >= 0.30, reuse
assert reuse["kv_write_cut"] >= 0.30, reuse
print("smoke OK:", {k: fleet[k] for k in ("finished", "tokens_generated",
                                          "pressure_events", "dropped_allocs")})
print("prefix reuse:", {k: round(reuse[k], 4) for k in
                        ("prefix_hit_rate", "prefill_cut", "kv_write_cut")})
EOF
