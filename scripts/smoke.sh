#!/usr/bin/env bash
# Smoke check: tier-1 tests + one fast serving benchmark with a JSON
# trajectory + the documented examples. Run from the repo root:
#   bash scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== examples (the README quickstart entry points must keep running) =="
python examples/quickstart.py > /dev/null
python examples/serve_batched_mrm.py > /dev/null

echo "== serving benchmark (fast) =="
python -m benchmarks.run serving --json /tmp/smoke_serving.json
python - <<'EOF'
import json
rep = json.load(open("/tmp/smoke_serving.json"))
assert not rep["failures"], rep["failures"]
fleet = rep["suites"]["serving"]["replicas_2"]
assert fleet["dropped_allocs"] == 0, fleet
# prefix reuse must be real compute savings for EVERY snapshot family
# (DESIGN.md §8): attention ring caches, SSM point snapshots, hybrid union
for key in ("prefix_reuse", "prefix_reuse_ssm", "prefix_reuse_hybrid"):
    reuse = rep["suites"]["serving"][key]
    assert reuse["prefill_cut"] >= 0.30, (key, reuse)
    if reuse["kv_write_cut"] is not None:
        assert reuse["kv_write_cut"] >= 0.30, (key, reuse)
# paged compute plane (DESIGN.md §10): a prefix hit must cost ZERO copy
# bytes (no donor-seed cache copy, no snapshot) at bit-identical decoded
# tokens, while the ring comparator still pays seed copies per hit, and
# the KV tier's metered reads must equal the kernel's page-gather bytes
pk = rep["suites"]["serving"]["paged_kernel"]
assert pk["seed_copy_bytes"] == 0, pk
assert pk["snapshot_bytes"] == 0, pk
assert pk["seed_copy_bytes_ring"] > 0, pk
assert pk["compute_hits"] > 0, pk
assert pk["kernel_read_bytes"] > 0, pk
assert abs(pk["kv_tier_read_bytes"] - pk["kernel_read_bytes"]) < 1e-6, pk
# sub-page tails (DESIGN.md §9): boundary-straddling prefixes must cut
# strictly more prefill tokens than the page-aligned matcher, with the
# tail copies actually metered — a tail-reuse regression fails the build
tr = rep["suites"]["serving"]["tail_reuse"]
assert tr["prefill_cut"] > tr["prefill_cut_page_aligned"], tr
assert tr["tail_hits"] > 0 and tr["tail_copy_bytes"] > 0, tr
# fleet-level reuse: the prefix directory + cross-replica migration must
# cut fleet prefill tokens >= 20% vs the per-replica radix baseline, with
# real metered interconnect traffic and balanced pressure ledgers — a
# cross-replica reuse regression fails the build here. The SSM variant
# moves a *point* state snapshot over the wire (no KV byte stream).
for key in ("fleet_reuse", "fleet_reuse_ssm"):
    fr = rep["suites"]["serving"][key]
    assert fr["prefill_cut"] >= 0.20, (key, fr)
    assert fr["ledger_imbalance"] == 0, (key, fr)
    assert fr["cross_replica_hits"] > 0, (key, fr)
    assert fr["migration_bytes"] > 0, (key, fr)
    assert fr["dropped_allocs"] == 0, (key, fr)
reuse = rep["suites"]["serving"]["prefix_reuse"]
fr = rep["suites"]["serving"]["fleet_reuse"]
print("smoke OK:", {k: fleet[k] for k in ("finished", "tokens_generated",
                                          "pressure_events", "dropped_allocs")})
print("prefix reuse:", {k: round(reuse[k], 4) for k in
                        ("prefix_hit_rate", "prefill_cut", "kv_write_cut")})
print("prefix reuse (ssm/hybrid):",
      {k: round(rep["suites"]["serving"][k]["prefill_cut"], 4)
       for k in ("prefix_reuse_ssm", "prefix_reuse_hybrid")})
print("paged kernel:", {k: round(pk[k], 4) for k in
                        ("compute_hits", "seed_copy_bytes",
                         "seed_copy_bytes_ring", "kernel_read_bytes")})
print("tail reuse:", {k: round(tr[k], 4) for k in
                      ("prefill_cut", "prefill_cut_page_aligned",
                       "tail_hits", "tail_tokens_copied")})
print("fleet reuse:", {k: round(fr[k], 4) for k in
                       ("prefill_cut", "cross_replica_hit_rate",
                        "migrations", "migration_bytes")})
print("fleet reuse (ssm):",
      {k: round(rep["suites"]["serving"]["fleet_reuse_ssm"][k], 4) for k in
       ("prefill_cut", "cross_replica_hit_rate", "migration_bytes")})
EOF
