#!/usr/bin/env bash
# Smoke check: tier-1 tests + one fast serving benchmark with a JSON
# trajectory. Run from the repo root:  bash scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== serving benchmark (fast) =="
python -m benchmarks.run serving --json /tmp/smoke_serving.json
python - <<'EOF'
import json
rep = json.load(open("/tmp/smoke_serving.json"))
assert not rep["failures"], rep["failures"]
fleet = rep["suites"]["serving"]["replicas_2"]
assert fleet["dropped_allocs"] == 0, fleet
reuse = rep["suites"]["serving"]["prefix_reuse"]
assert reuse["prefill_cut"] >= 0.30, reuse
assert reuse["kv_write_cut"] >= 0.30, reuse
# fleet-level reuse: the prefix directory + cross-replica migration must
# cut fleet prefill tokens >= 20% vs the per-replica radix baseline, with
# real metered interconnect traffic and balanced pressure ledgers — a
# cross-replica reuse regression fails the build here
fr = rep["suites"]["serving"]["fleet_reuse"]
assert fr["prefill_cut"] >= 0.20, fr
assert fr["ledger_imbalance"] == 0, fr
assert fr["cross_replica_hits"] > 0, fr
assert fr["migration_bytes"] > 0, fr
assert fr["dropped_allocs"] == 0, fr
print("smoke OK:", {k: fleet[k] for k in ("finished", "tokens_generated",
                                          "pressure_events", "dropped_allocs")})
print("prefix reuse:", {k: round(reuse[k], 4) for k in
                        ("prefix_hit_rate", "prefill_cut", "kv_write_cut")})
print("fleet reuse:", {k: round(fr[k], 4) for k in
                       ("prefill_cut", "cross_replica_hit_rate",
                        "migrations", "migration_bytes")})
EOF
