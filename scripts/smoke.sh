#!/usr/bin/env bash
# Smoke check: tier-1 tests + one fast serving benchmark with a JSON
# trajectory. Run from the repo root:  bash scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== serving benchmark (fast) =="
python -m benchmarks.run serving --json /tmp/smoke_serving.json
python - <<'EOF'
import json
rep = json.load(open("/tmp/smoke_serving.json"))
assert not rep["failures"], rep["failures"]
fleet = rep["suites"]["serving"]["replicas_2"]
assert fleet["dropped_allocs"] == 0, fleet
print("smoke OK:", {k: fleet[k] for k in ("finished", "tokens_generated",
                                          "pressure_events", "dropped_allocs")})
EOF
