#!/usr/bin/env bash
# Smoke check: tier-1 tests + one fast serving benchmark with a JSON
# trajectory + the documented examples. Run from the repo root:
#   bash scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== examples (the README quickstart entry points must keep running) =="
python examples/quickstart.py > /dev/null
python examples/serve_batched_mrm.py > /dev/null

echo "== serving benchmark (fast) =="
python -m benchmarks.run serving --json /tmp/smoke_serving.json
python - <<'EOF'
import json
rep = json.load(open("/tmp/smoke_serving.json"))
assert not rep["failures"], rep["failures"]
fleet = rep["suites"]["serving"]["replicas_2"]
assert fleet["dropped_allocs"] == 0, fleet
# prefix reuse must be real compute savings for EVERY snapshot family
# (DESIGN.md §8): attention ring caches, SSM point snapshots, hybrid union
for key in ("prefix_reuse", "prefix_reuse_ssm", "prefix_reuse_hybrid"):
    reuse = rep["suites"]["serving"][key]
    assert reuse["prefill_cut"] >= 0.30, (key, reuse)
    if reuse["kv_write_cut"] is not None:
        assert reuse["kv_write_cut"] >= 0.30, (key, reuse)
# paged compute plane (DESIGN.md §10), universal: for EVERY family — KV
# pages (attention), latent pages (MLA covered by tests) and point-state
# pages (SSM/hybrid) — a prefix hit must cost ZERO copy bytes at
# bit-identical decoded tokens vs a cold paged start, with ZERO ring
# fallbacks, while the ring comparator still pays seed copies per hit,
# and the KV tier's metered reads must equal the kernel's gather bytes
for key in ("paged_kernel", "paged_kernel_ssm", "paged_kernel_hybrid"):
    pk = rep["suites"]["serving"][key]
    assert pk["ring_fallbacks"] == 0, (key, pk)
    assert pk["seed_copy_bytes"] == 0, (key, pk)
    assert pk["snapshot_bytes"] == 0, (key, pk)
    assert pk["seed_copy_bytes_ring"] > 0, (key, pk)
    assert pk["compute_hits"] > 0, (key, pk)
    assert pk["kernel_read_bytes"] > 0, (key, pk)
    assert abs(pk["kv_tier_read_bytes"] - pk["kernel_read_bytes"]) < 1e-6, \
        (key, pk)
    if key != "paged_kernel":   # recurrent stacks meter state pages too
        assert pk["state_bytes_page"] > 0, (key, pk)
pk = rep["suites"]["serving"]["paged_kernel"]
# sub-page tails (DESIGN.md §9): boundary-straddling prefixes must cut
# strictly more prefill tokens than the page-aligned matcher, with the
# tail copies actually metered — a tail-reuse regression fails the build
tr = rep["suites"]["serving"]["tail_reuse"]
assert tr["prefill_cut"] > tr["prefill_cut_page_aligned"], tr
assert tr["tail_hits"] > 0 and tr["tail_copy_bytes"] > 0, tr
# fleet-level reuse: the prefix directory + cross-replica migration must
# cut fleet prefill tokens >= 20% vs the per-replica radix baseline, with
# real metered interconnect traffic and balanced pressure ledgers — a
# cross-replica reuse regression fails the build here. The SSM variant
# moves a *point* state snapshot over the wire (no KV byte stream).
for key in ("fleet_reuse", "fleet_reuse_ssm"):
    fr = rep["suites"]["serving"][key]
    assert fr["prefill_cut"] >= 0.20, (key, fr)
    assert fr["ledger_imbalance"] == 0, (key, fr)
    assert fr["cross_replica_hits"] > 0, (key, fr)
    assert fr["migration_bytes"] > 0, (key, fr)
    assert fr["dropped_allocs"] == 0, (key, fr)
reuse = rep["suites"]["serving"]["prefix_reuse"]
fr = rep["suites"]["serving"]["fleet_reuse"]
print("smoke OK:", {k: fleet[k] for k in ("finished", "tokens_generated",
                                          "pressure_events", "dropped_allocs")})
print("prefix reuse:", {k: round(reuse[k], 4) for k in
                        ("prefix_hit_rate", "prefill_cut", "kv_write_cut")})
print("prefix reuse (ssm/hybrid):",
      {k: round(rep["suites"]["serving"][k]["prefill_cut"], 4)
       for k in ("prefix_reuse_ssm", "prefix_reuse_hybrid")})
print("paged kernel:", {k: round(pk[k], 4) for k in
                        ("compute_hits", "seed_copy_bytes",
                         "seed_copy_bytes_ring", "kernel_read_bytes")})
print("tail reuse:", {k: round(tr[k], 4) for k in
                      ("prefill_cut", "prefill_cut_page_aligned",
                       "tail_hits", "tail_tokens_copied")})
print("fleet reuse:", {k: round(fr[k], 4) for k in
                       ("prefill_cut", "cross_replica_hit_rate",
                        "migrations", "migration_bytes")})
print("fleet reuse (ssm):",
      {k: round(rep["suites"]["serving"]["fleet_reuse_ssm"][k], 4) for k in
       ("prefill_cut", "cross_replica_hit_rate", "migration_bytes")})
EOF

echo "== predictive replication A/B (reactive vs predictive fleet plane) =="
python -m benchmarks.run replication --json /tmp/smoke_replication.json
python - <<'EOF'
import json
rep = json.load(open("/tmp/smoke_replication.json"))
assert not rep["failures"], rep["failures"]
# DESIGN.md §13 gates: the herald-led rag_storm fan-out must cut TTFT
# p95 >= 40% vs the reactive baseline at bit-identical decoded tokens,
# with nonzero speculative push bytes, strictly fewer demand migrations,
# and a fabric byte ledger that balances exactly (every byte is one
# demand migration or one speculative push — zero imbalance)
for key, arm in rep["suites"]["replication"].items():
    assert arm["replicated_bytes"] > 0, (key, arm)
    assert arm["migrations_predictive"] < arm["migrations_reactive"], \
        (key, arm)
    assert arm["ledger_imbalance"] == 0, (key, arm)
rs = rep["suites"]["replication"]["rag_storm"]
assert rs["ttft_p95_cut"] >= 0.40, rs
di = rep["suites"]["replication"]["diurnal"]
assert di["ttft_p95_cut"] >= -0.02, di
print("replication:", {k: {"ttft_p95_cut": round(a["ttft_p95_cut"], 4),
                           "migrations": (a["migrations_reactive"],
                                          a["migrations_predictive"]),
                           "replicated_gb": round(a["replicated_bytes"] / 1e9,
                                                  2)}
                       for k, a in rep["suites"]["replication"].items()})
EOF

echo "== kernel bench (grouped grid vs ungrouped baseline) =="
python -m benchmarks.run kernel_bench --json /tmp/smoke_kernels.json
python - <<'EOF'
import json
rep = json.load(open("/tmp/smoke_kernels.json"))
assert not rep["failures"], rep["failures"]
# the grouped, null-skipping grid must read strictly fewer page bytes
# than the ungrouped (PR 6) gather on sparse page tables — at bit-equal
# outputs (asserted inside the bench) — for every geometry; the same
# entry lands in BENCH_kernels.json as the persisted trajectory
entry = rep["suites"]["kernel_bench"]
for case in entry["cases"]:
    g, u = (case["kernel_read_bytes_grouped"],
            case["kernel_read_bytes_ungrouped"])
    assert 0 < g < u, case
traj = json.load(open("BENCH_kernels.json"))
assert traj["entries"], "kernel-bench trajectory must persist"
print("kernel bench:", [
    {"ps": c["page_size"], "read_cut": round(c["read_bytes_cut"], 4)}
    for c in entry["cases"]])
EOF
