"""Regenerate EXPERIMENTS.md from the dry-run/benchmark artifacts.

  PYTHONPATH=src python scripts/gen_experiments.py
"""
import glob
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

from benchmarks.roofline import analytic_kernel_bytes  # noqa: E402
from repro.launch.mesh import HBM_BW  # noqa: E402

ART = ROOT / "artifacts"


def load(f):
    return json.loads(pathlib.Path(f).read_text())


def cells(mesh, base=ART / "dryrun"):
    out = {}
    for f in sorted(glob.glob(str(base / f"*__{mesh}__base.json"))):
        d = load(f)
        out[(d["arch"], d["shape"])] = d
    return out


def fmt_e(x):
    return f"{x:.2e}"


MOVE_NOTES = {
    ("memory", "train"): "XLA-path attention/score + remat traffic; the Pallas flash kernel keeps block intermediates in VMEM (see kmem_s)",
    ("memory", "prefill"): "score-matrix materialization; Pallas flash kernel streams KV once per q-block (kmem_s)",
    ("memory", "decode"): "whole-KV + weight read stream per token; Pallas decode kernel streams pages at HBM bw (kmem_s)",
    ("compute", "train"): "reduce remat recompute (checkpoint policy) and MoE capacity factor",
    ("collective", "train"): "overlap TP collectives with compute; reduce-scatter gradient averaging; inter-pod gradient compression",
    ("collective", "decode"): "KV-seq partial-softmax reductions; batch them across layers",
}


def main():
    single = cells("single")
    multi = cells("multi")
    fig1 = load(ART / "fig1.json")
    workload = load(ART / "workload.json")
    tco = load(ART / "tco.json")

    L = []
    w = L.append
    w("# EXPERIMENTS — Managed-Retention Memory reproduction\n")
    w("All numbers regenerable: `PYTHONPATH=src python -m repro.launch.dryrun --all "
      "--mesh both && PYTHONPATH=src python -m benchmarks.run && PYTHONPATH=src "
      "python scripts/gen_experiments.py`.\n")

    # ----------------------------------------------------------------- setup
    w("## §Setup and conventions\n")
    w("- Target hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link "
      "ICI (assignment constants). Container is CPU-only: kernels validated in "
      "Pallas interpret mode; dry-runs lower+compile against 512 forced host "
      "devices; nothing here is a wall-clock measurement.")
    w("- Meshes: single-pod (16,16)=(data,model), multi-pod (2,16,16)=(pod,data,model).")
    w("- **Trip-count-aware analysis**: `compiled.cost_analysis()` counts lax.scan "
      "bodies ONCE (verified: a 10-iteration scanned matmul reports 1x flops). All "
      "FLOPs/bytes/collective numbers below come from our HLO analyzer "
      "(`repro/launch/hlo_analysis.py`) which multiplies while-loop bodies by "
      "their parsed trip counts; it matches XLA exactly on loop-free graphs "
      "(tested). The raw `cost_analysis()` is also recorded in each artifact.")
    w("- Roofline terms (seconds, per device): compute = flops/197e12; memory = "
      "bytes_accessed/819e9 (XLA-style op-IO model with fusion/slice/in-place "
      "handling); collective = per-device collective *operand* bytes/50e9 per the "
      "assignment formula (wire-corrected bytes also recorded per artifact).")
    w("- `kmem_s` = analytic fused-kernel memory bound (weights+activations+KV "
      "streaming only — what the validated Pallas kernels achieve by keeping "
      "score/decay blocks in VMEM; `benchmarks/roofline.py:analytic_kernel_bytes`).")
    w("- MODEL_FLOPS = 6*N*D (train) or 2*N_active*D (serve); useful = "
      "MODEL_FLOPS / (per-device HLO flops x 256).\n")

    # ------------------------------------------------------- paper validation
    w("## §Paper-validation\n")
    w("### Figure 1 — endurance requirements vs technologies (writes/cell, 5-year life)\n")
    w("| requirement | writes/cell |")
    w("|---|---|")
    for k, v in fig1["requirements"].items():
        w(f"| {k.replace('_', ' ')} | {v:.2e} |")
    w("")
    w("| technology | device endurance | potential |")
    w("|---|---|---|")
    for k in ("nand_slc", "optane_pcm", "rram", "stt_mram", "hbm3e",
              "mrm_pcm", "mrm_rram", "mrm_mram"):
        t = fig1["technologies"][k]
        w(f"| {k} | {t['device']:.0e} | {t['potential']:.0e} |")
    w("")
    w("Verdicts (the paper's §3 claims, all reproduced): " +
      ", ".join(f"**{k}**={v}" for k, v in fig1["verdicts"].items()) + "\n")
    w("### Workload characterization (§2.2), MEASURED from the serving engine\n")
    w(f"- steady-state read:write ratio **{workload['steady_rw_ratio']:,.0f} : 1** "
      f"(paper: >1000:1) — llama2-70b accounting scale, real token generation")
    w(f"- sequential read fraction **{workload['seq_read_fraction']*100:.1f}%**; "
      f"writes are append-only KV pages + one-time weight deploy")
    w(f"- KV append per token {workload['kv_bytes_per_token']/1024:.0f} KiB vs "
      f"{workload['weight_read_bytes_per_token']/1e9:.0f} GB of weight reads per "
      f"decode step (amplification {workload['weight_to_kvwrite_amplification']:,.0f}x)\n")
    w("### Tiering / TCO (llama2-70b inference machine)\n")
    w("| system | feasible | memory power (W) | vs HBM-only | tokens/J |")
    w("|---|---|---|---|---|")
    for k, v in tco.items():
        w(f"| {k} | {v['feasible']} | {v['energy_w']:.0f} | "
          f"{v['energy_vs_hbm']:.2f}x | {v['tokens_per_joule']:.1f} |")
    w("")
    w("MRM tiers are feasible and cut sustained memory power 2.2-2.9x; the "
      "LPDDR capacity tier alone is infeasible (read bandwidth) — the paper's "
      "argument for a *new* class rather than existing slow tiers. Placement "
      "solver puts weights+KV on MRM and write-heavy activations on HBM, "
      "matching §4's co-existence claim.\n")

    # ----------------------------------------------------------------- dryrun
    w("## §Dry-run\n")
    n_s, n_m = len(single), len(multi)
    fit_s = sum(1 for d in single.values() if d["memory"]["fits_16gib"])
    w(f"All **{n_s} single-pod + {n_m} multi-pod cells compile** "
      "(`.lower().compile()` with ShapeDtypeStruct inputs, no allocation); "
      "`memory_analysis()`/`cost_analysis()` captured per cell under "
      "`artifacts/dryrun/`. 6 long_500k cells are skipped by design for pure "
      "full-attention archs (DESIGN.md §Arch-applicability): 34+34 run + 6 "
      "documented skips = 40 assigned cells.\n")
    w("Multi-pod (2,16,16): batch shards over (pod,data) — e.g. per-cell "
      "argument bytes halve vs single-pod for batch-sharded inputs; the 'pod' "
      "axis carries the data-parallel gradient reduction (train) and request "
      "sharding (serve).\n")
    w(f"{fit_s}/{n_s} single-pod cells fit 16 GiB/device as-is; the oversized "
      "cells are exactly the big-model train cells and dense-KV decode cells — "
      "§Perf shows the variants that bring the three hillclimbed cells down "
      "(e.g. internvl2 train 322->55 GiB, mixtral train 265->28 GiB, "
      "deepseek-v2-lite decode 33->2.3 GiB).\n")
    w("| arch | shape | mesh | compile_s | GiB/dev | fits |")
    w("|---|---|---|---|---|---|")
    for (a, s), d in {**single, **{(a, s): d for (a, s), d in multi.items()}}.items():
        pass
    for mesh_name, tbl in (("single", single), ("multi", multi)):
        for (a, s), d in tbl.items():
            m = d["memory"]
            w(f"| {a} | {s} | {mesh_name} | {d.get('compile_s', 0):.0f} | "
              f"{m['per_device_gib']:.1f} | {'Y' if m['fits_16gib'] else 'N'} |")
    w("")

    # ------------------------------------------------- multi-pod comparison
    w("### Multi-pod scaling check (single (16,16) vs multi (2,16,16))\n")
    w("| arch | shape | GiB/dev single | GiB/dev multi | coll_s single | coll_s multi |")
    w("|---|---|---|---|---|---|")
    for (a, sh_) in [("internvl2-76b", "train_4k"), ("mixtral-8x22b", "train_4k"),
                     ("qwen3-8b", "decode_32k"), ("mamba2-2.7b", "long_500k")]:
        ds, dm = single.get((a, sh_)), multi.get((a, sh_))
        if not ds or not dm:
            continue
        w(f"| {a} | {sh_} | {ds['memory']['per_device_gib']:.1f} | "
          f"{dm['memory']['per_device_gib']:.1f} | "
          f"{ds['roofline']['collective_s']:.2e} | {dm['roofline']['collective_s']:.2e} |")
    w("")
    w("Doubling to two pods halves the per-device batch slice, and with it "
      "both the activation footprint AND the per-device activation-collective "
      "volume (both track the local batch) — clean weak scaling. The cost "
      "that does NOT shrink is the gradient all-reduce (per-device grads are "
      "batch-independent) which now crosses the slowest inter-pod links; "
      "that is the term the int8/top-k error-feedback gradient compression "
      "(optim/compress.py; convergence-tested) is built to cut (2x / ~20x "
      "wire bytes).\n")

    # --------------------------------------------------------------- roofline
    w("## §Roofline (single-pod, per device, seconds per step)\n")
    w("| arch | shape | compute | memory | collective | dominant | kmem_s | useful | note |")
    w("|---|---|---|---|---|---|---|---|---|")
    for (a, s), d in single.items():
        rt = d["roofline"]
        ka = analytic_kernel_bytes(a, s, d["n_devices"]) / HBM_BW
        kind = ("train" if s.startswith("train") else
                "prefill" if s.startswith("prefill") else "decode")
        note = MOVE_NOTES.get((rt["dominant"], kind), "")
        w(f"| {a} | {s} | {fmt_e(rt['compute_s'])} | {fmt_e(rt['memory_s'])} | "
          f"{fmt_e(rt['collective_s'])} | {rt['dominant']} | {fmt_e(ka)} | "
          f"{d['model_flops']['useful_ratio']:.3f} | {note} |")
    w("")
    w("Observations:")
    w("- Every cell is **memory-term dominated** on the XLA path — consistent "
      "with the paper's premise that this workload is bandwidth-bound, and "
      "with the known cost of non-fused attention (the probability matrices "
      "round-trip HBM). The `kmem_s` column is the same step under the "
      "validated Pallas kernels: 1-3 orders of magnitude lower, putting most "
      "cells at compute- or weight-stream-bound, i.e. at roofline.")
    w("- Roofline fraction (compute_s / dominant term): best train cells reach "
      "~0.25-0.41 on the pure-XLA path (gemma2-27b 0.23, internvl2 0.27, "
      "mixtral 0.27 post-fix); against `kmem_s` the same cells are "
      "compute-bound (fraction ~1.0), which is the relevant target for the "
      "kernelized deployment.")
    w("- MODEL_FLOPS/HLO ratio `useful` reflects remat (~0.75 ceiling at full "
      "recompute), MoE capacity factor, and attention not counted in 6ND; "
      "decode-cell values are small by construction (2*N_active*B vs per-step "
      "overheads).\n")

    md = "\n".join(L)
    (ROOT / "EXPERIMENTS.md").write_text(md + PERF + "\n")
    print(f"wrote EXPERIMENTS.md ({len(md) + len(PERF)} chars)")


PERF = """
## §Perf — hillclimbing log (3 cells)

Method per the assignment: baseline all 34 single-pod cells (table above),
pick the three most interesting, then hypothesis -> change -> re-lower ->
re-analyse, recording confirmations AND refutations. Variants live under
`artifacts/dryrun_variants/`; the pre-fix baselines under `artifacts/dryrun_v0/`.

Cells chosen: **mixtral-8x22b x train_4k** (worst useful-FLOPs ratio 0.029 +
most collective-bound), **deepseek-v2-lite-16b x decode_32k** (most
representative of the paper: the decode read stream over compressed KV), and
**internvl2-76b x train_4k** (largest dense model; worst memory footprint,
322 GiB/device).

### Cell 1: mixtral-8x22b x train_4k  (paper-faithful baseline -> beyond)

| iteration | hypothesis | change | compute_s | memory_s | collective_s (operand) | wire GB | GiB/dev | useful |
|---|---|---|---|---|---|---|---|---|
| v0 baseline | — | — | 170.4 | 885.6 | 303.5 | 2799* | 279 | 0.029 |
| 1 | useful=0.029 means ~34x redundant compute; suspect MoE dispatch sharding | **found**: group scan iterated a token-derived axis whose batch sharding GSPMD must replicate -> every data rank computed ALL groups (16x), and expert weights were all-gathered. Regrouped along the sequence dim with batch as a sharded batched dim (`models/moe.py`) | **11.9 (14.3x)** | **43.4 (20x)** | **30.2 (10x)** | 2799 | 265 | **0.412** |
| 2 | activation TP all-reduces (1.5 TB/dev) halve under Megatron-style sequence-parallel residuals | `--rules sp` (residual stream seq-sharded between blocks) | 9.2 | 38.1 | 47.4 | 4699 | **157** | 0.533 |
| 3 | footprint: shard weights 2D + opt over data | `--rules sp --fsdp` (+q_chunk=2048) | 9.2 | 40.4 | 50.1 | 5274 | **27.8** | 0.533 |

*v0 wire shown at iteration-1 scale for comparability (v0 artifact records 2799 GB post-fix equivalent).

- It. 1 **confirmed**, and is the headline: a real 14-20x systems bug found
  purely from the roofline's useful-FLOPs diagnostic. It generalized to
  deepseek-v2-lite (useful 0.062 -> 0.525).
- It. 2 **partially refuted**: the memory *footprint* halved as predicted
  (265->157 GiB) and memory traffic fell ~12%, but the collective term
  *rose* — GSPMD Auto-mode resharding between the seq-sharded residual and
  the head-sharded attention inserts replicate-then-repartition copies (XLA
  warns `[SPMD] Involuntary full rematerialization`). Lesson recorded: with
  Auto axes, SP needs manual shard_map (or Shardy) to realize its collective
  win; we keep SP for its memory win.
- It. 3 **confirmed** for capacity: 265 -> 27.8 GiB/device (9.5x), at ~flat
  roofline terms (FSDP gathers are overlapped weight streams). Net vs v0
  paper-faithful baseline: dominant bound 885.6s -> 40.4s (**21.9x**).

### Cell 2: deepseek-v2-lite-16b x decode_32k  (the paper's decode read stream)

| iteration | hypothesis | change | compute_s | memory_s | collective_s | GiB/dev | fits |
|---|---|---|---|---|---|---|---|
| baseline | — | naive MLA decode (expand latents to per-head K/V each step) | 9.52e-3 | 3.36e-1 | 1.07e-4 | 33.4 | N |
| 1 | expansion flops/bytes dominate; absorb W_UK into q and W_UV into out -> attention runs over the compressed cache | `--set mla_absorb=true` | **1.60e-4 (60x)** | 2.09e-1 | 2.05e-2 | 16.2 | N |
| 2 | byte breakdown showed 96/171 GB/dev was GSPMD all-gathering the cache every layer: a dynamic_update_slice at a traced index on the (newly) seq-sharded cache dim forces gather+reshard; a masked elementwise write stays shard-local | masked-write cache append (`models/attention.py`, `models/mla.py`) + consistent `act_kv_seq` constraint on the MLA cache | 1.6e-4 | **5.33e-2 (3.9x)** | 2.6e-4 | 7.4 | **Y** |
| 3 | weights (31 GB bf16 over 16-way TP) dominate the remaining footprint; 2D-shard them | `--fsdp` | 1.6e-4 | **3.93e-2** | 5.4e-3 | **2.34** | **Y** |

- Net: memory term 0.336 -> 0.039 s (**8.6x**), compute 60x, footprint
  33.4 -> 2.34 GiB. The masked-write fix from it. 2 was landed framework-wide
  and re-baselining every decode/long cell improved or matched all 14 of
  them (e.g. qwen3 decode 0.118 -> 0.094 s); old baselines preserved in
  `artifacts/dryrun_v0/`.
- This is the paper's §2.2 workload made quantitative: post-optimization the
  decode step is bound by exactly (weights + compressed-KV) sequential
  reads — the stream MRM is designed to serve.

### Cell 3: internvl2-76b x train_4k  (largest dense train)

| iteration | hypothesis | change | compute_s | memory_s | collective_s | GiB/dev |
|---|---|---|---|---|---|---|
| baseline | — | TP(16) x DP(16), full remat | 11.6 | 43.6 | 28.5 | 321.7 |
| 1 | 80 saved layer-inputs (1 GiB each) dominate; seq-shard the residual stream | `--rules sp` | 11.5 | 33.9 | 33.4 | **111.6** |
| 2 | optimizer m/v (35 GiB fp32) next; ZeRO-1 over data | `--rules sp --zero1` | 11.5 | 33.7 | 33.3 | 57.6 |
| 3 | params+grads (17.5 GiB) next; 2D weight sharding | `--rules sp --fsdp` | 11.5 | 33.9 | 33.4 | **55.4** |
| 4 | fewer q-chunks shrink flash-bwd dq buffers | `--set q_chunk=2048` | 11.6 | 33.6 | 31.5 | 56.0 (**refuted**, no change) |

- Net: 321.7 -> 55.4 GiB/device (**5.8x**) at slightly better terms. The
  remaining gap to 16 GiB needs gradient-accumulation microbatching
  (enumerated, not implemented) — recorded as the next lever.
- It. 4 is a kept refutation: the dq/partial buffers were not the residual
  footprint driver; the napkin math over-attributed them.

### Paper-faithful vs beyond-paper summary

The paper's technique (MRM tiering/DCM/refresh) is orthogonal to these
compute-graph optimizations, so the *paper-faithful baseline* here is the
pre-hillclimb framework (v0 artifacts) running the faithful MRM control
plane — all §Paper-validation results hold identically before and after.
The beyond-paper work is everything in this section plus the Pallas
kernels: on the kernel-adjusted roofline (`kmem_s`), the hillclimbed cells
sit at their weight/KV-stream bound, i.e. the memory system — not compute —
is the binding constraint, which is precisely the regime the paper argues
MRM should serve.

### Roofline-fraction scorecard (the §Perf headline)

For a memory-bandwidth-bound workload (which this paper argues LLM
inference fundamentally is), "fraction of roofline" must be read against
the *binding* resource. We report both views per hillclimbed cell, XLA
path, production mesh:

| cell | metric | v0 baseline | final optimized | gain |
|---|---|---|---|---|
| mixtral train_4k | compute-roofline fraction (compute_s / dominant) | 0.19 (170.4/885.6) | **0.27** (11.9/43.4 landed default; 0.39 on the kernel-adjusted bound; the 27.8 GiB footprint variant trades back to 0.18) | bound 885.6s -> 43.4s, **20.4x** |
| internvl2 train_4k | compute-roofline fraction | 0.27 (11.6/43.6) | **0.34** (11.5/33.6) | bound 43.6 -> 33.6s, 1.3x + 5.8x footprint |
| deepseek-v2-lite decode_32k | memory-stream efficiency (useful weight+KV bytes / HLO bytes) | 0.008 | **0.07 XLA-path**; the Pallas decode kernel serves the remaining gap (score blocks in VMEM), putting the step at its weight+KV stream bound — the regime MRM serves | memory term 0.336 -> 0.039s, **8.6x** |

Train cells on the kernel-adjusted memory bound are compute/collective
bound at 0.27-0.39 of the 197 TFLOP/s roofline with full-remat training
(remat alone caps useful at 0.75); decode cells are *correctly*
memory-bound — per the paper, that is the design point, and the per-token
read stream after optimization is within ~2x of the raw weight+KV bytes.

### Stopping criterion

Per the method, we stopped each cell after <5% movement on the dominant
term across consecutive changes (cell 1 it.3, cell 2 it.3, cell 3 it.4).

### Ablations (single knobs on the hillclimbed cells)

| cell | knob | compute_s | memory_s | collective_s | GiB/dev | reading |
|---|---|---|---|---|---|---|
| internvl2 train | remat=full (default) | 11.6 | 43.6 | 28.5 | 322 | baseline |
| internvl2 train | remat=dots | 9.4 | 48.1 | 25.1 | **759** | recompute saved (-19% compute) but dot outputs stored — memory-infeasible at 76B |
| internvl2 train | remat=none | 9.4 | 49.8 | 25.1 | **1656** | full activation storage: 5.1x the footprint; full remat is mandatory at this scale |
| mixtral train | capacity_factor=1.25 (default) | 11.9 | 43.4 | 30.2 | 265 | baseline |
| mixtral train | capacity_factor=1.0 | 9.8 | 40.0 | 26.6 | 265 | all three terms scale ~linearly with cf (-18% compute); a quality/perf knob |
| mixtral train | capacity_factor=2.0 | 15.4 | 55.0 | 43.8 | 265 | +30-45% across terms — dropless-style slack is expensive in dense dispatch |

## §Beyond-paper features (in addition to the §Perf optimizations)

- **Automatic prefix caching** over MRM pages (the paper cites vLLM's [53]):
  sealed page-aligned prompt prefixes are shared across sessions with
  refcounts + an eviction hook; repeated prompts cost zero KV writes (tested:
  >5x write reduction on a repeated 200-token prompt, identical outputs).
  On MRM this also directly buys *endurance*: shared prefixes are written
  once and read many times — the exact asymmetry the memory class exploits.
- **Model-redeploy wear accounting**: `ServeEngine.redeploy_weights()`
  rewrites the weight region through the wear-levelling allocator; tests
  confirm the Fig.-1 arithmetic from the running system (5 redeploys = 5
  region rewrites, spread with wear ratio < 3, projected lifetime at hourly
  cadence > 5 years on MRM-RRAM).
- **Memory-efficient flash attention custom-VJP** (O(block) backward
  residuals), **gradient compression with error feedback** (int8 + top-k;
  convergence-tested), **elastic re-mesh planning + straggler eviction +
  resharding checkpoint restore** (tested end-to-end via failure injection
  in the train driver), and the **Pallas kernels** (flash prefill, paged
  decode, SSD scan) validated against independent oracles across
  shape/dtype/feature sweeps.
"""


if __name__ == "__main__":
    main()
