#!/usr/bin/env python
"""Docs consistency gate (CI `docs` job).

Five checks, all cheap and dependency-free:

1. **README file references** — every path-looking token in README.md
   (backticked or inside fenced code blocks, containing a `/` or a known
   source suffix) must exist in the repo. Catches entry points that moved
   or were renamed after the docs were written.
2. **README CLI flags** — every `--flag` README mentions must be defined
   somewhere under `src/repro/launch/`, `benchmarks/` or `experiments/`
   (argparse
   definitions are greppable as string literals). Catches documented
   flags that were dropped or renamed.
3. **DESIGN.md section cross-references** — every explicit DESIGN.md
   section reference anywhere in the repo (docs, source, tests) must
   resolve to a matching section heading in DESIGN.md. Bare paper
   references like (2.2) and single-letter placeholders are out of
   scope (they cite the source paper / are documentation meta-text).
4. **DESIGN.md CLI flags** — same rule as (2) for DESIGN.md: flags the
   architecture doc cites (e.g. the §11 `--inject-rber` contract) must
   still be defined.
5. **README DESIGN-map completeness** — the README's "Where to read
   next" map must carry a row for every `## §` heading DESIGN.md
   actually has, so new sections cannot land undocumented on the
   front page.

Run:  python scripts/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SRC_SUFFIXES = (".py", ".sh", ".md", ".toml", ".txt", ".yml", ".json")


def fail(errors: list) -> None:
    if errors:
        for e in errors:
            print(f"DOCS ERROR: {e}")
        sys.exit(1)


def _candidate_paths(text: str):
    """Path-looking tokens from backticks and fenced code blocks."""
    tokens = set(re.findall(r"`([^`\n]+)`", text))
    for block in re.findall(r"```(?:\w*\n)?(.*?)```", text, re.S):
        tokens.update(block.split())
    for tok in tokens:
        tok = tok.strip().rstrip(",.;:")
        if tok.startswith(("--", "-m", "http")) or "=" in tok or "$" in tok:
            continue
        if "/" in tok or tok.endswith(SRC_SUFFIXES):
            # strip trailing qualifiers like `file.py::func` or `§N`
            tok = tok.split("::")[0].split(" ")[0]
            if re.fullmatch(r"[\w./-]+", tok) and "." in tok.split("/")[-1]:
                yield tok


def check_readme_paths(errors: list) -> None:
    text = (ROOT / "README.md").read_text()
    for tok in sorted(set(_candidate_paths(text))):
        if tok.startswith("/"):           # absolute output paths (/tmp/...)
            continue
        if not (ROOT / tok).exists():
            errors.append(f"README.md references missing file: {tok}")


def _defined_flags() -> set:
    defined = set()
    for path in list((ROOT / "src" / "repro" / "launch").glob("*.py")) \
            + list((ROOT / "benchmarks").glob("*.py")) \
            + list((ROOT / "experiments").glob("*.py")):
        defined.update(re.findall(r"add_argument\(\s*\"(--[a-z0-9-]+)\"",
                                  path.read_text()))
    return defined


def _check_doc_flags(doc: str, errors: list) -> None:
    text = (ROOT / doc).read_text()
    flags = set(re.findall(r"(--[a-z][a-z0-9-]+)", text))
    defined = _defined_flags()
    for flag in sorted(flags - defined):
        if flag in ("--json", "--help"):  # runner/argparse built-ins
            defined_runner = any(
                flag in p.read_text() for p in (ROOT / "benchmarks").glob("*.py"))
            if flag == "--help" or defined_runner:
                continue
        errors.append(f"{doc} documents unknown CLI flag: {flag}")


def check_readme_flags(errors: list) -> None:
    _check_doc_flags("README.md", errors)


def check_design_flags(errors: list) -> None:
    _check_doc_flags("DESIGN.md", errors)


def check_readme_design_map(errors: list) -> None:
    design = (ROOT / "DESIGN.md").read_text()
    readme = (ROOT / "README.md").read_text()
    for heading in re.findall(r"^## (§[\w-]+)", design, re.M):
        if not re.search(rf"^\|\s*{re.escape(heading)}\s*\|", readme, re.M):
            errors.append(f"README.md DESIGN map has no row for DESIGN.md "
                          f"heading '{heading}'")


def check_design_sections(errors: list) -> None:
    design = (ROOT / "DESIGN.md").read_text()
    headings = set(re.findall(r"^## (§[\w-]+)", design, re.M))
    if not headings:
        errors.append("DESIGN.md has no '## §' headings at all")
    # numbered sections (§6) or named sections (§Arch-applicability);
    # single capital letters (§N, §X) are placeholder meta-text, skipped
    ref_re = re.compile(r"DESIGN\.md\s+(§(?:\d+|[A-Z][\w-]+))")
    refs = []
    for path in ROOT.rglob("*"):
        if path.suffix not in (".py", ".md", ".sh") or not path.is_file():
            continue
        if any(part.startswith(".") for part in path.parts):
            continue
        for m in ref_re.finditer(path.read_text()):
            refs.append((path.relative_to(ROOT), m.group(1)))
    for where, ref in refs:
        if ref not in headings:
            errors.append(f"{where}: reference '{ref}' has no matching "
                          f"'## {ref}' heading in DESIGN.md "
                          f"(headings: {sorted(headings)})")


def main() -> None:
    errors: list = []
    check_readme_paths(errors)
    check_readme_flags(errors)
    check_design_sections(errors)
    check_design_flags(errors)
    check_readme_design_map(errors)
    fail(errors)
    print("docs OK: README file/flag references, DESIGN.md § "
          "cross-references/flags, and the README DESIGN map all resolve")


if __name__ == "__main__":
    main()
