"""End-to-end training driver example: train a ~language model for a few
hundred steps with checkpointing, then resume — exercising the data
pipeline, sharded AdamW, chunked-CE loss, remat, and the FT control loop.

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
(Defaults are sized for this CPU container; on a TPU pod drop --reduced and
raise --batch/--seq-len.)
"""
import argparse
import sys
sys.path.insert(0, "src")

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm")
    args = ap.parse_args()

    history = train_mod.main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--seq-len", "128", "--batch", "8",
        "--lr", "1e-3", "--warmup", "40",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", "20",
    ])
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss: {first:.3f} -> {last:.3f} over {args.steps} steps")
    assert last < first, "training failed to reduce loss"

    print("\n-- resuming from the checkpoint for 20 more steps --")
    train_mod.main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps + 20),
        "--seq-len", "128", "--batch", "8",
        "--ckpt-dir", args.ckpt_dir, "--resume", "--log-every", "10",
    ])


if __name__ == "__main__":
    main()
