"""Serve a small model with batched requests, with weights + paged KV cache
living on MRM — the paper's deployment, end to end:

- continuous batching over fixed decode slots (real token generation);
- chunked prefill: prompts enter in 32-token pieces interleaved with
  decode rounds (bounded inter-token latency for resident sessions);
- weights written once to the MRM weight region, read wholesale per pass;
- KV pages allocated with DCM retention programmed from session lifetime,
  capacity pressure resolved by prefix-LRU eviction (never silent drops);
- radix prefix reuse: the requests share a 32-token head, so later
  admissions attach the shared pages AND skip their prefill compute
  (DESIGN.md §6, §8);
- the retention tracker refreshes live pages and drops closed sessions;
- the report shows the measured read:write ratio, sequentiality, energy.

Run:  PYTHONPATH=src python examples/serve_batched_mrm.py
"""
import sys
sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.memclass import HBM3E, MRM_RRAM
from repro.core.simulator import MemorySystem
from repro.models import init_params
from repro.serving import EngineConfig, ServeEngine

FULL = get_config("gemma2-27b")      # accounting scale (deployment)
cfg = reduced(FULL)                  # compute scale (this container)
params = init_params(cfg, jax.random.key(0))

mem = MemorySystem({
    "mrm": (MRM_RRAM, 512 << 30),    # weights + KV pages
    "hbm": (HBM3E, 96 << 30),        # activations (write-heavy)
})
engine = ServeEngine(
    cfg, params, mem,
    EngineConfig(max_slots=4, max_cache_len=128, weight_tier="mrm",
                 kv_tier="mrm", page_tokens=16, expected_session_s=30.0,
                 eos_token=-1, chunk_tokens=32,
                 kv_pressure_policy="evict-lru"),
    account_cfg=FULL)

rng = np.random.default_rng(0)
print(f"serving {FULL.name}: weights {engine.weight_bytes/1e9:.0f} GB -> MRM, "
      f"KV {FULL.kv_bytes_per_token()/1024:.0f} KiB/token, paged x16 tokens, "
      f"chunked prefill x32")
shared_head = list(rng.integers(2, cfg.vocab_size, 32))  # system prompt
for i in range(8):
    prompt = shared_head + list(
        rng.integers(2, cfg.vocab_size, int(rng.integers(8, 28))))
    engine.submit(prompt, max_new_tokens=16)

rep = engine.run_until_idle()
mrm = rep["memory"]["tiers"]["mrm"]
print(f"\nfinished {rep['finished']} requests, {rep['tokens_generated']} tokens "
      f"({rep['prefill_chunks']} prefill chunks)")
print(f"  steady read:write ratio  {rep['steady_rw_ratio']:,.0f}:1   (paper: >1000:1)")
print(f"  sequential read fraction {mrm['seq_fraction']*100:.1f}%")
print(f"  energy per token         {rep['energy_per_token_j']*1e3:.2f} mJ")
print(f"  refresh events           {rep['memory']['refresh_stats']['refresh']}")
print(f"  pressure events          {rep['pressure']['events']} "
      f"(silent drops {rep['dropped_allocs']})")
print(f"  prefix hits              {rep['prefix_hits']} "
      f"({rep['prefix_tokens_reused']} KV tokens reused, "
      f"{rep['prefill_tokens_skipped']} prefill tokens skipped)")
print(f"  MRM wear (max writes)    {mrm['wear_max']:.0f}  "
      f"(ratio {mrm['wear_ratio']:.2f}, life used {mrm['life_used']:.2e})")
print(f"  ECC overhead             {mrm['ecc_overhead']*100:.2f}%")
assert rep["steady_rw_ratio"] > 1000
assert rep["dropped_allocs"] == 0
assert rep["prefix_hits"] >= 1          # the shared head was actually reused
