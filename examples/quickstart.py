"""Quickstart: the MRM memory class in 60 seconds.

1. Pick an architecture from the assigned pool and look at its inference
   memory-IO profile (the paper's §2 characterization).
2. Solve the retention-aware placement across HBM / MRM / LPDDR tiers.
3. Program one DCM write and watch the retention/energy/endurance trade.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import (DataClassProfile, Tier, plan_write, solve_placement)
from repro.core.memclass import HBM3E, HOUR, LPDDR5X, MRM_RRAM

ARCH = "qwen3-8b"

cfg = get_config(ARCH)
counts = cfg.param_counts()
kv_tok = cfg.kv_bytes_per_token()
print(f"== {ARCH}: {counts['total']/1e9:.1f}B params, "
      f"{kv_tok/1024:.1f} KiB of KV appended per generated token")

# --- 1. workload profile (decode reads everything, writes one vector) ------
decode_tps = 800.0
weights_bytes = counts["total"] * 2
classes = [
    DataClassProfile("weights", weights_bytes, decode_tps * weights_bytes / 32,
                     weights_bytes / (24 * HOUR), 24 * HOUR, soft_state=False),
    DataClassProfile("kv_cache", 64e9, decode_tps * 64e9 / 32,
                     decode_tps * kv_tok * 8, 600, soft_state=True),
    DataClassProfile("activations", 4e9, 0.3e12, 0.3e12, 0.01,
                     soft_state=True, random_access=True),
]
print(f"   decode read:write ratio ~ "
      f"{(weights_bytes + 64e9) / (kv_tok * 32):,.0f}:1  (paper §2.2: >1000:1)")

# --- 2. retention-aware placement ------------------------------------------
tiers = [Tier(HBM3E, 96e9, count=4), Tier(MRM_RRAM, 512e9, count=8),
         Tier(LPDDR5X, 256e9, count=2)]
res = solve_placement(classes, tiers)
print("== placement:", res.assignment)
print(f"   feasible={res.feasible}  memory power={res.energy_w:.0f} W  "
      f"capacity cost=${res.cost_usd:,.0f}")

# --- 3. DCM: program a write for a 10-minute KV page ------------------------
op = plan_write(MRM_RRAM, expected_lifetime_s=600)
nominal = plan_write(MRM_RRAM, expected_lifetime_s=MRM_RRAM.retention_s)
print(f"== DCM write @10min lifetime: retention={op.retention_s/3600:.2f} h, "
      f"energy {op.energy_pj_bit:.2f} pJ/bit (nominal {nominal.energy_pj_bit:.2f}), "
      f"endurance {op.endurance_at_point:.1e} (device nominal "
      f"{MRM_RRAM.endurance_device:.1e})")
