"""Quickstart: the MRM memory class in 60 seconds.

1. Pick an architecture from the assigned pool and look at its inference
   memory-IO profile (the paper's §2 characterization).
2. Solve the retention-aware placement across HBM / MRM / LPDDR tiers.
3. Program one DCM write and watch the retention/energy/endurance trade.
4. Serve a few real requests through the full stack — radix prefix reuse
   cuts the second identical prompt's prefill in both planes
   (DESIGN.md §6, §8).
5. Age a KV page past its retention deadline and watch the reliability
   plane correct it: scrub-on-read metered as refresh + check bits, the
   retention clock re-armed (DESIGN.md §11).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import (DataClassProfile, Tier, plan_write, solve_placement)
from repro.core.memclass import HBM3E, HOUR, LPDDR5X, MRM_RRAM

ARCH = "qwen3-8b"

cfg = get_config(ARCH)
counts = cfg.param_counts()
kv_tok = cfg.kv_bytes_per_token()
print(f"== {ARCH}: {counts['total']/1e9:.1f}B params, "
      f"{kv_tok/1024:.1f} KiB of KV appended per generated token")

# --- 1. workload profile (decode reads everything, writes one vector) ------
decode_tps = 800.0
weights_bytes = counts["total"] * 2
classes = [
    DataClassProfile("weights", weights_bytes, decode_tps * weights_bytes / 32,
                     weights_bytes / (24 * HOUR), 24 * HOUR, soft_state=False),
    DataClassProfile("kv_cache", 64e9, decode_tps * 64e9 / 32,
                     decode_tps * kv_tok * 8, 600, soft_state=True),
    DataClassProfile("activations", 4e9, 0.3e12, 0.3e12, 0.01,
                     soft_state=True, random_access=True),
]
print(f"   decode read:write ratio ~ "
      f"{(weights_bytes + 64e9) / (kv_tok * 32):,.0f}:1  (paper §2.2: >1000:1)")

# --- 2. retention-aware placement ------------------------------------------
tiers = [Tier(HBM3E, 96e9, count=4), Tier(MRM_RRAM, 512e9, count=8),
         Tier(LPDDR5X, 256e9, count=2)]
res = solve_placement(classes, tiers)
print("== placement:", res.assignment)
print(f"   feasible={res.feasible}  memory power={res.energy_w:.0f} W  "
      f"capacity cost=${res.cost_usd:,.0f}")

# --- 3. DCM: program a write for a 10-minute KV page ------------------------
op = plan_write(MRM_RRAM, expected_lifetime_s=600)
nominal = plan_write(MRM_RRAM, expected_lifetime_s=MRM_RRAM.retention_s)
print(f"== DCM write @10min lifetime: retention={op.retention_s/3600:.2f} h, "
      f"energy {op.energy_pj_bit:.2f} pJ/bit (nominal {nominal.energy_pj_bit:.2f}), "
      f"endurance {op.endurance_at_point:.1e} (device nominal "
      f"{MRM_RRAM.endurance_device:.1e})")

# --- 4. serve through the full stack: prefix reuse is real -------------------
import jax
import numpy as np

from repro.configs import reduced
from repro.core.simulator import MemorySystem
from repro.models import init_params
from repro.serving import EngineConfig, ServeEngine

small = reduced(cfg)                     # compute scale (this container)
params = init_params(small, jax.random.key(0))
mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
engine = ServeEngine(
    small, params, mem,
    EngineConfig(max_slots=2, max_cache_len=96, weight_tier="hbm",
                 kv_tier="mrm", page_tokens=16, chunk_tokens=16,
                 eos_token=-1, kv_pressure_policy="evict-lru"),
    account_cfg=cfg)                     # accounting scale (deployment)
rng = np.random.default_rng(0)
prompt = list(rng.integers(2, small.vocab_size, 40))
for _ in range(2):                       # identical prompts: the 2nd hits
    engine.submit(list(prompt), max_new_tokens=8)
    engine.run_until_idle()
rep = engine.report()
print(f"== served 2x the same 40-token prompt: "
      f"prefix hits {rep['prefix_hits']}, "
      f"prefill tokens skipped {rep['prefill_tokens_skipped']}, "
      f"KV tokens reused {rep['prefix_tokens_reused']}")
assert rep["prefix_hits"] >= 1
assert rep["prefill_tokens_skipped"] > 0

# --- 5. reliability plane: age a page, scrub it back (DESIGN.md §11) --------
mem_r = MemorySystem({"mrm": (MRM_RRAM, 64 << 30)}, ecc_profile="domain")
rid = mem_r.write_region("mrm", "kv:demo", 1 << 20, expected_lifetime_s=600)
region = mem_r.region(rid)
dev = mem_r.devices["mrm"]
print(f"== ECC (domain profile): a 1 MiB KV page at 10-min retention "
      f"carries {dev.stats.ecc_write_bytes:,.0f} check-bit bytes "
      f"({dev.ecc.overhead_for('kv', region.retention_s):.2%} overhead)")
mem_r.advance(0.8 * region.retention_s / mem_r.tracker.margin)  # near deadline
scrubbed = mem_r.scrub_region(rid)
print(f"== scrub-on-read near the deadline: corrected in place, metered as "
      f"refresh ({dev.stats.refresh_bytes:,.0f} B) + scrub reads "
      f"({dev.stats.scrub_read_bytes:,.0f} B incl. check bits), wear "
      f"{dev.wear.scrub_rewrites} block rewrites; retention clock re-armed "
      f"(next deadline {region.written_at + region.retention_s:.0f}s)")
assert scrubbed
assert dev.stats.ecc_write_bytes > 0 and dev.stats.scrub_read_bytes > 0
assert region.written_at == mem_r.now  # the scrub re-armed the clock
