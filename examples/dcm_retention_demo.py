"""Dynamically Configurable Memory (paper §4) demo: the same RRAM cells
serve hour-lived KV pages and day-lived weights at different write energies,
while the cluster-level refresh scheduler keeps everything alive exactly as
long as needed — and not longer.

Run:  PYTHONPATH=src python examples/dcm_retention_demo.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import MemorySystem, plan_write
from repro.core.memclass import DAY, HOUR, MRM_RRAM

print("DCM write-energy vs programmed retention (MRM-RRAM):")
for life, label in [(10.0, "10 s  (speculative draft)"),
                    (600.0, "10 min (chat session KV)"),
                    (HOUR, "1 h   (long doc session)"),
                    (DAY, "1 day (weights, daily redeploy)")]:
    op = plan_write(MRM_RRAM, life)
    print(f"  {label:<28} retention={op.retention_s/3600:7.2f} h  "
          f"energy={op.energy_pj_bit:5.2f} pJ/bit  "
          f"endurance={op.endurance_at_point:.1e}")

print("\nCluster control plane over one simulated hour:")
ms = MemorySystem({"mrm": (MRM_RRAM, 8 << 30)})
weights = ms.write_region("mrm", "weights", 4e9, expected_lifetime_s=DAY)
sessions = [ms.write_region("mrm", f"session:{i}", 64e6,
                            expected_lifetime_s=600) for i in range(4)]
for minute in range(60):
    ms.advance(60.0)
    for rid in sessions:
        ms.read_region(rid)          # active sessions keep reading
    ms.read_region(weights)
    if minute == 20:                  # two sessions end at t=20min
        for rid in sessions[:2]:
            ms.release_region(rid)
        sessions = sessions[2:]
        print("  t=20min: released 2 sessions (soft state dropped, no refresh)")
rep = ms.report()
print(f"  refreshes: {rep['refresh_stats']['refresh']} "
      f"({rep['refresh_stats']['refresh_bytes']/1e6:.0f} MB rewritten)")
print(f"  drops/migrates: {rep['refresh_stats']['drop']}/"
      f"{rep['refresh_stats']['migrate']}")
print(f"  MRM energy: {rep['total_energy_j']:.2f} J over {rep['now_s']/60:.0f} min")
