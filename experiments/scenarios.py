"""Scenario zoo for the event-driven fleet simulator (DESIGN.md §12).

Each scenario family is a config dataclass with a ``generate(rng)``
method yielding symbolic :class:`~repro.serving.fleet_sim.FleetRequest`
streams, plus a ``fleet()`` method building the matching
:class:`~repro.serving.fleet_sim.FleetConfig`. The scenario owns ALL
randomness (one seeded ``random.Random``); the simulator itself is
deterministic, so ``(scenario, seed)`` fixes the full event trace — the
contract the determinism harness and the CI trace-hash gate rely on.

The families map to the traffic structures the paper argues MRM can
exploit (PAPER.md §4, "Towards Memory Specialization" in PAPERS.md):

- **bursty** — open-loop Poisson arrivals with burst multipliers: reuse
  windows under load spikes. The ``scale`` preset is the acceptance run
  (≥ 64 replicas, ≥ 100k queued sessions to quiescence).
- **diurnal** — multi-tenant sinusoidal rate over simulated hours; the
  lull is where retention decay either saves energy or evicts tomorrow's
  prefixes.
- **agentic** — tool-call loops re-entering with *grown* prefixes: the
  registered group extends page by page, the re-entry always hits.
- **rag_storm** — fan-out bursts over one fresh document context:
  directory registration races, migration storms, link serialization.
- **long_doc** — few sessions, huge shared contexts: capacity pressure,
  evict/spill/recompute chain, cold-tier reads in the decode path.
- **abandonment** — offered load beyond fleet capacity with impatient
  users: queued sessions time out and must never leak state.

``SCENARIOS`` maps family name -> config class; every class has
``presets()`` with at least ``smoke`` (CI-feasible) and ``default``.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, replace
from typing import Dict, Iterator

from repro.serving.fleet_sim import FleetConfig, FleetRequest


@dataclass(frozen=True)
class ScenarioBase:
    n_replicas: int = 8
    slots_per_replica: int = 16
    sessions: int = 5_000
    seed: int = 0

    def fleet(self) -> FleetConfig:
        return FleetConfig(n_replicas=self.n_replicas,
                           slots_per_replica=self.slots_per_replica)

    def generate(self, rng: random.Random) -> Iterator[FleetRequest]:
        raise NotImplementedError

    def submit_all(self, sim, rng: random.Random) -> None:
        """Feed the scenario into a :class:`FleetSim`. The default is
        open-loop: every arrival is pre-scheduled by ``generate``.
        Closed-loop families override this to chain follow-ups off
        completion times (``FleetSim.chain``) — the RNG draw order must
        stay independent of execution order (pre-draw all think times)
        so the trace-digest determinism contract holds."""
        for req in self.generate(rng):
            sim.submit(req)

    @classmethod
    def presets(cls) -> Dict[str, "ScenarioBase"]:
        raise NotImplementedError


@dataclass(frozen=True)
class Bursty(ScenarioBase):
    """Open-loop Poisson arrivals with square-wave burst multipliers."""
    rate_per_s: float = 2000.0
    burst_multiplier: float = 4.0
    burst_every_s: float = 5.0
    burst_len_s: float = 1.0
    groups: int = 200
    shared_tokens: int = 512
    unique_tokens: int = 64
    max_new_tokens: int = 8
    abandon_after_s: float = 120.0

    def generate(self, rng: random.Random) -> Iterator[FleetRequest]:
        t = 0.0
        for i in range(self.sessions):
            in_burst = (t % self.burst_every_s) < self.burst_len_s
            rate = self.rate_per_s * (self.burst_multiplier if in_burst
                                      else 1.0)
            t += rng.expovariate(rate)
            yield FleetRequest(
                session_key=i, group=rng.randrange(self.groups),
                shared_tokens=self.shared_tokens,
                unique_tokens=self.unique_tokens,
                max_new_tokens=self.max_new_tokens, arrival_s=t,
                abandon_after_s=self.abandon_after_s)

    @classmethod
    def presets(cls) -> Dict[str, "Bursty"]:
        smoke = cls(n_replicas=8, sessions=4_000, rate_per_s=1500.0)
        return {
            "smoke": smoke,
            "default": cls(n_replicas=16, sessions=50_000,
                           rate_per_s=4000.0, groups=400),
            # the acceptance run: >= 64 replicas, >= 100k queued sessions
            "scale": cls(n_replicas=64, slots_per_replica=32,
                         sessions=100_000, rate_per_s=20_000.0, groups=800),
        }


@dataclass(frozen=True)
class Diurnal(ScenarioBase):
    """Multi-tenant sinusoidal arrival rate over simulated hours: tenants
    share prefix pools; the trough spans the retention window, so decayed
    prefixes must be recomputed at the next peak."""
    peak_rate_per_s: float = 800.0
    trough_frac: float = 0.1
    period_s: float = 600.0         # compressed "day"
    tenants: int = 8
    groups_per_tenant: int = 25
    shared_tokens: int = 512
    unique_tokens: int = 96
    max_new_tokens: int = 8

    def fleet(self) -> FleetConfig:
        # a cold TTL shorter than the trough: the lull decays idle tenants
        return replace(super().fleet(), cold_ttl_s=self.period_s / 4)

    def generate(self, rng: random.Random) -> Iterator[FleetRequest]:
        t = 0.0
        for i in range(self.sessions):
            phase = 0.5 * (1 - math.cos(2 * math.pi * t / self.period_s))
            rate = self.peak_rate_per_s * (
                self.trough_frac + (1 - self.trough_frac) * phase)
            t += rng.expovariate(max(rate, 1e-6))
            tenant = rng.randrange(self.tenants)
            yield FleetRequest(
                session_key=i,
                group=tenant * self.groups_per_tenant
                + rng.randrange(self.groups_per_tenant),
                shared_tokens=self.shared_tokens,
                unique_tokens=self.unique_tokens,
                max_new_tokens=self.max_new_tokens, arrival_s=t,
                tenant=f"tenant{tenant}")

    @classmethod
    def presets(cls) -> Dict[str, "Diurnal"]:
        return {
            "smoke": cls(n_replicas=8, sessions=4_000, period_s=120.0,
                         peak_rate_per_s=600.0),
            "default": cls(n_replicas=16, sessions=40_000),
        }


@dataclass(frozen=True)
class Agentic(ScenarioBase):
    """Tool-call loops: each agent re-enters ``calls_per_agent`` times,
    its scratchpad prefix growing by ``growth_tokens`` per round — the
    registered prefix group extends, so every re-entry is a longest-match
    hit on pages the agent itself registered.

    ``closed_loop`` (the default) makes the loop real: call *k+1*
    arrives one think-time after call *k* **completes**
    (``FleetSim.chain``), so achieved latency shapes the arrival process
    — a slow fleet sees agents back off, a fast one sees them hammer.
    Think times are pre-drawn in generation order, so the RNG stream
    never depends on completion order and the trace digest stays
    bit-stable. ``closed_loop=False`` recovers the PR 9 open-loop
    pre-scheduled arrivals."""
    agents: int = 400
    calls_per_agent: int = 8
    base_shared_tokens: int = 256
    growth_tokens: int = 128
    think_time_s: float = 2.0
    unique_tokens: int = 32
    max_new_tokens: int = 16
    closed_loop: bool = True

    def fleet(self) -> FleetConfig:
        # sticky loops: don't migrate a scratchpad around the fleet
        return replace(super().fleet(), migrate_load_gap=16)

    def _agent_calls(self, rng: random.Random, agent: int, sid0: int):
        """One agent's call sequence with pre-drawn gaps — the exact RNG
        consumption of the PR 9 open-loop generator (uniform start, one
        expovariate per call), so both loop modes share a seed stream."""
        t = rng.uniform(0.0, 10.0)
        calls, gaps = [], []
        for call in range(self.calls_per_agent):
            calls.append(FleetRequest(
                session_key=sid0 + call, group=agent,
                shared_tokens=self.base_shared_tokens
                + call * self.growth_tokens,
                unique_tokens=self.unique_tokens,
                max_new_tokens=self.max_new_tokens, arrival_s=t))
            gap = rng.expovariate(1.0 / self.think_time_s)
            gaps.append(gap)
            t += gap
        return calls, gaps

    def generate(self, rng: random.Random) -> Iterator[FleetRequest]:
        for a in range(self.agents):
            calls, _ = self._agent_calls(rng, a, a * self.calls_per_agent)
            yield from calls

    def submit_all(self, sim, rng: random.Random) -> None:
        if not self.closed_loop:
            super().submit_all(sim, rng)
            return
        for a in range(self.agents):
            calls, gaps = self._agent_calls(rng, a,
                                            a * self.calls_per_agent)
            sim.submit(calls[0])
            for k in range(1, len(calls)):
                sim.chain(calls[k - 1].session_key, calls[k], gaps[k - 1])

    @property
    def sessions_total(self) -> int:
        return self.agents * self.calls_per_agent

    @classmethod
    def presets(cls) -> Dict[str, "Agentic"]:
        return {
            "smoke": cls(n_replicas=8, agents=300, calls_per_agent=6),
            "default": cls(n_replicas=16, agents=2_000, calls_per_agent=10),
        }


@dataclass(frozen=True)
class RagStorm(ScenarioBase):
    """RAG fan-out: every storm shares one *fresh* document group. The
    document's first ``heralds`` queries trickle in (the leading edge a
    trending document always has), then ``fanout`` near-simultaneous
    requests land ``lead_s`` later — the reactive plane answers the burst
    with a pile-up on the one registered owner plus demand-migration
    bursts that serialize on the donor's up-link; the predictive plane
    (DESIGN §13) sees the herald hits cross the replication threshold and
    pre-places the document on warm owners before the burst arrives."""
    storms: int = 120
    fanout: int = 32
    heralds: int = 2
    herald_gap_s: float = 0.15
    lead_s: float = 0.4
    storm_gap_s: float = 0.5
    doc_tokens: int = 1024
    unique_tokens: int = 48
    max_new_tokens: int = 8

    def generate(self, rng: random.Random) -> Iterator[FleetRequest]:
        sid = 0
        t = 0.0
        for storm in range(self.storms):
            t += rng.expovariate(1.0 / self.storm_gap_s)
            for h in range(self.heralds):
                yield FleetRequest(
                    session_key=sid, group=storm,
                    shared_tokens=self.doc_tokens,
                    unique_tokens=self.unique_tokens,
                    max_new_tokens=self.max_new_tokens,
                    arrival_s=t + h * self.herald_gap_s)
                sid += 1
            burst = t + (self.heralds - 1) * self.herald_gap_s + self.lead_s
            for _ in range(self.fanout):
                yield FleetRequest(
                    session_key=sid, group=storm,
                    shared_tokens=self.doc_tokens,
                    unique_tokens=self.unique_tokens,
                    max_new_tokens=self.max_new_tokens,
                    arrival_s=burst + rng.uniform(0.0, 0.05))
                sid += 1

    @classmethod
    def presets(cls) -> Dict[str, "RagStorm"]:
        return {
            "smoke": cls(n_replicas=8, storms=60, fanout=24),
            "default": cls(n_replicas=16, storms=400, fanout=64),
        }


@dataclass(frozen=True)
class LongDoc(ScenarioBase):
    """Few sessions, huge shared contexts: registration overflows the
    warm tier, driving the evict -> spill-to-cold -> recompute pressure
    chain; matched cold groups read at cold-tier bandwidth in decode."""
    docs: int = 24
    readers_per_doc: int = 6
    doc_tokens: int = 32_768
    unique_tokens: int = 128
    max_new_tokens: int = 16
    reader_gap_s: float = 3.0

    def fleet(self) -> FleetConfig:
        # warm tier sized to hold only a fraction of the document set
        doc_bytes = self.doc_tokens * 131072
        return replace(super().fleet(),
                       warm_capacity_bytes=float(doc_bytes * self.docs // 3),
                       cold_capacity_bytes=float(doc_bytes * self.docs),
                       hot_capacity_bytes=192e9)

    def generate(self, rng: random.Random) -> Iterator[FleetRequest]:
        sid = 0
        for doc in range(self.docs):
            t = rng.uniform(0.0, 5.0)
            for _ in range(self.readers_per_doc):
                yield FleetRequest(
                    session_key=sid, group=doc,
                    shared_tokens=self.doc_tokens,
                    unique_tokens=self.unique_tokens,
                    max_new_tokens=self.max_new_tokens, arrival_s=t)
                sid += 1
                t += rng.expovariate(1.0 / self.reader_gap_s)

    @classmethod
    def presets(cls) -> Dict[str, "LongDoc"]:
        return {
            "smoke": cls(n_replicas=4, docs=12, readers_per_doc=4,
                         doc_tokens=16_384),
            "default": cls(n_replicas=8, docs=48, readers_per_doc=8),
        }


@dataclass(frozen=True)
class Abandonment(ScenarioBase):
    """Offered load beyond fleet capacity with impatient users: a large
    fraction of queued sessions times out before first token. The gate is
    structural — abandoned sessions leave zero pins, zero hot bytes, and
    the fleet still quiesces."""
    rate_per_s: float = 4000.0
    abandon_after_s: float = 0.5
    groups: int = 100
    shared_tokens: int = 512
    unique_tokens: int = 64
    max_new_tokens: int = 8

    def fleet(self) -> FleetConfig:
        return replace(super().fleet(), n_replicas=max(2, self.n_replicas))

    def generate(self, rng: random.Random) -> Iterator[FleetRequest]:
        t = 0.0
        for i in range(self.sessions):
            t += rng.expovariate(self.rate_per_s)
            yield FleetRequest(
                session_key=i, group=rng.randrange(self.groups),
                shared_tokens=self.shared_tokens,
                unique_tokens=self.unique_tokens,
                max_new_tokens=self.max_new_tokens, arrival_s=t,
                abandon_after_s=self.abandon_after_s)

    @classmethod
    def presets(cls) -> Dict[str, "Abandonment"]:
        return {
            "smoke": cls(n_replicas=4, sessions=4_000, rate_per_s=3000.0),
            "default": cls(n_replicas=8, sessions=40_000),
        }


SCENARIOS: Dict[str, type] = {
    "bursty": Bursty,
    "diurnal": Diurnal,
    "agentic": Agentic,
    "rag_storm": RagStorm,
    "long_doc": LongDoc,
    "abandonment": Abandonment,
}


def build(name: str, preset: str = "smoke") -> ScenarioBase:
    """Resolve ``(family, preset)`` to a scenario config."""
    try:
        cls = SCENARIOS[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"choose from {sorted(SCENARIOS)}") from None
    presets = cls.presets()
    try:
        return presets[preset]
    except KeyError:
        raise ValueError(f"scenario {name!r} has no preset {preset!r}; "
                         f"choose from {sorted(presets)}") from None
