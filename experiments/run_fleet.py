"""Run a fleet scenario on the event-driven simulator and gate its SLOs.

Usage (from the repo root)::

    PYTHONPATH=src python -m experiments.run_fleet --scenario bursty
    PYTHONPATH=src python -m experiments.run_fleet --scenario rag_storm \
        --preset default --seed 3 --json /tmp/fleet.json
    PYTHONPATH=src python -m experiments.run_fleet --all --preset smoke
    PYTHONPATH=src python -m experiments.run_fleet --list

Every run drains the scenario to quiescence (or fails loudly with
``NonQuiescentError``), checks the conservation invariants, enforces the
CI gates — TTFT/ITL p99 present over a non-empty finished population and
a zero pressure-ledger imbalance — and appends the result to
``BENCH_fleet.json`` at the repo root (deduplicated per scenario+preset;
the trajectory CI uploads as an artifact).
"""
from __future__ import annotations

import argparse
import json
import random
import sys
import time

from repro.core.trajectory import persist_trajectory
from repro.serving.fleet_sim import FleetSim

from experiments.scenarios import SCENARIOS, build

TRAJECTORY_FILE = "BENCH_fleet.json"


def run_scenario(name: str, preset: str = "smoke", seed: int = 0,
                 max_events: int = 20_000_000) -> dict:
    """Build, run and gate one scenario; returns the trajectory entry."""
    sc = build(name, preset)
    sim = FleetSim(sc.fleet())
    rng = random.Random(seed if seed else sc.seed)
    t0 = time.perf_counter()
    # submit_all lets closed-loop families chain follow-ups off
    # completion times; open-loop families pre-schedule every arrival
    sc.submit_all(sim, rng)
    report = sim.run(max_events=max_events)
    sim.check()
    wall = time.perf_counter() - t0
    entry = {
        "scenario": f"{name}/{preset}",
        "seed": seed if seed else sc.seed,
        "submitted": sim.stats["submitted"],
        "wall_s": round(wall, 3),
        "events_per_s": round(report["trace"]["n_events"] / max(wall, 1e-9)),
        **{k: report[k] for k in ("quiesced", "n_replicas", "sessions",
                                  "slo", "fleet", "replication", "directory",
                                  "fabric", "retention", "pressure",
                                  "trace")},
    }
    gate(entry)
    return entry


def gate(entry: dict) -> None:
    """The fleet-scenarios CI gates: the run must quiesce, report tail
    SLOs over a non-empty finished population, and balance the pressure
    ledger with nothing unresolved."""
    assert entry["quiesced"], f"{entry['scenario']}: did not quiesce"
    slo = entry["slo"]
    for metric in ("ttft", "itl"):
        assert slo[metric]["n"] > 0, \
            f"{entry['scenario']}: no finished sessions for {metric}"
        p99 = slo[metric]["p99"]
        assert p99 == p99 and p99 >= 0.0, \
            f"{entry['scenario']}: bad {metric} p99 {p99!r}"
    assert entry["pressure"]["ledger_imbalance"] == 0, \
        f"{entry['scenario']}: pressure ledger imbalance"
    assert entry["pressure"]["unresolved"] == 0, \
        f"{entry['scenario']}: unresolved pressure events"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", choices=sorted(SCENARIOS),
                    help="scenario family to run")
    ap.add_argument("--preset", default="smoke",
                    help="scenario preset (smoke/default/...; see --list)")
    ap.add_argument("--seed", type=int, default=0,
                    help="scenario RNG seed (0 = the preset's own seed)")
    ap.add_argument("--all", action="store_true",
                    help="run every scenario family at --preset")
    ap.add_argument("--list", action="store_true",
                    help="list scenario families and their presets")
    ap.add_argument("--json", default=None,
                    help="also write the entries to this path")
    ap.add_argument("--max-events", type=int, default=20_000_000,
                    help="event budget before declaring non-quiescence")
    args = ap.parse_args(argv)

    if args.list:
        for name in sorted(SCENARIOS):
            presets = SCENARIOS[name].presets()
            print(f"{name}: {', '.join(sorted(presets))}")
        return 0
    if not args.scenario and not args.all:
        ap.error("--scenario, --all or --list required")

    names = sorted(SCENARIOS) if args.all else [args.scenario]
    entries = []
    for name in names:
        entry = run_scenario(name, args.preset, args.seed,
                             max_events=args.max_events)
        entries.append(entry)
        persist_trajectory(TRAJECTORY_FILE, entry, key="scenario",
                           ignore=("at", "wall_s", "events_per_s"))
        s = entry["sessions"]
        print(f"{entry['scenario']}: {s['finished']} finished / "
              f"{s['abandoned']} abandoned of {entry['submitted']} "
              f"({entry['trace']['n_events']} events, {entry['wall_s']}s, "
              f"reuse {entry['fleet']['reuse_frac']:.3f}, "
              f"ttft p99 {entry['slo']['ttft']['p99'] * 1e3:.2f} ms, "
              f"itl p99 {entry['slo']['itl']['p99'] * 1e3:.2f} ms, "
              f"trace {entry['trace']['digest'][:12]})")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"entries": entries}, f, indent=1, default=float)
            f.write("\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
