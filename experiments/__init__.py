"""Fleet-scale stress scenarios for the event-driven simulator.

Not unit tests — the actual test suite is in ``tests/``. See
``experiments/README.md`` and DESIGN.md §12.
"""
