"""Model correctness: chunked attention vs naive oracle (values + grads),
prefill/decode consistency against the full forward pass, windowed ring
caches, MLA absorbed-vs-naive decode, SSD chunked-vs-recurrent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import init_params, sample_batch
from repro.models.attention import chunked_attention, decode_attention
from repro.models.transformer import decode, loss_and_metrics, prefill


def naive_attention(q, k, v, *, scale, cap=None, window=None, q_offset=0):
    B, Sq, H, Dk = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qq = q.reshape(B, Sq, Hkv, G, Dk)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qq.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, v.shape[-1]).astype(v.dtype)


@pytest.mark.parametrize("window,cap,qc,kvc", [
    (None, None, 16, 16), (None, 50.0, 32, 16), (24, None, 16, 32),
    (24, 30.0, 64, 64), (None, None, 128, 128),
])
def test_chunked_attention_matches_naive(window, cap, qc, kvc):
    rng = np.random.default_rng(0)
    B, S, H, Hkv, D = 2, 96, 4, 2, 16
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    out = chunked_attention(q, k, v, scale=D**-0.5, window=window, cap=cap,
                            q_chunk=qc, kv_chunk=kvc)
    ref = naive_attention(q, k, v, scale=D**-0.5, cap=cap, window=window)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("cap,window", [(None, None), (30.0, 24)])
def test_chunked_attention_grads_match(cap, window):
    rng = np.random.default_rng(1)
    B, S, H, D = 1, 64, 2, 8
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    g = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)

    def f_chunked(q, k, v):
        return (chunked_attention(q, k, v, scale=D**-0.5, cap=cap, window=window,
                                  q_chunk=16, kv_chunk=16) * g).sum()

    def f_naive(q, k, v):
        return (naive_attention(q, k, v, scale=D**-0.5, cap=cap, window=window) * g).sum()

    gc = jax.grad(f_chunked, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gc, gn):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


FAST_ARCHS = ("deepseek-7b", "gemma2-27b", "mixtral-8x22b",
              "deepseek-v2-lite-16b", "mamba2-2.7b", "hymba-1.5b",
              "musicgen-large")


@pytest.mark.parametrize("arch", FAST_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """Greedy decode continuation must equal teacher-forced full forward.
    fp32 so path differences (chunked prefill vs cache decode) are exact."""
    cfg = reduced(get_config(arch), dtype="float32", param_dtype="float32",
                  capacity_factor=4.0)  # cap=g: dropless, seq-len-invariant
    params = init_params(cfg, jax.random.key(0))
    S0, S1 = 24, 4  # prompt, continuation
    batch = sample_batch(cfg, batch=2, seq=S0 + S1, with_labels=False)
    toks = batch["tokens"]
    prefix = cfg.n_meta_tokens + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)

    # ground truth: full forward logits at each position via loss-less prefill
    full_batch = dict(batch)
    logits_full, _ = prefill(cfg, params, full_batch, max_cache_len=S0 + S1 + prefix)

    # prefill on the prompt, then teacher-forced decode steps
    pb = {k: (v[:, :S0] if k == "tokens" else v) for k, v in batch.items()}
    logits, caches = prefill(cfg, params, pb, max_cache_len=S0 + S1 + prefix)
    for t in range(S1):
        tok = toks[:, S0 + t][:, None]
        cur = jnp.int32(prefix + S0 + t)
        logits, caches = decode(cfg, params, caches, tok, cur)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(logits_full, np.float32),
        atol=2e-3, rtol=2e-3)


def test_windowed_ring_cache_decode():
    """Ring cache of window size must reproduce windowed full attention."""
    cfg = reduced(get_config("mixtral-8x22b"), dtype="float32",
                  param_dtype="float32", capacity_factor=4.0)
    assert cfg.window_pattern == (64,)
    params = init_params(cfg, jax.random.key(1))
    S0, S1 = 80, 3  # prompt longer than the 64-token window
    batch = sample_batch(cfg, batch=1, seq=S0 + S1, with_labels=False)
    toks = batch["tokens"]
    logits_full, _ = prefill(cfg, params, batch, max_cache_len=S0 + S1)
    pb = {"tokens": toks[:, :S0]}
    logits, caches = prefill(cfg, params, pb, max_cache_len=S0 + S1)
    for t in range(S1):
        logits, caches = decode(cfg, params, caches, toks[:, S0 + t][:, None],
                                jnp.int32(S0 + t))
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(logits_full, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_mla_absorbed_equals_naive_decode():
    import dataclasses
    cfg = reduced(get_config("deepseek-v2-lite-16b"), dtype="float32",
                  param_dtype="float32", capacity_factor=4.0)
    cfg_a = dataclasses.replace(cfg, mla_absorb=True)
    params = init_params(cfg, jax.random.key(2))
    batch = sample_batch(cfg, batch=2, seq=16, with_labels=False)
    _, caches = prefill(cfg, params, batch, max_cache_len=24)
    tok = batch["tokens"][:, -1:]
    l0, _ = decode(cfg, params, caches, tok, jnp.int32(16))
    l1, _ = decode(cfg_a, params, caches, tok, jnp.int32(16))
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32), atol=2e-3, rtol=2e-3)


def test_ssd_chunked_matches_recurrence():
    from repro.models.ssm import ssd_chunked, ssd_decode
    rng = np.random.default_rng(3)
    B, S, H, P, N = 2, 64, 4, 8, 16
    x = jnp.asarray(rng.normal(0, 1, (B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.2, (B, S, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 4, (H,)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (B, S, 1, N)), jnp.float32)
    c = jnp.asarray(rng.normal(0, 1, (B, S, 1, N)), jnp.float32)
    y_chunk, state_chunk = ssd_chunked(x, dt, a, b, c, chunk=16)
    # sequential recurrence
    state = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        y_t, state = ssd_decode(x[:, t], dt[:, t], a, b[:, t], c[:, t], state)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_seq, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(state_chunk, state, atol=1e-3, rtol=1e-3)


def test_moe_capacity_and_combine_invariants():
    """No token weight may exceed 1; dropped tokens produce zero output."""
    from repro.models.moe import moe_sublayer, moe_defs
    from repro.models.param import materialize
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config("mixtral-8x22b")),
                              capacity_factor=0.5)  # force drops
    p = materialize(moe_defs(cfg), jax.random.key(0), "float32")
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 64, cfg.d_model)),
                    jnp.float32)
    out, aux = moe_sublayer(cfg, p, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_loss_finite_and_shapes(arch):
    """The per-arch smoke the assignment requires: reduced config, one
    train step's forward on CPU, output shapes + no NaNs."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    batch = sample_batch(cfg, batch=2, seq=32)
    loss, metrics = jax.jit(lambda p, b: loss_and_metrics(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) > 0
