"""Sharding rules (divisibility-fallback properties via hypothesis over an
AbstractMesh) and the trip-count-aware HLO analyzer."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.launch.hlo_analysis import (analyze, exec_counts, parse_module,
                                       roofline_terms, shape_bytes, shape_dims)
from repro.runtime.sharding import DEFAULT_RULES, mesh_axis_size, spec_for

def _abstract_mesh(sizes, names):
    try:  # jax >= 0.5: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh(sizes, names)
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, sizes)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_spec_for_basic():
    assert spec_for(("vocab", "embed"), (256000, 4096), MESH) == P("model", None)
    assert spec_for(("act_batch", None), (256, 4096), MESH) == P("data", None)
    assert spec_for(("act_batch", None), (256, 4096), MESH3) == P(("pod", "data"), None)


def test_spec_for_divisibility_fallback():
    # 8 heads don't divide the 16-way model axis -> replicate
    assert spec_for(("embed", "heads", "head_dim"), (2048, 8, 256), MESH) == \
        P(None, None, None)
    # 25 heads (hymba) -> replicate; vocab still shards
    assert spec_for(("heads",), (25,), MESH) == P(None)
    # batch=1 long-context decode -> act_batch falls back
    assert spec_for(("act_batch", "act_kv_seq"), (1, 524288), MESH) == \
        P(None, "model")


def test_spec_for_never_reuses_axis():
    spec = spec_for(("act_batch", "act_kv_seq", "act_kv_heads"),
                    (256, 32768, 16), MESH)
    used = [a for part in spec for a in
            ((part,) if isinstance(part, str) else (part or ()))]
    assert len(used) == len(set(used))


@given(st.lists(st.sampled_from([None, "vocab", "heads", "ff", "experts",
                                 "act_batch", "act_kv_seq"]),
                min_size=1, max_size=4),
       st.lists(st.integers(min_value=1, max_value=4096), min_size=4, max_size=4))
@settings(max_examples=100, deadline=None)
def test_spec_for_always_divides(axes, dims):
    dims = dims[:len(axes)]
    spec = spec_for(axes, dims, MESH)
    for part, dim in zip(spec, dims):
        if part is None:
            continue
        axes_t = (part,) if isinstance(part, str) else tuple(part)
        assert dim % mesh_axis_size(MESH, axes_t) == 0


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

SYNTH = """
HloModule test, num_partitions=8

%cond (p: (f32[8,8], s32[])) -> pred[] {
  %p = (f32[8,8]{1,0}, s32[]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=1
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (bp: (f32[8,8], s32[])) -> (f32[8,8], s32[]) {
  %bp = (f32[8,8]{1,0}, s32[]) parameter(0)
  %x = f32[8,8]{1,0} get-tuple-element(%bp), index=0
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%sum
  %i2 = s32[] get-tuple-element(%bp), index=1
  %one = s32[] constant(1)
  %i3 = s32[] add(%i2, %one)
  ROOT %t = (f32[8,8]{1,0}, s32[]) tuple(%ar, %i3)
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,8]) -> f32[8,8] {
  %arg = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %tup = (f32[8,8]{1,0}, s32[]) tuple(%arg, %zero)
  %w = (f32[8,8]{1,0}, s32[]) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=0
}
"""


def test_shape_parsing():
    assert shape_bytes("f32[8,8]{1,0}") == 256
    assert shape_bytes("(f32[4]{0}, bf16[2,2]{1,0})") == 24
    assert shape_dims("bf16[3,5,7]{2,1,0}") == [3, 5, 7]
    assert shape_bytes("pred[]") == 1


def test_trip_count_and_flops():
    comps, entry = parse_module(SYNTH)
    assert entry == "main"
    counts = exec_counts(comps, entry)
    assert counts["body"] == 12
    ana = analyze(SYNTH, num_devices=8)
    assert ana["dot_flops"] == 12 * 2 * 8 * 8 * 8
    ar = ana["collectives"]["all-reduce"]
    assert ar["count"] == 12
    assert ar["operand_bytes"] == 12 * 256
    assert ar["wire_bytes"] == pytest.approx(12 * 2 * 256 * 7 / 8)


def test_roofline_terms():
    ana = analyze(SYNTH, num_devices=8)
    rt = roofline_terms(ana, peak_flops=1e12, hbm_bw=1e11, ici_bw=1e10)
    assert rt["dominant"] in ("compute", "memory", "collective")
    assert rt["compute_s"] == pytest.approx(ana["flops"] / 1e12)


def test_analyzer_matches_xla_on_loop_free_graph():
    """On a graph with no loops our flop count must match XLA's own."""
    import jax.numpy as jnp

    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    ana = analyze(compiled.as_text(), 1)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax 0.4.x returns [dict]
        ca = ca[0]
    xla = ca["flops"]
    assert ana["dot_flops"] == pytest.approx(xla, rel=0.01)
