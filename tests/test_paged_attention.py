"""Ragged paged-attention kernel (DESIGN.md §10): interpret-mode Pallas
parity against the jnp reference across page sizes / ragged batches /
layer features, bit-invariance to table padding, and the serving-level
paged-plane guarantees — a prefix hit decodes bit-identically (fp32) to
a cold start with ZERO copy bytes, and the per-tier metered reads equal
the kernel's page-gather byte count."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.memclass import HBM3E, MRM_RRAM
from repro.core.simulator import MemorySystem
from repro.kernels.paged_attention import (interleave_kv,
                                           ragged_paged_attention,
                                           ragged_paged_attention_ref)
from repro.models import init_params
from repro.serving import EngineConfig, ServeEngine

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# Kernel: Pallas (interpret) vs jnp reference
# ---------------------------------------------------------------------------


def _case(q_lens, kv_lens, ps, Hq, Hkv, D, extra_pages=0, dtype=jnp.float32):
    """Random ragged batch on paged storage. Every sequence gets its own
    page run; page 0 stays the reserved null page. ``extra_pages`` pads
    each table row with trailing null slots (must not change results)."""
    S = len(q_lens)
    W = max(-(-k // ps) for k in kv_lens)
    P = 1 + S * W
    kv_pages = jnp.asarray(RNG.normal(0, 1, (P, ps, 2 * Hkv, D)), dtype)
    table = np.zeros((S, W + extra_pages), np.int32)
    for s, klen in enumerate(kv_lens):
        n = -(-klen // ps)
        table[s, :n] = 1 + s * W + np.arange(n)
    T = sum(q_lens)
    q = jnp.asarray(RNG.normal(0, 1, (T, Hq, D)), dtype)
    cu = jnp.asarray(np.concatenate([[0], np.cumsum(q_lens)]), jnp.int32)
    return (q, kv_pages, jnp.asarray(table), cu,
            jnp.asarray(kv_lens, jnp.int32))


@pytest.mark.parametrize("ps", [8, 16, 32])
@pytest.mark.parametrize("q_lens,kv_lens", [
    ([5, 1, 9], [37, 12, 9]),          # mixed extend + decode, ragged
    ([1, 1, 1, 1], [33, 7, 64, 17]),   # pure batched decode
    ([16], [48]),                      # single chunked-extend sequence
])
@pytest.mark.parametrize("cap,window", [(None, None), (30.0, None),
                                        (None, 20), (30.0, 20)])
def test_pallas_matches_reference(ps, q_lens, kv_lens, cap, window):
    q, kvp, tbl, cu, kl = _case(q_lens, kv_lens, ps, Hq=4, Hkv=2, D=16)
    scale = 16 ** -0.5
    out = ragged_paged_attention(q, kvp, tbl, cu, kl, scale=scale, cap=cap,
                                 window=window, max_q_len=max(q_lens),
                                 backend="pallas", interpret=True)
    ref = ragged_paged_attention_ref(q, kvp, tbl, cu, kl, scale=scale,
                                     cap=cap, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-6, rtol=3e-6)


def test_pallas_bit_exact_fp32():
    """fp32 interpret-mode lowering reduces in the same page order as the
    reference scan — outputs are bit-identical, the property the serving
    hit-vs-cold guarantee stands on."""
    q, kvp, tbl, cu, kl = _case([5, 1, 9], [37, 12, 9], 16, 4, 2, 16)
    out = ragged_paged_attention(q, kvp, tbl, cu, kl, scale=0.25,
                                 max_q_len=9, backend="pallas",
                                 interpret=True)
    ref = ragged_paged_attention_ref(q, kvp, tbl, cu, kl, scale=0.25)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_table_padding_bit_invariance():
    """Trailing null-page table slots contribute exp-weight 0 and
    correction 1 to the online softmax — results are BIT-identical, so a
    borrower whose table is wider than the donor's never diverges."""
    args = dict(q_lens=[4, 7], kv_lens=[29, 18], ps=8, Hq=2, Hkv=1, D=8)
    q, kvp, tbl0, cu, kl = _case(**args)
    q2, kvp2, tbl4, _, _ = _case(extra_pages=4, **args)
    out0 = ragged_paged_attention_ref(q, kvp, tbl0, cu, kl, scale=0.3)
    out4 = ragged_paged_attention_ref(q, kvp, tbl4, cu, kl, scale=0.3)
    assert np.array_equal(np.asarray(out0), np.asarray(out4))
    p0 = ragged_paged_attention(q, kvp, tbl0, cu, kl, scale=0.3,
                                max_q_len=7, backend="pallas",
                                interpret=True)
    p4 = ragged_paged_attention(q, kvp, tbl4, cu, kl, scale=0.3,
                                max_q_len=7, backend="pallas",
                                interpret=True)
    assert np.array_equal(np.asarray(p0), np.asarray(p4))


@pytest.mark.parametrize("ps", [8, 16, 32])
@pytest.mark.parametrize("q_lens,kv_lens", [
    ([5, 1, 9], [37, 12, 9]),          # mixed extend + decode, ragged
    ([1, 1, 1, 1], [33, 7, 64, 17]),   # pure batched decode
])
def test_grouped_grid_sparse_table_parity(ps, q_lens, kv_lens):
    """Sparse page tables (interior null slots): the grouped grid skips
    null page blocks without a gather, the ungrouped baseline pulls and
    masks them in-register, the reference masks by page id — all three
    bit-identical in fp32. The host-side gather replica confirms the
    grouped grid reads strictly fewer pages."""
    from repro.kernels.paged_attention.kernel import pages_gathered
    q, kvp, tbl, cu, kl = _case(q_lens, kv_lens, ps, Hq=4, Hkv=2, D=16)
    tbl = np.array(tbl)
    tbl[:, 1::2] = 0                    # null out every other slot
    tbl = jnp.asarray(tbl)
    kw = dict(scale=16 ** -0.5, max_q_len=max(q_lens))
    ref = ragged_paged_attention_ref(q, kvp, tbl, cu, kl, scale=kw["scale"])
    for bq, bkv, nb in [(4, 2, 2), (8, 4, 3), (64, 64, 4)]:
        out = ragged_paged_attention(q, kvp, tbl, cu, kl, backend="pallas",
                                     interpret=True, block_q=bq,
                                     block_kv=bkv, num_buffers=nb, **kw)
        assert np.array_equal(np.asarray(out), np.asarray(ref))
    base = ragged_paged_attention(q, kvp, tbl, cu, kl, backend="pallas",
                                  interpret=True, skip_blocks=False, **kw)
    assert np.array_equal(np.asarray(base), np.asarray(ref))
    grouped = pages_gathered(tbl, cu, kl, page_size=ps,
                             max_q_len=kw["max_q_len"])
    full = pages_gathered(tbl, cu, kl, page_size=ps,
                          max_q_len=kw["max_q_len"], skip_blocks=False)
    assert 0 < grouped < full


def test_kernel_config_resolution():
    """Explicit block/buffer overrides win over the tuned cache and are
    clamped to the launch shape; the env-driven interpret default is
    resolved once per process."""
    from repro.kernels.paged_attention.tune import (KernelConfig,
                                                    best_config,
                                                    resolve_config,
                                                    set_config)
    set_config(64, 48, KernelConfig(block_q=32, block_kv=16, num_buffers=4))
    assert best_config(64, 48) == KernelConfig(32, 16, 4)
    eff = resolve_config(64, 48, max_q_len=5, table_width=3)
    assert eff == KernelConfig(block_q=5, block_kv=3, num_buffers=4)
    eff = resolve_config(64, 48, max_q_len=100, table_width=100,
                         block_q=8, block_kv=2, num_buffers=9)
    assert eff == KernelConfig(block_q=8, block_kv=2, num_buffers=4)


def test_explicit_positions_ring_layout():
    """The q_pos/kv_pos_pages variant (ring-cache compatibility: the
    decode_attention wrapper) masks by stored positions, not slot-derived
    ones — scattered/empty rows behave like the legacy kernel."""
    ps, Hkv, D = 16, 2, 16
    B, C = 2, 48
    k = RNG.normal(0, 1, (B, C, Hkv, D))
    v = RNG.normal(0, 1, (B, C, Hkv, D))
    pos = np.where(RNG.random((B, C)) < 0.8,
                   RNG.integers(0, 40, (B, C)), -1).astype(np.int32)
    cur = np.asarray([25, 37], np.int32)
    kvp = jnp.asarray(np.stack([k, v], axis=3).reshape(B, C, 2 * Hkv, D)
                      .reshape(B * C // ps, ps, 2 * Hkv, D), jnp.float32)
    kv_pos = jnp.asarray(pos.reshape(B * C // ps, ps))
    n_per = C // ps
    tbl = jnp.arange(B * n_per, dtype=jnp.int32).reshape(B, n_per)
    q = jnp.asarray(RNG.normal(0, 1, (B, 4, D)), jnp.float32)
    cu = jnp.arange(B + 1, dtype=jnp.int32)
    kl = jnp.full((B,), C, jnp.int32)
    out = ragged_paged_attention(q, kvp, tbl, cu, kl, scale=0.25,
                                 q_pos=jnp.asarray(cur), kv_pos_pages=kv_pos,
                                 max_q_len=1, backend="pallas",
                                 interpret=True)
    ref = ragged_paged_attention_ref(q, kvp, tbl, cu, kl, scale=0.25,
                                     q_pos=jnp.asarray(cur),
                                     kv_pos_pages=kv_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-6, rtol=3e-6)


def test_interleave_layout_roundtrip():
    k = jnp.asarray(RNG.normal(0, 1, (2, 5, 3, 4)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (2, 5, 3, 4)), jnp.float32)
    kv = interleave_kv(k, v)
    assert kv.shape == (2, 5, 6, 4)
    # K heads at even fused indices, V heads at odd — the layout every
    # page gather in the kernel assumes
    assert np.array_equal(np.asarray(kv[:, :, 0::2]), np.asarray(k))
    assert np.array_equal(np.asarray(kv[:, :, 1::2]), np.asarray(v))


# ---------------------------------------------------------------------------
# Serving: zero-copy prefix hits on the paged plane
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module", params=["deepseek-7b", "deepseek-v2-lite-16b"])
def arch_setup(request):
    full = get_config(request.param)
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    return full, cfg, params


def _mk_engine(full, cfg, params, **kw):
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    ecfg = dict(max_slots=2, max_cache_len=96, weight_tier="hbm",
                kv_tier="mrm", eos_token=-1, chunk_tokens=16, page_tokens=16,
                radix_hot_threshold=2)
    ecfg.update(kw)
    return ServeEngine(cfg, params, mem, EngineConfig(**ecfg),
                       account_cfg=full)


def _outputs(eng):
    return {k: list(v) for k, v in eng.outputs.items()}


def _run(eng, prompts, max_new=6):
    for p in prompts:   # sequential: each later prompt can hit
        eng.submit(list(p), max_new)
        eng.run_until_idle()
    return eng.report()


def test_paged_hit_bit_equal_zero_copy_metered(arch_setup):
    """The PR's acceptance bar, per positional family (GQA + MLA):
    prefix-hit decode on the paged plane is bit-identical (fp32) to both
    the ring path and a cold start, with copy bytes == 0 (no donor-seed
    copy, no snapshot), and the KV tier's read stream equals the
    kernel's analytically-metered page gathers exactly."""
    full, cfg, params = arch_setup
    rng = np.random.default_rng(5)
    base = rng.integers(2, 400, 40)
    prompts = [base, np.concatenate([base[:32], rng.integers(2, 400, 9)])]

    ring = _mk_engine(full, cfg, params, paged_kernel=False)
    rep_ring = _run(ring, prompts)
    paged = _mk_engine(full, cfg, params, paged_kernel=True)
    rep = _run(paged, prompts)
    cold = _mk_engine(full, cfg, params, paged_kernel=True,
                      prefix_caching=False)
    _run(cold, prompts)

    assert _outputs(ring) == _outputs(paged) == _outputs(cold)
    assert rep["prefix"]["compute_hits"] >= 1
    # zero-copy hit: the ring path pays a full cache-tree copy per hit,
    # the paged path splices the page table
    assert rep_ring["seed_copy_bytes"] > 0
    assert rep["seed_copy_bytes"] == 0.0
    assert rep["snapshot_bytes"] == 0.0 and rep_ring["snapshot_bytes"] > 0
    # metering: the KV tier read exactly what the kernel gathered (plus
    # the read half of any sub-page tail copy)
    assert rep["kernel_read_bytes"] > 0
    mrm_reads = paged.mem.devices["mrm"].stats.read_bytes
    assert mrm_reads == pytest.approx(
        rep["kernel_read_bytes"] + paged.kv.tail_copy_bytes / 2)


def test_paged_subpage_tail_bit_equal():
    """Sub-page tail reuse on the paged plane: the ONLY bytes a hit ever
    copies are the tail rows of one page (copy_page_rows), and outputs
    stay bit-identical to ring and cold runs."""
    full = get_config("deepseek-7b")
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(41)
    head = list(rng.integers(2, 400, 55))   # straddles a 16-token page
    prompts = [head + list(rng.integers(2, 400, 9)) for _ in range(3)]

    ring = _mk_engine(full, cfg, params, paged_kernel=False)
    _run(ring, prompts)
    paged = _mk_engine(full, cfg, params, paged_kernel=True)
    rep = _run(paged, prompts)
    cold = _mk_engine(full, cfg, params, paged_kernel=True,
                      prefix_caching=False)
    _run(cold, prompts)

    assert _outputs(ring) == _outputs(paged) == _outputs(cold)
    assert paged.kv.tail_hits > 0
    assert rep["seed_copy_bytes"] == 0.0
    assert paged.prefill_tokens_computed < cold.prefill_tokens_computed


def test_paged_migration_splices_pages(arch_setup):
    """Cross-replica migration on the paged plane ships page data, not
    snapshots: the receiver writes the donor's compute pages into its
    pool, and a local hit on the grafted prefix decodes identically to
    the donor — still zero copy bytes at admission."""
    full, cfg, params = arch_setup
    rng = np.random.default_rng(11)
    p = rng.integers(2, 400, 36)
    donor = _mk_engine(full, cfg, params, paged_kernel=True)
    _run(donor, [p], max_new=4)
    recv = _mk_engine(full, cfg, params, paged_kernel=True)

    key = donor.radix_key_for(list(p))
    exp = donor.export_prefix(key)
    assert exp is not None and exp.get("page_data") is not None
    assert exp["snapshot_bytes"] == 0.0
    imp = recv.import_prefix(exp["tokens"], caches=exp["caches"],
                             hot=exp["hot"], hits=exp["hits"],
                             snap_kind=exp["snap_kind"],
                             snap_tokens=exp["snap_tokens"],
                             page_data=exp["page_data"],
                             page_tokens=exp["page_tokens"])
    assert imp["total_tokens"] > 0 and imp["snapshot_bytes"] == 0.0

    rep = _run(recv, [p], max_new=4)
    d_out, r_out = list(donor.outputs[0]), list(recv.outputs[0])
    assert d_out == r_out
    assert rep["prefix"]["compute_hits"] == 1
    assert rep["seed_copy_bytes"] == 0.0

    # geometry mismatch is rejected BEFORE adoption (a graft this engine
    # cannot compute on would poison later hits)
    recv2 = _mk_engine(full, cfg, params, paged_kernel=True)
    bad = recv2.import_prefix(exp["tokens"], page_data=exp["page_data"],
                              page_tokens=exp["page_tokens"] * 2)
    assert bad["total_tokens"] == 0
    assert recv2.kv.radix.match(key, recv2.mem.now).tokens == 0


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "hymba-1.5b"])
def test_paged_point_stack_hit_bit_equal(arch):
    """paged_kernel=True is universal: SSM and hybrid stacks serve on
    pooled point-state pages (conv + recurrent state captured at page
    boundaries) — no ring fallback exists any more — and a prefix hit
    resumes from a sealed page's state bit-identically (fp32) to the
    ring path and a cold start, with zero seed-copy bytes."""
    full = get_config(arch)
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(13)
    base = rng.integers(2, 400, 40)
    prompts = [base, np.concatenate([base[:32], rng.integers(2, 400, 9)])]

    ring = _mk_engine(full, cfg, params, paged_kernel=False)
    _run(ring, prompts)
    paged = _mk_engine(full, cfg, params, paged_kernel=True)
    assert paged.paged is True and paged.backend.paged is True
    rep = _run(paged, prompts)
    cold = _mk_engine(full, cfg, params, paged_kernel=True,
                      prefix_caching=False)
    _run(cold, prompts)

    assert _outputs(ring) == _outputs(paged) == _outputs(cold)
    assert rep["prefix"]["compute_hits"] >= 1
    assert rep["seed_copy_bytes"] == 0.0
    assert rep["snapshot_bytes"] == 0.0
    # recurrent-state pages ride the same accounting: page reads carry
    # the per-page state snapshot bytes
    assert paged.kv.state_bytes_page > 0
    assert paged.prefill_tokens_computed < cold.prefill_tokens_computed


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "hymba-1.5b"])
def test_paged_state_page_migration(arch):
    """Cross-replica migration of point-state pages: the receiver grafts
    conv/state pages and a local hit decodes identically to the donor;
    wrong page geometry or mangled state leaves are rejected BEFORE
    adoption."""
    full = get_config(arch)
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(17)
    p = rng.integers(2, 400, 32)
    donor = _mk_engine(full, cfg, params, paged_kernel=True)
    _run(donor, [p], max_new=4)

    key = donor.radix_key_for(list(p))
    exp = donor.export_prefix(key)
    assert exp is not None and exp.get("page_data") is not None
    assert exp["snapshot_bytes"] == 0.0

    recv = _mk_engine(full, cfg, params, paged_kernel=True)
    imp = recv.import_prefix(exp["tokens"], caches=exp["caches"],
                             hot=exp["hot"], hits=exp["hits"],
                             snap_kind=exp["snap_kind"],
                             snap_tokens=exp["snap_tokens"],
                             page_data=exp["page_data"],
                             page_tokens=exp["page_tokens"])
    assert imp["total_tokens"] > 0 and imp["snapshot_bytes"] == 0.0
    rep = _run(recv, [p], max_new=4)
    assert list(donor.outputs[0]) == list(recv.outputs[0])
    assert rep["prefix"]["compute_hits"] == 1
    assert rep["seed_copy_bytes"] == 0.0

    # page-size mismatch: state captured at foreign page boundaries is
    # meaningless here
    recv2 = _mk_engine(full, cfg, params, paged_kernel=True)
    bad = recv2.import_prefix(exp["tokens"], page_data=exp["page_data"],
                              page_tokens=exp["page_tokens"] * 2)
    assert bad["total_tokens"] == 0
    assert recv2.kv.radix.match(key, recv2.mem.now).tokens == 0
    # mangled state-page leaves (wrong recurrent-state geometry)
    mangled = jax.tree.map(lambda a: a[..., :-1], exp["page_data"])
    bad = recv2.import_prefix(exp["tokens"], page_data=mangled,
                              page_tokens=exp["page_tokens"])
    assert bad["total_tokens"] == 0
    assert recv2.kv.radix.match(key, recv2.mem.now).tokens == 0


def test_paged_pool_growth_and_row_copy():
    """The compute-page pool doubles when the free list drains (every
    cache-family leaf widens on the page axis) and copy_page_rows moves
    exactly the requested rows."""
    from repro.serving.engine import ComputeBackend
    full = get_config("deepseek-7b")
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    ecfg = EngineConfig(max_slots=2, max_cache_len=96, page_tokens=16,
                        weight_tier="hbm", kv_tier="mrm")
    b = ComputeBackend(cfg, params, ecfg, paged=True)
    pool0 = jax.tree.leaves(b.paged_caches)[0].shape[1]
    ids = [b.alloc_page() for _ in range(pool0 + 3)]   # forces a doubling
    assert len(set(ids)) == len(ids) and 0 not in ids
    pool1 = jax.tree.leaves(b.paged_caches)[0].shape[1]
    assert pool1 == 2 * pool0
    # mark page ids[0], copy 5 rows into ids[1]
    b.paged_caches = jax.tree.map(
        lambda a: a.at[:, ids[0]].set(1.0), b.paged_caches)
    b.copy_page_rows(ids[0], ids[1], 5)
    for leaf in jax.tree.leaves(b.paged_caches):
        got = np.asarray(leaf[:, ids[1]])
        assert np.all(got[:, :5] == 1.0) and np.all(got[:, 5:] == 0.0)
    for pid in ids:
        b.free_page(pid)
    assert len(b._free) == pool1 - 1
