"""Shared-fabric topology + predictive-replication admission control
(DESIGN.md §13): donor up-links serialize concurrent exports even to
distinct receivers, the bisection core caps aggregate flow, and the
replicator's defer-on-hot policy lets demand migrations preempt queued
speculative pushes. Pure-python analytic plane — no jax.
"""
import random
import sys
from dataclasses import replace
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.serving.events import EventKind
from repro.serving.fabric import Fabric
from repro.serving.fleet_sim import FleetConfig, FleetRequest, FleetSim

GB = 1e9


# ---------------------------------------------------------------------------
# topology primitives
# ---------------------------------------------------------------------------


def test_up_link_serializes_concurrent_exports():
    # one donor, two receivers: second transfer queues on the donor NIC
    # even though both receivers are idle (the PR 3-9 per-receiver model
    # would have run them in parallel)
    fab = Fabric(4, link_gbps=100.0, bisection_gbps=400.0)
    s0, d0 = fab.reserve(0, 1, int(100 * GB), 0.0)
    s1, d1 = fab.reserve(0, 2, int(100 * GB), 0.0)
    assert (s0, d0) == (0.0, pytest.approx(1.0))
    assert s1 == pytest.approx(d0) and d1 == pytest.approx(2.0)
    assert fab.queue_wait_s == pytest.approx(1.0)


def test_down_link_serializes_concurrent_imports():
    fab = Fabric(4, link_gbps=100.0, bisection_gbps=400.0)
    _, d0 = fab.reserve(1, 0, int(50 * GB), 0.0)
    s1, _ = fab.reserve(2, 0, int(50 * GB), 0.0)
    assert s1 == pytest.approx(d0)


def test_bisection_core_caps_disjoint_pairs():
    # 2x link bisection = 2 channels: the third disjoint-pair transfer
    # queues on the core although all four NICs involved are free
    fab = Fabric(8, link_gbps=100.0, bisection_gbps=200.0)
    assert fab.n_channels == 2
    _, d0 = fab.reserve(0, 1, int(100 * GB), 0.0)
    s1, _ = fab.reserve(2, 3, int(100 * GB), 0.0)
    s2, _ = fab.reserve(4, 5, int(100 * GB), 0.0)
    assert s1 == 0.0
    assert s2 == pytest.approx(d0)


def test_free_at_and_hot_track_the_full_path():
    fab = Fabric(4, link_gbps=100.0, bisection_gbps=400.0)
    assert not fab.hot(0, 1, 0.0)
    _, done = fab.reserve(0, 1, int(100 * GB), 0.0)
    assert fab.hot(0, 1, 0.5) and fab.hot(0, 2, 0.5) and fab.hot(2, 1, 0.5)
    assert not fab.hot(2, 3, 0.5)          # disjoint path, channels free
    assert fab.free_at(0, 2, 0.5) == pytest.approx(done)
    assert not fab.hot(0, 1, done)         # instantaneously free again


def test_half_bisection_default_and_validation():
    fab = Fabric(8, link_gbps=100.0)
    assert fab.bisection_gbps == pytest.approx(400.0)
    assert fab.n_channels == 4
    with pytest.raises(ValueError, match="below a single link"):
        Fabric(4, link_gbps=100.0, bisection_gbps=50.0)
    with pytest.raises(ValueError, match="positive"):
        Fabric(4, link_gbps=0.0)


def test_ledgers_meter_every_byte_once():
    fab = Fabric(4, link_gbps=100.0)
    fab.reserve(0, 1, int(10 * GB), 0.0)
    fab.reserve(0, 2, int(30 * GB), 0.0)
    rep = fab.report()
    assert rep["transfers"] == 2
    assert rep["bytes"] == int(40 * GB)
    assert rep["up_bytes"] == {0: int(40 * GB)}
    assert rep["down_bytes"] == {1: int(10 * GB), 2: int(30 * GB)}
    assert rep["busy_s"] == pytest.approx(0.4)


# ---------------------------------------------------------------------------
# admission control: demand preempts queued speculation
# ---------------------------------------------------------------------------


def test_demand_migration_preempts_queued_speculative_push():
    # the replicator never reserves a hot fabric — it re-checks at
    # free_at(). A demand migration arriving inside that window reserves
    # immediately, so the earlier-queued push finds the fabric hot again
    # and defers a second time: demand traffic overtakes speculation
    # without an explicit priority queue.
    fab = Fabric(4, link_gbps=100.0, bisection_gbps=100.0)  # 1 channel
    fab.reserve(0, 1, int(100 * GB), 0.0)                   # demand, 0..1s
    # speculative push 2->3 asks at t=0.5: hot (core busy) -> defers
    assert fab.hot(2, 3, 0.5)
    retry_at = fab.free_at(2, 3, 0.5)
    assert retry_at == pytest.approx(1.0)
    # demand migration 2->3 at t=0.8 reserves *now* (queued start)
    s, d = fab.reserve(2, 3, int(100 * GB), 0.8)
    assert s == pytest.approx(1.0) and d == pytest.approx(2.0)
    # the push re-checks at its retry time and yields again
    assert fab.hot(2, 3, retry_at)
    assert fab.free_at(2, 3, retry_at) == pytest.approx(d)


def test_replication_push_is_lowest_priority_event_kind():
    # at an equal timestamp every demand-side event fires first, so a
    # push decision sees the fabric reservations demand traffic just made
    assert EventKind.REPLICATION_PUSH == max(EventKind)
    assert EventKind.REPLICATION_PUSH > EventKind.MIGRATION_DELIVERY
    assert EventKind.REPLICATION_PUSH > EventKind.ARRIVAL


# ---------------------------------------------------------------------------
# fleet integration: pushes defer under contention, ledger stays balanced
# ---------------------------------------------------------------------------


def _herald_fanout(n_groups=3, fanout=12, heralds=2):
    """Herald-led fan-out bursts sharing one fresh group each (the
    rag_storm shape, hand-rolled so the test owns every timestamp)."""
    reqs, sid = [], 0
    for g in range(n_groups):
        t = g * 2.0
        for h in range(heralds):
            reqs.append(FleetRequest(session_key=sid, group=g,
                                     shared_tokens=1024, unique_tokens=48,
                                     max_new_tokens=4,
                                     arrival_s=t + 0.15 * h))
            sid += 1
        for i in range(fanout):
            reqs.append(FleetRequest(session_key=sid, group=g,
                                     shared_tokens=1024, unique_tokens=48,
                                     max_new_tokens=4,
                                     arrival_s=t + 0.55 + 0.004 * i))
            sid += 1
    return reqs


def _run(cfg, reqs):
    sim = FleetSim(cfg)
    for r in reqs:
        sim.submit(r)
    rep = sim.run(max_events=2_000_000)
    sim.check()
    return sim, rep


def test_fleet_pushes_defer_on_hot_fabric_and_ledger_balances():
    # a starved fabric (1 GB/s link, single core channel) keeps the
    # fabric hot through every burst: speculative pushes must defer (and
    # some abandon), never reserve into the contention, and the fabric
    # byte ledger must still equal migrated + replicated exactly
    cfg = FleetConfig(n_replicas=4, interconnect_gbps=1.0,
                      fabric_bisection_gbps=1.0,
                      replicate_threshold=1, replicate_copies=3)
    sim, rep = _run(cfg, _herald_fanout())
    rp = rep["replication"]
    assert rp["pushes_scheduled"] > 0
    assert rp["pushes_deferred"] > 0, "hot fabric never deferred a push"
    fab = rep["fabric"]
    assert fab["bytes"] == pytest.approx(
        rep["fleet"]["migrated_bytes"] + rp["replicated_bytes"])
    assert rep["quiesced"]


def test_fleet_replication_beats_reactive_on_herald_fanout():
    reqs = _herald_fanout(n_groups=4, fanout=16)
    base_cfg = FleetConfig(n_replicas=4, interconnect_gbps=100.0)
    pred_cfg = replace(base_cfg, replicate_threshold=1, replicate_copies=3)
    _, base = _run(base_cfg, reqs)
    _, pred = _run(pred_cfg, reqs)
    assert pred["fleet"]["decoded_tokens"] == base["fleet"]["decoded_tokens"]
    assert pred["replication"]["replicated_bytes"] > 0
    assert pred["fleet"]["migrations"] < base["fleet"]["migrations"]
    assert pred["slo"]["ttft"]["p95"] < base["slo"]["ttft"]["p95"]


def test_fleet_trace_digest_stable_under_submission_shuffle():
    reqs = _herald_fanout()
    cfg = FleetConfig(n_replicas=4, replicate_threshold=1,
                      replicate_copies=3, record_trace=True)
    digests = []
    for seed in (None, 0, 1):
        order = list(reqs)
        if seed is not None:
            random.Random(seed).shuffle(order)
        _, rep = _run(cfg, order)
        digests.append(rep["trace"]["digest"])
    assert len(set(digests)) == 1, "submission order leaked into the trace"
