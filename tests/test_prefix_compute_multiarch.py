"""Prefix compute reuse across mixer families (DESIGN.md §8).

The radix tree shares KV *pages* identically for every architecture; these
tests pin down the harder guarantee — that a prefix hit skips prefill
*compute* — for each snapshot family:

- attention (deepseek-7b) and MLA (deepseek-v2-lite-16b): *positional*
  snapshots — ring caches masked by stored positions, one donor snapshot
  serves any shorter page-aligned boundary;
- SSM (mamba2-2.7b) and hybrid (hymba-1.5b): *point* snapshots — the
  recurrent state integrates the whole prefix, so a snapshot is valid only
  at the exact page boundary it was captured at, and the first borrower at
  a new boundary recomputes once while capturing for the next.

Everything runs fp32: the extend/seeded paths are mathematically identical
to the cold prefill, and point stacks chunk on the position-space page
grid so the recurrent state's accumulation order is identical too — greedy
decode must match bit-for-bit.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.memclass import HBM3E, MRM_RRAM
from repro.core.simulator import MemorySystem
from repro.models import init_params
from repro.models.transformer import snapshot_kind, supports_extend
from repro.serving import ClusterFrontend, EngineConfig, ServeEngine

ARCHS = ["deepseek-7b", "mamba2-2.7b", "deepseek-v2-lite-16b", "hymba-1.5b"]
EXPECTED_KIND = {
    "deepseek-7b": "positional",
    "deepseek-v2-lite-16b": "positional",
    "mamba2-2.7b": "point",
    "hymba-1.5b": "point",
}


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    full = get_config(request.param)
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    return request.param, full, cfg, params


def _mk_engine(full, cfg, params, **kw):
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    ecfg = dict(max_slots=2, max_cache_len=96, weight_tier="hbm",
                kv_tier="mrm", eos_token=-1, chunk_tokens=16, page_tokens=16)
    ecfg.update(kw)
    return ServeEngine(cfg, params, mem, EngineConfig(**ecfg), account_cfg=full)


def _outputs(eng):
    return {k: list(v) for k, v in eng.outputs.items()}


def test_snapshot_kind_per_family(arch_setup):
    arch, full, cfg, params = arch_setup
    assert snapshot_kind(cfg) == EXPECTED_KIND[arch]
    assert snapshot_kind(full) == EXPECTED_KIND[arch]
    assert supports_extend(cfg)  # every family extends now


def test_extend_matches_whole_prompt_logits(arch_setup):
    """Model-level: prefilling a prompt's head and ``extend``-ing the tail
    is the same computation as whole-prompt prefill, for every mixer
    family — last-position logits and a subsequent decode step agree to
    fp32 reassociation tolerance (the two modes reduce in different
    orders; exact bitwise equality is only guaranteed when two runs cut
    the prompt identically, which the engine-level tests pin down)."""
    import jax.numpy as jnp

    from repro.models import transformer as tfm

    arch, full, cfg, params = arch_setup
    if cfg.n_experts:
        # MoE top-k routing flips on fp32 reassociation noise (~1e-6 at a
        # router input becomes a different expert), which is chaos, not an
        # extend bug — test the mixer path with dense MLPs instead. The
        # MoE config's extend path is held to the *stronger* bit-equality
        # bar in the engine-level tests below (identical partitions).
        cfg = reduced(full, dtype="float32", param_dtype="float32",
                      n_experts=0, n_shared_experts=0, moe_top_k=0,
                      expert_d_ff=0, first_dense_layers=0)
        params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(5)
    L, split = 40, 24
    toks = rng.integers(2, 400, (1, L)).astype(np.int32)
    plen = cfg.n_meta_tokens

    logits_whole, caches_whole = tfm.prefill(
        cfg, params, {"tokens": jnp.asarray(toks)}, max_cache_len=96)
    logits_head, caches = tfm.prefill(
        cfg, params, {"tokens": jnp.asarray(toks[:, :split])}, max_cache_len=96)
    logits_ext, caches_ext = tfm.extend(
        cfg, params, caches, jnp.asarray(toks[:, split:]), plen + split)
    np.testing.assert_allclose(np.asarray(logits_ext),
                               np.asarray(logits_whole),
                               atol=2e-4, rtol=2e-4)
    # decode one step from both cache states with the same forced token
    tok = np.asarray(jnp.argmax(logits_whole, -1)).astype(np.int32)[:, None]
    d_whole, _ = tfm.decode(cfg, params, caches_whole, jnp.asarray(tok),
                            plen + L)
    d_ext, _ = tfm.decode(cfg, params, caches_ext, jnp.asarray(tok), plen + L)
    np.testing.assert_allclose(np.asarray(d_ext), np.asarray(d_whole),
                               atol=2e-4, rtol=2e-4)


def test_chunked_engine_bit_equal_to_whole_prompt_engine(arch_setup):
    """Engine-level: a chunk_tokens=16 engine decodes exactly what a
    whole-prompt engine decodes. Bitwise equality requires both engines
    to cut prompts identically — guaranteed for point stacks, which chunk
    on the position-space page grid in every mode (DESIGN.md §8).
    Positional stacks reassociate the softmax between modes (covered at
    logits level above; the attention token-level form lives in
    tests/test_serving.py::test_chunked_prefill_token_equivalence)."""
    arch, full, cfg, params = arch_setup
    if EXPECTED_KIND[arch] != "point":
        pytest.skip("partition differs between modes for positional "
                    "stacks; see logits-level test above")
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(2, 400, n)) for n in (41, 70, 23)]
    chunked = _mk_engine(full, cfg, params, chunk_tokens=16)
    whole = _mk_engine(full, cfg, params, chunk_tokens=None)
    for eng in (chunked, whole):
        for p in prompts:
            eng.submit(list(p), 6)
        rep = eng.run_until_idle()
        assert rep["finished"] == len(prompts)
    assert _outputs(chunked) == _outputs(whole)


def test_prefix_hit_decodes_identically_to_cold_start(arch_setup):
    """Shared-prefix traffic served with the radix tree on decodes exactly
    what a prefix_caching=False engine decodes, and compute was actually
    skipped. Point stacks (SSM/hybrid) skip from the *second* borrower —
    the first recomputes the shared run once while capturing the state at
    the observed boundary (DESIGN.md §8)."""
    arch, full, cfg, params = arch_setup
    kind = EXPECTED_KIND[arch]
    rng = np.random.default_rng(21)
    shared = list(rng.integers(2, 400, 48))
    prompts = [shared + list(rng.integers(2, 400, 8)) for _ in range(4)]

    warm = _mk_engine(full, cfg, params)
    for p in prompts:  # sequential: each later prompt can hit
        warm.submit(list(p), 6)
        warm.run_until_idle()
    assert warm.kv.prefix_hits >= 2          # pages shared either way
    assert warm.prefill_tokens_skipped > 0   # compute shortened overall
    assert warm.prefix_compute_hits >= 1
    if kind == "point":
        # a point capture exists at a page-aligned boundary the borrowers
        # share (either the donor's own last page boundary, or the
        # observed-share capture the first borrower left behind)
        from repro.serving import SnapshotHandle
        plen = warm.backend.prefix_len()
        point_bounds = {n.payload.tokens for n in warm.kv.radix.nodes()
                        if isinstance(n.payload, SnapshotHandle)
                        and n.payload.live and n.payload.kind == "point"}
        match_b = ((plen + len(shared)) // 16) * 16
        assert match_b in point_bounds, (match_b, point_bounds)

    cold = _mk_engine(full, cfg, params, prefix_caching=False)
    for p in prompts:
        cold.submit(list(p), 6)
        cold.run_until_idle()
    assert cold.prefill_tokens_skipped == 0
    assert _outputs(warm) == _outputs(cold)


def test_tail_hit_bit_equal_to_cold_start(arch_setup):
    """Sub-page tail reuse (DESIGN.md §9): a shared head that straddles a
    page boundary decodes bit-identically (fp32) to a cold start for
    every mixer family. Positional stacks (attention/MLA) actually copy
    the tail and resume extend from the exact token boundary; point
    stacks (SSM/hybrid) have no mid-page capture, so the tail degrades
    gracefully to the page-aligned behavior — same outputs, no copy."""
    arch, full, cfg, params = arch_setup
    kind = EXPECTED_KIND[arch]
    rng = np.random.default_rng(41)
    head = list(rng.integers(2, 400, 55))    # page 16: straddles a boundary
    prompts = [head + list(rng.integers(2, 400, 9)) for _ in range(4)]

    warm = _mk_engine(full, cfg, params, tail_copy=True)
    for p in prompts:   # sequential: each later prompt can hit
        warm.submit(list(p), 6)
        warm.run_until_idle()
    page_aligned = _mk_engine(full, cfg, params, tail_copy=False)
    for p in prompts:
        page_aligned.submit(list(p), 6)
        page_aligned.run_until_idle()
    cold = _mk_engine(full, cfg, params, prefix_caching=False)
    for p in prompts:
        cold.submit(list(p), 6)
        cold.run_until_idle()

    assert _outputs(warm) == _outputs(page_aligned) == _outputs(cold)
    if kind == "positional":
        # the tail was really copied (metered) and really skipped
        assert warm.kv.tail_hits > 0
        assert warm.kv.tail_tokens_copied > 0
        if full.kv_bytes_per_token() > 0:
            assert warm.kv.tail_copy_bytes > 0
        assert warm.prefill_tokens_computed \
            < page_aligned.prefill_tokens_computed
    else:
        # point stacks: the flag is on but no mid-page snapshot exists,
        # so no copy may happen (a copy without compute reuse would
        # waste bus bytes and double-account the boundary)
        assert warm.kv.tail_hits == 0
        assert warm.prefill_tokens_computed \
            == page_aligned.prefill_tokens_computed


def test_decode_audit_all_families_interleaved(arch_setup):
    """Regression guard for the PR 4 clobbering class: with the padded
    whole-prompt path deleted, chunked prefill interleaves with decode on
    every path — the engine's decode-masking audit verifies per step that
    no cache family (ring KV, MLA latents, conv/SSD state) of an inactive
    slot is written. The audit raising would fail this test."""
    arch, full, cfg, params = arch_setup
    eng = _mk_engine(full, cfg, params, max_prefills_per_step=1,
                     audit_decode_masking=True)
    eng.submit(list(np.arange(2, 14)), 20)    # short: decoding quickly
    eng.submit(list(np.arange(2, 80)), 4)     # long: chunks interleave
    saw_interleave = False
    while not eng.sched.idle and eng.steps < 200:
        out = eng.step()
        if out["prefill_tokens"] > 0 and out["decode_tokens"] > 0:
            saw_interleave = True
    assert eng.sched.stats.finished == 2
    assert saw_interleave                     # the audit actually ran hot


NON_ATTENTION = ["mamba2-2.7b", "deepseek-v2-lite-16b", "hymba-1.5b"]


@pytest.fixture(scope="module", params=NON_ATTENTION)
def non_attn_setup(request):
    full = get_config(request.param)
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    return request.param, full, cfg, params


def test_migrated_hit_decodes_identically_non_attention(non_attn_setup):
    """Cross-replica migration of the non-attention payloads (compressed
    latent pages / recurrent state / hybrid union): a request served off a
    grafted prefix on another replica decodes exactly what a cold engine
    decodes. (The attention case is covered in test_cluster_directory.)"""
    arch, full, cfg, params = non_attn_setup
    rng = np.random.default_rng(17)
    shared = list(rng.integers(2, 400, 48))
    # the seed prompt IS the shared head: its end-boundary snapshot then
    # sits exactly where the fan-out matches — required for point stacks
    prompts = [list(shared)] + \
        [shared + list(rng.integers(2, 400, 8)) for _ in range(3)]

    fe = ClusterFrontend([_mk_engine(full, cfg, params) for _ in range(2)],
                         migrate_prefixes=True, migrate_load_gap=-1)
    rids = []
    for i, p in enumerate(prompts):
        rids.append(fe.submit(list(p), 6, session_key=f"u{i}"))
        fe.run_until_idle()
    # the fan-out crossed replicas and arrived as real hits there
    replicas = {fe.replica_of(r) for r in rids}
    assert len(replicas) == 2
    assert sum(e.kv.prefix_hits_migrated for e in fe.engines) >= 1
    # compute donation crossed the wire too: some replica that was not the
    # seed's home skipped prefill tokens
    home = fe.replica_of(rids[0])
    assert fe.engines[1 - home].prefill_tokens_skipped > 0

    cold = _mk_engine(full, cfg, params, prefix_caching=False)
    for p in prompts:
        cold.submit(list(p), 6)
        cold.run_until_idle()
    assert [fe.output(r) for r in rids] == \
        [cold.outputs[i] for i in range(len(prompts))]
