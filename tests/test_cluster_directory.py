"""Fleet-level prefix directory + cross-replica KV migration (DESIGN.md §7).

Covers the directory protocol (ownership registered on insert, dropped on
leaf eviction), the migration path (pages and refcounts conserved on both
replicas' ledgers, interconnect traffic metered), the migrated-hit vs
cold-start decode equivalence guarantee, snapshot memory accounting, and
the load-tiebreak fix (directory-owned hot-prefix bytes count as load).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.memclass import HBM3E, MRM_RRAM
from repro.core.simulator import MemorySystem
from repro.serving import (ClusterFrontend, EngineConfig, PrefixDirectory,
                           ServeEngine, SnapshotHandle)


# ---------------------------------------------------------------------------
# PrefixDirectory unit semantics
# ---------------------------------------------------------------------------


def test_directory_register_lookup_invalidate():
    d = PrefixDirectory(page_tokens=4)
    path = list(range(12))                    # 3 pages
    d.register(0, path)
    # every page-aligned prefix is owned; longest match wins
    assert d.lookup(path) == (12, {0})
    assert d.lookup(path[:7]) == (4, {0})     # page-aligned, not 7
    assert d.lookup([99] * 8) == (0, None)
    d.register(1, path[:8])                   # second replica, shorter path
    assert d.lookup(path)[1] == {0}
    assert d.lookup(path[:8])[1] == {0, 1}
    # leaf eviction on replica 0 drops only the run the leaf covered
    d.invalidate(0, path, tail_tokens=4)      # deepest page leaves 0's tree
    assert d.lookup(path) == (8, {0, 1})
    # ancestors remain owned by 0 until their own eviction
    d.invalidate(0, path[:8], tail_tokens=8)
    assert d.lookup(path) == (8, {1})
    d.invalidate(1, path[:8], tail_tokens=8)
    assert d.lookup(path) == (0, None)
    assert d.n_entries() == 0


def test_directory_multicodebook_keys_normalized():
    d = PrefixDirectory(page_tokens=2)
    seq = np.arange(8, dtype=np.int32).reshape(4, 2)
    d.register(0, seq)
    assert d.lookup(seq) == (4, {0})
    assert d.lookup([[0, 1], [2, 3]]) == (2, {0})


# ---------------------------------------------------------------------------
# Engine-integrated: ownership follows the tree
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cluster_setup():
    from repro.models import init_params
    full = get_config("deepseek-7b")
    cfg = reduced(full)
    return full, cfg, init_params(cfg, jax.random.key(0))


def _mk_engine(full, cfg, params, **kw):
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    ecfg = dict(max_slots=2, max_cache_len=96, weight_tier="hbm",
                kv_tier="mrm", eos_token=-1, chunk_tokens=16, page_tokens=16)
    ecfg.update(kw)
    return ServeEngine(cfg, params, mem, EngineConfig(**ecfg), account_cfg=full)


def test_ownership_registered_on_insert_dropped_on_eviction(cluster_setup):
    full, cfg, params = cluster_setup
    fe = ClusterFrontend([_mk_engine(full, cfg, params) for _ in range(2)])
    prompt = list(range(2, 66))               # 64 tokens = 4 pages
    r0 = fe.submit(list(prompt), 4, session_key="a")
    fe.run_until_idle()
    home = fe.replica_of(r0)
    key = fe.engines[home].radix_key_for(prompt)
    matched, owners = fe.directory.lookup(key)
    assert matched > 0 and owners == {home}
    assert fe.directory.owned_by(home) > 0
    # draining the tree invalidates every prefix the replica owned
    fe.engines[home].kv.evict_prefixes()
    assert fe.engines[home].kv.radix.n_nodes() == 0
    assert fe.directory.lookup(key) == (0, None)
    assert fe.directory.owned_by(home) == 0
    assert fe.directory.invalidations > 0


def test_migration_conserves_pages_and_refcounts(cluster_setup):
    """A forced migration (gap -1: any queued owner loses) grafts the
    prefix on the receiver with both replicas' ledgers intact: donor pages
    untouched, receiver pages tree-owned (refcount 1), every region
    released when sessions close and both trees drain."""
    full, cfg, params = cluster_setup
    engines = [_mk_engine(full, cfg, params) for _ in range(2)]
    fe = ClusterFrontend(engines, migrate_prefixes=True, migrate_load_gap=-1)
    prompt = list(range(2, 66))
    r0 = fe.submit(list(prompt), 4, session_key="a")
    fe.run_until_idle()
    home = fe.replica_of(r0)
    other = 1 - home
    donor_pages = {id(p) for n in engines[home].kv.radix.nodes()
                   for p in n.pages}
    r1 = fe.submit(list(prompt) + [400], 4, session_key="b")
    assert fe.replica_of(r1) == other          # migrated, request followed
    assert fe.migrations == 1
    assert fe.migration_bytes > 0 and fe.migration_s > 0
    assert engines[other].kv.radix_stats.adopted_pages > 0
    # donor's pages were copied, not moved: same objects, refcount intact
    assert {id(p) for n in engines[home].kv.radix.nodes()
            for p in n.pages} == donor_pages
    for n in engines[home].kv.radix.nodes():
        for p in n.pages:
            assert p.refcount >= 1
    # receiver's adopted pages are distinct objects, tree-owned
    adopted = [p for n in engines[other].kv.radix.nodes() for p in n.pages]
    assert donor_pages.isdisjoint({id(p) for p in adopted})
    fe.run_until_idle()
    # the migrated request arrived as a real cross-replica hit, and the
    # scheduler counted its grafted prefix as an admission match
    assert engines[other].kv.prefix_hits_migrated >= 1
    assert engines[other].sched.stats.migrated_admissions >= 1
    # full teardown: every region on both replicas goes back
    for e in engines:
        e.kv.evict_prefixes()
        assert e.kv.radix.n_nodes() == 0
        assert e.kv.live_pages() == 0
        assert e.mem.devices["mrm"].alloc.utilization == 0.0
    # directory forgot both replicas
    key = engines[0].radix_key_for(prompt)
    assert fe.directory.lookup(key) == (0, None)


@pytest.fixture(scope="module")
def f32_setup():
    full = get_config("deepseek-7b")
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    from repro.models import init_params
    return full, cfg, init_params(cfg, jax.random.key(0))


def test_migrated_hit_decodes_identically_to_cold_start(f32_setup):
    """Acceptance: a migrated hit (receiver seeded from the donor's
    transferred snapshot, prefill extended from the boundary) decodes the
    exact tokens a never-saw-the-prefix cold engine decodes."""
    full, cfg, params = f32_setup
    rng = np.random.default_rng(17)
    shared = list(rng.integers(2, 400, 48))
    borrower = shared + list(rng.integers(2, 400, 8))

    engines = [_mk_engine(full, cfg, params) for _ in range(2)]
    fe = ClusterFrontend(engines, migrate_prefixes=True, migrate_load_gap=-1)
    r0 = fe.submit(shared + list(rng.integers(2, 400, 8)), 6, session_key="a")
    fe.run_until_idle()
    home = fe.replica_of(r0)
    r1 = fe.submit(list(borrower), 6, session_key="b")
    fe.run_until_idle()
    assert fe.replica_of(r1) == 1 - home       # served off the migrated copy
    target = fe.engines[1 - home]
    assert target.kv.prefix_hits_migrated >= 1
    assert target.prefill_tokens_skipped > 0   # compute actually donated

    cold = _mk_engine(full, cfg, params)
    cold.submit(list(borrower), 6)
    cold.run_until_idle()
    assert fe.output(r1) == cold.outputs[0]


def test_load_tiebreak_counts_directory_owned_prefix_bytes(cluster_setup):
    """Bugfix: a replica stuffed with pinned shared prefixes (radix-tree
    resident, no live sessions) must lose least-loaded ties to a really
    empty replica."""
    full, cfg, params = cluster_setup
    engines = [_mk_engine(full, cfg, params) for _ in range(2)]
    fe = ClusterFrontend(engines)
    # replica 0 serves (and registers) a prompt; no sessions stay live
    fe.submit(list(range(2, 66)), 4)
    fe.run_until_idle()
    assert engines[0].kv.radix_kv_bytes() > 0
    assert engines[0].kv.live_kv_bytes() == 0
    # equal queue lengths, but 0 holds hot-prefix KV -> 1 wins the tie
    assert fe.route() == 1
    engines[0].kv.evict_prefixes()
    assert fe.route() == 0                     # bytes gone -> index order


def test_snapshot_bytes_metered_against_kv_tier(cluster_setup):
    """ROADMAP satellite: donor ring-cache snapshots are carved from the
    KV tier budget (metered region write), reported as snapshot_bytes,
    and released when their radix node leaves the tree."""
    full, cfg, params = cluster_setup
    eng = _mk_engine(full, cfg, params)
    util0 = eng.mem.devices["mrm"].alloc.utilization
    eng.submit(list(range(2, 66)), 4)
    eng.run_until_idle()
    rep = eng.report()
    assert rep["snapshot_bytes"] > 0
    assert rep["prefix"]["snapshots_published"] >= 1
    assert eng.mem.devices["mrm"].alloc.utilization > util0
    # eviction releases the snapshot region with the node
    eng.kv.evict_prefixes()
    assert eng.live_snapshot_bytes() == 0
    assert eng.mem.devices["mrm"].alloc.utilization == 0.0


def test_adopt_prefix_partial_under_pressure_keeps_ledger_balanced():
    """Adoption into a nearly-full tier truncates at a page boundary
    (optional transfer: no unresolved pressure events), and what was
    adopted is tree-owned and releasable."""
    from repro.serving import PagedKVManager
    cfg = get_config("qwen3-8b")
    mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 22), "hbm": (HBM3E, 1 << 30)})
    kv = PagedKVManager(cfg, mem, "mrm", page_tokens=4,
                        policy="evict-lru")
    tokens = list(range(400))                 # ~100 pages, way over capacity
    new_tok, total, node = kv.adopt_prefix(tokens)
    assert 0 < new_tok < 400 and new_tok % 4 == 0
    assert total == new_tok and node is not None
    assert kv.pressure.unresolved == 0 and kv.pressure.events == 0
    assert all(p.refcount == 1 for n in kv.radix.nodes() for p in n.pages)
    # a second adoption of the same path is a no-op (already held)
    new2, total2, _ = kv.adopt_prefix(tokens[:new_tok])
    assert new2 == 0 and total2 == new_tok
    kv.evict_prefixes()
    assert kv.radix.n_nodes() == 0
    assert mem.devices["mrm"].alloc.utilization == 0.0


def test_snapshot_handle_release_is_idempotent():
    mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 30)})
    rid = mem.write_region("mrm", "snap", 1024, expected_lifetime_s=1.0)
    h = SnapshotHandle(caches=None, nbytes=1024.0, mem=mem, region_id=rid)
    assert h.live
    h.release()
    assert not h.live
    h.release()                                # no double-free
    assert mem.devices["mrm"].alloc.utilization == 0.0


def test_migration_admission_control_queues_on_busy_link(cluster_setup):
    """ROADMAP satellite: each receiver has ONE modelled interconnect
    link. Two migrations pulled into the same replica within one submit
    burst serialize on it — the second finds the link busy and reports a
    nonzero queue wait, and the receiver's clock ends at the delivery
    time of the last transfer (both TTFTs pay)."""
    full, cfg, params = cluster_setup
    engines = [_mk_engine(full, cfg, params) for _ in range(2)]
    fe = ClusterFrontend(engines, migrate_prefixes=True, migrate_load_gap=-1)
    p1 = list(range(2, 66))                    # two distinct 4-page prefixes
    p2 = list(range(200, 264))
    r0 = fe.submit(list(p1), 4, session_key="seed")
    fe.submit(list(p2), 4, session_key="seed")  # sticky: same home replica
    fe.run_until_idle()
    home = fe.replica_of(r0)
    other = 1 - home
    # pile queued work on the owner so both borrowers out-migrate to the
    # idle replica within ONE burst (no cluster step between submits)
    for i in range(3):
        engines[home].submit(list(range(400 + i, 440 + i)), 2)
    t0 = engines[other].mem.now
    b1 = fe.submit(p1 + [300], 4, session_key="b1")
    b2 = fe.submit(p2 + [301], 4, session_key="b2")
    assert fe.replica_of(b1) == other and fe.replica_of(b2) == other
    assert fe.migrations == 2
    # the second transfer queued behind the first on the receiver's link
    assert fe.migrations_queued >= 1
    assert fe.migration_queue_wait_s > 0
    rep_done = fe.run_until_idle()
    inter = rep_done["interconnect"]
    assert inter["queued_migrations"] == fe.migrations_queued
    assert inter["queue_wait_s"] == pytest.approx(fe.migration_queue_wait_s)
    # the receiver stalled to the serialized delivery time: transfer
    # durations + the queue wait all passed through its clock
    assert engines[other].mem.now - t0 >= (
        inter["migration_s"] + inter["queue_wait_s"]) - 1e-9
    # and the work still decodes: every request finished
    assert rep_done["finished"] == 7


def test_directory_shard_counters_prove_load_balance():
    """ISSUE 10 satellite: the hash-sharded directory spreads digest keys
    across every shard (Fibonacci mixing on the page digests), keeps the
    per-shard lookup/update counters, and batches invalidations as deltas
    (``delta_batches <= delta_ops``: O(changes) mutations, not a
    per-prefix broadcast)."""
    d = PrefixDirectory(page_tokens=4, n_shards=8)
    rng = np.random.default_rng(0)
    paths = [list(rng.integers(0, 1000, 16)) for _ in range(200)]
    for i, path in enumerate(paths):
        d.register(i % 4, path)
        d.lookup(path)
    c = d.shards.shard_counters()
    assert c["n_shards"] == 8
    assert sum(c["entries"]) == d.n_entries()
    assert min(c["entries"]) > 0, f"idle shard: {c['entries']}"
    mean = sum(c["entries"]) / c["n_shards"]
    assert max(c["entries"]) < 3 * mean, f"shard hot spot: {c['entries']}"
    assert min(c["lookups"]) > 0 and sum(c["updates"]) > 0
    assert 0 < c["delta_batches"] <= c["delta_ops"]
    # delta invalidation drains exactly what was registered
    for i, path in enumerate(paths):
        d.invalidate(i % 4, path, tail_tokens=len(path))
    assert d.n_entries() == 0
    assert all(n == 0 for n in d.shards.shard_counters()["entries"])


def _replication_sequence(fe, prompt):
    """Seed one owner, then three extension hits: the second crosses a
    replicate_threshold of 2 and pushes toward the non-owners. In
    lockstep mode the first push makes the donor's up-link hot, so the
    same-instant second copy *defers* (admission control, no retry loop
    outside the event plane) — the third hit re-crosses the threshold on
    a cold fabric and fills the remaining copy."""
    fe.submit(list(prompt), 4, session_key="seed")
    fe.run_until_idle()
    for i, key in enumerate(("h1", "h2", "h3")):
        fe.submit(prompt + [301 + i], 4, session_key=key)
        rep = fe.run_until_idle()
    return rep


def test_predictive_replication_accounting_zero_imbalance(cluster_setup):
    """ISSUE 10 satellite: a speculative push meters its bytes exactly
    once on the fabric and exactly once into the receiver's tier — the
    fabric byte ledger equals demand migration bytes + replication bytes
    with zero imbalance, and the replicas' page ledgers still drain."""
    full, cfg, params = cluster_setup
    engines = [_mk_engine(full, cfg, params) for _ in range(3)]
    fe = ClusterFrontend(engines, migrate_prefixes=True,
                         migrate_load_gap=100,     # no demand migrations
                         replicate_threshold=2, replicate_copies=2)
    prompt = list(range(2, 66))
    rep = _replication_sequence(fe, prompt)
    home = fe.replica_of(min(fe.requests))         # the seed's owner
    inter = rep["interconnect"]
    assert fe.replications == 2                    # both non-owners warmed
    assert inter["replications"] == 2
    assert inter["replication_bytes"] > 0
    assert inter["replicated_tokens"] == 2 * 64
    assert inter["migrations"] == 0                # speculative != demand
    # the invariant: every fabric byte is one demand or speculative byte
    imbalance = fe.fabric.bytes_total - (fe.migration_bytes
                                         + fe.replication_bytes)
    assert imbalance == pytest.approx(0.0)
    assert rep["fabric"]["bytes"] == fe.fabric.bytes_total
    # the receivers really adopted the pages (tier write metered once)
    key = engines[0].radix_key_for(prompt)
    matched, owners = fe.directory.lookup(key)
    assert matched == 64 and owners == {0, 1, 2}
    for i, e in enumerate(engines):
        if i != home:
            assert e.kv.radix_stats.adopted_pages > 0, f"replica {i}"
    # teardown releases every adopted copy on every replica
    for e in engines:
        e.kv.evict_prefixes()
        assert e.kv.live_pages() == 0
        assert e.mem.devices["mrm"].alloc.utilization == 0.0
    assert fe.directory.lookup(key) == (0, None)


def test_event_mode_replication_matches_lockstep(cluster_setup):
    """The REPLICATION_PUSH event path delivers the same copies the
    lockstep path does — same replication count, same decoded tokens,
    same balanced fabric ledger — with pushes recorded in the trace."""
    full, cfg, params = cluster_setup

    def run_one(clock_mode):
        engines = [_mk_engine(full, cfg, params) for _ in range(3)]
        fe = ClusterFrontend(engines, migrate_prefixes=True,
                             migrate_load_gap=100, clock_mode=clock_mode,
                             record_trace=True,
                             replicate_threshold=2, replicate_copies=2)
        rep = _replication_sequence(fe, list(range(2, 66)))
        outs = {k: list(fe.output(r)) for k, r in
                zip(("seed", "h1", "h2", "h3"), sorted(fe.requests))}
        return fe, rep, outs

    fe_l, rep_l, outs_l = run_one("lockstep")
    fe_e, rep_e, outs_e = run_one("event")
    assert outs_l == outs_e, "replication changed decoded tokens"
    assert fe_e.replications == fe_l.replications == 2
    assert rep_e["interconnect"]["replication_bytes"] == pytest.approx(
        rep_l["interconnect"]["replication_bytes"])
    for fe in (fe_l, fe_e):
        assert fe.fabric.bytes_total == pytest.approx(
            fe.migration_bytes + fe.replication_bytes)
    assert fe_e.trace.n_events > 0


def test_fleet_report_interconnect_and_directory_sections(cluster_setup):
    full, cfg, params = cluster_setup
    fe = ClusterFrontend([_mk_engine(full, cfg, params) for _ in range(2)],
                         migrate_prefixes=True, migrate_load_gap=-1)
    fe.submit(list(range(2, 66)), 4, session_key="a")
    fe.run_until_idle()
    r1 = fe.submit(list(range(2, 66)) + [401], 4, session_key="b")
    rep = fe.run_until_idle()
    inter = rep["interconnect"]
    assert inter["migrations"] == 1
    assert inter["migration_bytes"] > 0
    assert inter["migration_s"] == pytest.approx(
        inter["migration_bytes"] / (inter["gbps"] * 1e9))
    # the request that triggered (and waited for) the transfer pays it:
    # its TTFT includes the interconnect time
    replica, local = fe.requests[r1]
    rec = next(r for r in fe.engines[replica].sched.latency
               if r["request_id"] == local)
    assert rec["ttft"] >= inter["migration_s"]
    assert rep["directory"]["entries"] > 0
    assert rep["directory"]["registrations"] > 0
    assert rep["prefix_hits_migrated"] >= 1
    assert rep["snapshot_bytes"] >= 0
