"""Scenario zoo smoke gates (DESIGN.md §12, experiments/): every family
drains to quiescence with tail SLOs over a non-empty finished population
and a balanced pressure ledger — the same gates the CI `fleet-scenarios`
job enforces — plus the trajectory-persistence dedupe contract for
``BENCH_fleet.json``.
"""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.core.trajectory import persist_trajectory

from experiments.run_fleet import gate, run_scenario
from experiments.scenarios import SCENARIOS, build


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_smoke_meets_gates(name):
    entry = run_scenario(name, "smoke")
    gate(entry)                       # quiesced, SLO p99 present, ledger 0
    s = entry["sessions"]
    assert s["finished"] + s["abandoned"] == s["submitted"] == entry[
        "submitted"]
    assert entry["trace"]["n_events"] > 0
    assert len(entry["trace"]["digest"]) == 40
    for metric in ("ttft", "itl"):
        slo = entry["slo"][metric]
        assert slo["p50"] <= slo["p95"] <= slo["p99"]


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_presets_and_ownership(name):
    """Each family publishes smoke + default presets, and the scenario —
    not the caller — owns every piece of randomness and fleet shape."""
    presets = SCENARIOS[name].presets()
    assert {"smoke", "default"} <= set(presets)
    sc = build(name, "smoke")
    assert sc.n_replicas >= 1 and sc.sessions >= 1
    fleet = sc.fleet()
    assert fleet.n_replicas == sc.n_replicas


def test_abandonment_scenario_actually_abandons():
    entry = run_scenario("abandonment", "smoke")
    assert entry["sessions"]["abandoned"] > 0
    assert entry["sessions"]["finished"] > 0, \
        "SLO gate needs a finished population even under shedding"


def test_long_doc_scenario_exercises_pressure_plane():
    entry = run_scenario("long_doc", "smoke")
    p = entry["pressure"]
    assert p["events"] > 0, "long_doc is sized to overflow the warm tier"
    assert p["unresolved"] == 0 and p["ledger_imbalance"] == 0


def test_diurnal_scenario_exercises_retention_decay():
    entry = run_scenario("diurnal", "smoke")
    assert entry["retention"]["decayed_bytes"] > 0, \
        "diurnal lulls are sized to outlive the cold TTL"


def test_agentic_scenario_is_closed_loop_and_deterministic():
    """ISSUE 10 satellite: agent follow-up calls re-arrive off the
    *completion* time of the previous call (think time added to
    finished_at, not a pre-scheduled open-loop timeline), and the chain
    stays bit-deterministic — think times are pre-drawn in generation
    order, so the RNG stream never depends on completion order."""
    a = run_scenario("agentic", "smoke")
    b = run_scenario("agentic", "smoke")
    assert a["trace"]["digest"] == b["trace"]["digest"]
    assert a["fleet"]["chained_submits"] > 0, \
        "agentic follow-ups were not chained off completions"
    # every chained follow-up was really submitted and drained
    s = a["sessions"]
    assert s["finished"] + s["abandoned"] == s["submitted"]
    assert a["quiesced"]


def test_rag_storm_heralds_lead_the_burst():
    """The herald queries precede the fan-out by lead_s, giving the
    predictive replicator (DESIGN.md §13) a signal before the burst."""
    import random as _random

    sc = build("rag_storm", "smoke")
    assert sc.heralds >= 1 and sc.lead_s > 0
    reqs = list(sc.generate(_random.Random(sc.seed)))
    by_group = {}
    for r in reqs:
        by_group.setdefault(r.group, []).append(r.arrival_s)
    for times in by_group.values():
        assert len(times) == sc.heralds + sc.fanout
        burst_start = min(times[sc.heralds:])
        assert burst_start - times[sc.heralds - 1] >= sc.lead_s - 1e-9


def test_unknown_scenario_and_preset_fail_loudly():
    with pytest.raises(ValueError, match="unknown scenario"):
        build("no-such-family", "smoke")
    with pytest.raises(ValueError, match="preset"):
        build("bursty", "no-such-preset")


# ---------------------------------------------------------------------------
# BENCH_fleet.json persistence
# ---------------------------------------------------------------------------


def _entry(**kw):
    base = {"scenario": "bursty/smoke", "seed": 1, "wall_s": 0.5,
            "events_per_s": 1000, "reuse": 0.7}
    base.update(kw)
    return base


def test_persist_trajectory_dedupes_wall_clock_noise(tmp_path):
    ignore = ("at", "wall_s", "events_per_s")
    assert persist_trajectory("B.json", _entry(), key="scenario",
                              root=str(tmp_path), ignore=ignore)
    # identical metrics, different wall clock -> deduplicated away
    assert not persist_trajectory(
        "B.json", _entry(wall_s=9.9, events_per_s=3), key="scenario",
        root=str(tmp_path), ignore=ignore)
    # a metric change appends
    assert persist_trajectory("B.json", _entry(reuse=0.8), key="scenario",
                              root=str(tmp_path), ignore=ignore)
    # a different scenario key never dedupes against this one
    assert persist_trajectory("B.json", _entry(scenario="diurnal/smoke"),
                              key="scenario", root=str(tmp_path),
                              ignore=ignore)
    data = json.loads((tmp_path / "B.json").read_text())
    assert len(data["entries"]) == 3
    assert all("at" in e for e in data["entries"])
