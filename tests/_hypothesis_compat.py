"""Graceful degradation when `hypothesis` isn't installed: property tests
skip (with a clear reason) instead of erroring the whole module at
collection, so the deterministic tests in the same file still run.

Usage in test modules:

    from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when dep is absent
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for `strategies`: any strategy call returns None, so
        module-level `@given(st.lists(...))` decorations still evaluate."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        def deco(_fn):
            @pytest.mark.skip(reason="hypothesis not installed "
                                     "(pip install -r requirements-dev.txt)")
            def _skipped():
                pass
            _skipped.__name__ = _fn.__name__
            _skipped.__doc__ = _fn.__doc__
            return _skipped
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn
