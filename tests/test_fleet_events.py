"""Determinism + invariant harness for the event-driven fleet plane
(DESIGN.md §12): content-derived event ordering, bit-identical trace
hashes across reruns *and* across tie-break insertion shuffles, and the
conservation invariants (token/page/refcount ledgers, abandonment never
leaks, pinned prefixes never decay while referenced) checked after every
processed event. Pure-python analytic simulator — no jax, runs in
milliseconds.
"""
import random
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.serving.events import (Event, EventKind, EventQueue, EventTrace,
                                  NonQuiescentError)
from repro.serving.fleet_sim import FleetConfig, FleetRequest, FleetSim

from experiments.scenarios import build


# ---------------------------------------------------------------------------
# EventQueue / EventTrace primitives
# ---------------------------------------------------------------------------


def _event_soup(rng, n=200):
    """Events with heavy timestamp collisions to stress the tie-breaks."""
    return [Event(time=rng.choice([0.0, 1.0, 1.0, 2.5]),
                  kind=rng.choice(list(EventKind)),
                  replica=rng.randrange(4),
                  key=rng.randrange(8),
                  info=(i,))
            for i in range(n)]


def test_event_queue_pop_order_is_content_derived():
    rng = random.Random(7)
    events = _event_soup(rng)
    reference = None
    for shuffle_seed in range(5):
        shuffled = list(events)
        random.Random(shuffle_seed).shuffle(shuffled)
        q = EventQueue()
        for ev in shuffled:
            q.push(ev)
        order = [ev.sort_key for ev in q.drain()]
        assert order == sorted(order), "pop order not sorted by sort_key"
        if reference is None:
            reference = order
        assert order == reference, \
            f"insertion shuffle {shuffle_seed} changed pop order"


def test_event_queue_rejects_scheduling_in_the_past():
    q = EventQueue()
    q.push(Event(5.0, EventKind.ARRIVAL, 0))
    q.pop()
    assert q.last_time == 5.0
    with pytest.raises(ValueError, match="past"):
        q.push(Event(4.0, EventKind.STEP, 0))


def test_trace_digest_reflects_event_content():
    a, b = EventTrace(), EventTrace()
    ev = Event(1.0, EventKind.ARRIVAL, 0, key=3, info=("x",))
    a.add(ev)
    b.add(ev)
    assert a.digest() == b.digest()
    b.add(Event(1.0, EventKind.ARRIVAL, 0, key=4))
    assert a.digest() != b.digest()
    assert b.n_events == 2


# ---------------------------------------------------------------------------
# FleetSim determinism
# ---------------------------------------------------------------------------


def _mk_requests(n=300, groups=6, seed=0, abandon_after_s=None,
                 max_new=64):
    rng = random.Random(seed)
    t = 0.0
    reqs = []
    for i in range(n):
        t += rng.expovariate(2000.0)
        reqs.append(FleetRequest(
            session_key=i, group=rng.randrange(groups),
            shared_tokens=512, unique_tokens=rng.randrange(32, 256),
            max_new_tokens=rng.randrange(8, max_new), arrival_s=t,
            abandon_after_s=abandon_after_s))
    return reqs


def _small_cfg(**kw):
    base = dict(n_replicas=2, slots_per_replica=4, max_prefills_per_round=4)
    base.update(kw)
    return FleetConfig(**base)


def _run(reqs, cfg=None):
    sim = FleetSim(cfg or _small_cfg())
    for r in reqs:
        sim.submit(r)
    report = sim.run()
    sim.check()
    return sim, report


def test_same_seed_runs_are_bit_identical():
    _, rep_a = _run(_mk_requests())
    _, rep_b = _run(_mk_requests())
    assert rep_a["trace"]["digest"] == rep_b["trace"]["digest"]
    assert rep_a == rep_b


def test_submission_order_shuffle_is_bit_identical():
    """The determinism satellite: the event queue orders by content, so
    submitting the same request set in any order replays identically."""
    reqs = _mk_requests()
    _, rep_a = _run(reqs)
    for shuffle_seed in (1, 2):
        shuffled = list(reqs)
        random.Random(shuffle_seed).shuffle(shuffled)
        _, rep_b = _run(shuffled)
        assert rep_b["trace"]["digest"] == rep_a["trace"]["digest"]


def test_scenario_smoke_digest_is_stable_across_runs():
    def one():
        sc = build("bursty", "smoke")
        sim = FleetSim(sc.fleet())
        for req in sc.generate(random.Random(sc.seed)):
            sim.submit(req)
        return sim.run()["trace"]["digest"]
    assert one() == one()


# ---------------------------------------------------------------------------
# Invariants at every event boundary
# ---------------------------------------------------------------------------


def _drive_checked(sim, extra_check=None):
    """FleetSim.run() with sim.check() (and an optional extra invariant)
    asserted after *every* processed event, not just at quiescence."""
    while sim.queue:
        ev = sim.queue.pop()
        sim.trace.add(ev)
        getattr(sim, sim._HANDLERS[ev.kind])(ev)
        sim.check()
        if extra_check is not None:
            extra_check(sim)
    return sim.report(quiesced=True)


def test_conservation_holds_after_every_event():
    sim = FleetSim(_small_cfg())
    for r in _mk_requests(n=120):
        sim.submit(r)
    rep = _drive_checked(sim)
    assert rep["sessions"]["finished"] == 120
    assert rep["pressure"]["ledger_imbalance"] == 0


def test_per_replica_timestamps_are_monotonic():
    sim = FleetSim(_small_cfg(record_trace=True))
    for r in _mk_requests(n=120):
        sim.submit(r)
    sim.run()
    last = {}
    for (t, kind, replica, key, info) in sim.trace.events:
        assert t >= last.get(replica, 0.0), \
            f"replica {replica} clock ran backwards at {t}"
        last[replica] = t
    assert sim.trace.n_events == len(sim.trace.events)


def test_abandonment_never_leaks():
    """Every submitted session ends finished or abandoned; abandoned
    sessions release all hot bytes and pins (checked every event)."""
    sim = FleetSim(_small_cfg(slots_per_replica=2))
    reqs = _mk_requests(n=200, abandon_after_s=0.02, max_new=128)
    for r in reqs:
        sim.submit(r)
    rep = _drive_checked(sim)
    s = rep["sessions"]
    assert s["finished"] + s["abandoned"] == s["submitted"] == 200
    assert s["abandoned"] > 0, "scenario was supposed to shed load"
    assert rep["pending_sessions"] == 0
    for sess in sim.sessions.values():
        if sess.phase == "abandoned":
            assert sess.hot_bytes == 0.0 and sess.pinned_group < 0


def test_pinned_prefix_never_decays_while_referenced():
    """cold_ttl shorter than any decode: decay sweeps fire mid-flight but
    a pinned (actively referenced) group must survive every sweep."""
    def pins_resolve(sim):
        for rep in sim.replicas:
            for sess in rep.active.values():
                if sess.pinned_group >= 0:
                    assert sess.pinned_group in rep.groups, \
                        f"pinned group {sess.pinned_group} decayed"

    sim = FleetSim(_small_cfg(cold_ttl_s=0.005))
    for r in _mk_requests(n=150, groups=3, max_new=96):
        sim.submit(r)
    rep = _drive_checked(sim, extra_check=pins_resolve)
    assert rep["sessions"]["finished"] == 150
    assert rep["retention"]["decayed_bytes"] > 0, \
        "ttl was supposed to trigger decay sweeps"


def test_non_quiescent_raise_and_report():
    sim = FleetSim(_small_cfg())
    for r in _mk_requests(n=50):
        sim.submit(r)
    with pytest.raises(NonQuiescentError, match="not quiescent") as ei:
        sim.run(max_events=10)
    assert ei.value.report["quiesced"] is False

    sim2 = FleetSim(_small_cfg())
    for r in _mk_requests(n=50):
        sim2.submit(r)
    rep = sim2.run(max_events=10, on_stall="report")
    assert rep["quiesced"] is False and rep["pending_events"] > 0
    # the budget is a checkpoint, not a wall: the drain can resume
    rep = sim2.run()
    assert rep["quiesced"] is True and rep["pending_sessions"] == 0


# ---------------------------------------------------------------------------
# Property suite (skips cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5),        # group
                          st.integers(1, 300),      # unique tokens
                          st.integers(1, 64),       # max new tokens
                          st.floats(0.0, 0.5)),     # inter-arrival gap
                min_size=1, max_size=60),
       st.one_of(st.none(), st.floats(0.001, 0.1)))
def test_property_conservation_any_workload(specs, abandon):
    t = 0.0
    sim = FleetSim(_small_cfg(slots_per_replica=2))
    for i, (group, unique, max_new, gap) in enumerate(specs):
        t += gap
        sim.submit(FleetRequest(session_key=i, group=group,
                                shared_tokens=256, unique_tokens=unique,
                                max_new_tokens=max_new, arrival_s=t,
                                abandon_after_s=abandon))
    rep = _drive_checked(sim)
    s = rep["sessions"]
    assert s["finished"] + s["abandoned"] == len(specs)
    assert rep["pressure"]["ledger_imbalance"] == 0


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_shuffle_invariance_any_seed(seed):
    reqs = _mk_requests(n=40, seed=seed)
    _, rep_a = _run(reqs)
    shuffled = list(reqs)
    random.Random(seed ^ 0xA5A5).shuffle(shuffled)
    _, rep_b = _run(shuffled)
    assert rep_a["trace"]["digest"] == rep_b["trace"]["digest"]
