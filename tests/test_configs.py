"""Config registry + analytic parameter-count sanity for all 10 archs."""
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.configs.shapes import LONG_CONTEXT_OK, LONG_CONTEXT_SKIP, SHAPES, cells

# published parameter counts (approx, in billions) for sanity bands
EXPECTED_B = {
    "mamba2-2.7b": (2.2, 3.2),
    "deepseek-7b": (6.0, 8.0),
    "gemma-2b": (2.0, 3.3),        # incl. 256k vocab embeddings
    "qwen3-8b": (7.0, 9.0),
    "gemma2-27b": (24.0, 30.0),
    "mixtral-8x22b": (130.0, 150.0),
    "deepseek-v2-lite-16b": (13.0, 17.5),
    "musicgen-large": (1.5, 4.0),
    "hymba-1.5b": (1.2, 2.0),
    "internvl2-76b": (62.0, 80.0),  # backbone only (ViT is stubbed)
}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_registered_and_valid(arch):
    cfg = get_config(arch)
    cfg.validate()
    assert cfg.name == arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_counts_in_band(arch):
    cfg = get_config(arch)
    n = cfg.param_counts()["total"] / 1e9
    lo, hi = EXPECTED_B[arch]
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_scan_groups_cover_all_layers(arch):
    cfg = get_config(arch)
    groups = cfg.scan_groups()
    assert sum(g.num_layers for g in groups) == cfg.num_layers
    # specs reconstructed from groups match layer_specs order
    flat = []
    for g in groups:
        for _ in range(g.repeats):
            flat.extend(g.unit)
    assert tuple(flat) == cfg.layer_specs()


def test_gemma2_alternating_pattern():
    cfg = get_config("gemma2-27b")
    specs = cfg.layer_specs()
    assert specs[0].window == 4096 and specs[1].window is None
    groups = cfg.scan_groups()
    assert len(groups) == 1 and len(groups[0].unit) == 2 and groups[0].repeats == 23


def test_deepseek_v2_first_dense():
    cfg = get_config("deepseek-v2-lite-16b")
    specs = cfg.layer_specs()
    assert specs[0].mlp == "dense" and all(s.mlp == "moe" for s in specs[1:])


def test_hymba_global_layers():
    cfg = get_config("hymba-1.5b")
    specs = cfg.layer_specs()
    for i, s in enumerate(specs):
        assert (s.window is None) == (i in (0, 15, 31))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_configs_valid(arch):
    r = reduced(get_config(arch))
    r.validate()
    assert r.d_model <= 256 and r.num_layers <= 4


def test_cell_enumeration():
    cs = list(cells(ASSIGNED_ARCHS))
    assert len(cs) == 34  # 10 archs x 3 shapes + 4 long_500k
    for a in LONG_CONTEXT_OK:
        assert (a, "long_500k") in cs
    for a in LONG_CONTEXT_SKIP:
        assert (a, "long_500k") not in cs


def test_kv_bytes_per_token_matches_paper_scale():
    # paper/Splitwise reference: llama2-70b ~0.32 MB/token at fp16
    cfg = get_config("llama2-70b")
    assert 2.5e5 < cfg.kv_bytes_per_token() < 4e5
    # MLA compression: deepseek-v2-lite is ~an order of magnitude smaller
    # per layer than equivalent GQA
    v2 = get_config("deepseek-v2-lite-16b")
    per_layer = v2.kv_bytes_per_token() / v2.num_layers
    gqa_equiv = 2 * 16 * 128 * 2  # kv=16 heads of 128 at bf16
    assert per_layer < gqa_equiv / 5
