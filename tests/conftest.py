import pathlib
import sys

# tests run with PYTHONPATH=src; this makes them work without it too.
SRC = pathlib.Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))
