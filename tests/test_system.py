"""End-to-end behaviour tests for the full system: training converges,
the drivers run (incl. failure injection + resume), serving produces the
paper's workload signature."""
import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticPipeline
from repro.models import init_params
from repro.models.transformer import loss_and_metrics
from repro.optim import OptConfig, init_opt_state
from repro.optim.adamw import adamw_update

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


def _env():
    import os
    e = dict(os.environ)
    e["PYTHONPATH"] = str(SRC)
    return e


@pytest.mark.parametrize("arch", ["deepseek-7b", "mamba2-2.7b", "mixtral-8x22b"])
def test_training_overfits_fixed_batch(arch):
    """The whole train stack (model + loss + AdamW) must drive loss to ~0
    on a memorization task — catches gradient bugs across families."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=300, weight_decay=0.0)
    opt = init_opt_state(params)
    pipe = SyntheticPipeline(cfg, DataConfig(seq_len=64, global_batch=4))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

    @jax.jit
    def step(p, o, b):
        (l, m), g = jax.value_and_grad(
            lambda pp: loss_and_metrics(cfg, pp, b), has_aux=True)(p)
        np_, no, st = adamw_update(oc, p, g, o)
        return np_, no, l

    l0 = None
    for i in range(150):
        params, opt, l = step(params, opt, batch)
        if l0 is None:
            l0 = float(l)
    assert float(l) < l0 * 0.5, f"{arch}: {l0} -> {float(l)}"


def test_train_driver_with_failure_injection(tmp_path):
    """Driver must detect the injected failure, produce a re-mesh plan,
    checkpoint, and a resume run must pick the checkpoint up."""
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "gemma-2b",
           "--reduced", "--steps", "12", "--seq-len", "32", "--batch", "2",
           "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
           "--inject-failure-at", "6", "--log-every", "5"]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                       env=_env())
    assert r.returncode == 0, r.stderr[-2000:]
    assert "re-mesh plan" in r.stdout
    assert "checkpointed" in r.stdout
    # resume
    r2 = subprocess.run(cmd[:-4] + ["--resume", "--log-every", "5"],
                        capture_output=True, text=True, timeout=900, env=_env())
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step" in r2.stdout


def test_serve_driver_end_to_end():
    cmd = [sys.executable, "-m", "repro.launch.serve", "--arch", "gemma-2b",
           "--requests", "3", "--max-new", "6", "--slots", "2"]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                       env=_env())
    assert r.returncode == 0, r.stderr[-2000:]
    rep = json.loads(r.stdout[r.stdout.index("{"):])
    assert rep["finished"] == 3
    assert rep["steady_rw_ratio"] > 1000


def test_grad_compression_training_path():
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "deepseek-7b",
           "--reduced", "--steps", "6", "--seq-len", "32", "--batch", "2",
           "--compress", "int8", "--log-every", "2"]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                       env=_env())
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert np.isfinite(out["final_loss"])
