"""Radix prefix-tree invariants (hypothesis property tests + deterministic
manager-level refcount/pinning checks).

Invariants under arbitrary insert/match/evict sequences:

- **token conservation** — the tree holds exactly the distinct page-aligned
  prefixes inserted (one page per distinct (path, page) pair), and
  evictions remove whole leaves' tokens, never a partial page;
- **refcount consistency** — every page's refcount equals (1 if the tree
  holds it) + (number of live sessions holding it); closing sessions and
  draining the tree releases every region;
- **pinned nodes are never evicted** — a live session pins its matched /
  registered path; eviction only ever removes unlocked leaves.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.memclass import HBM3E, MRM_RRAM
from repro.core.simulator import MemorySystem
from repro.serving import PagedKVManager, RadixKVIndex
from repro.serving.kv_cache import Page

PT = 4  # page_tokens for the pure-tree tests


def _mk_pages(tokens):
    return [Page(page_id=i, region_id=None, n_tokens=PT, sealed=True)
            for i in range(len(tokens) // PT)]


def _distinct_page_prefixes(seqs):
    """Ground truth: the set of (page-aligned prefix) paths a radix tree
    over `seqs` must hold — one page per distinct prefix."""
    out = set()
    for s in seqs:
        for k in range(1, len(s) // PT + 1):
            out.add(tuple(s[:k * PT]))
    return out


from _hypothesis_compat import HAS_HYPOTHESIS

if HAS_HYPOTHESIS:
    seq_strategy = st.lists(
        st.lists(st.integers(min_value=0, max_value=3), min_size=PT,
                 max_size=6 * PT).map(lambda s: s[:len(s) // PT * PT]),
        min_size=1, max_size=12)
else:  # shim: @given skips these tests; the strategy is never drawn
    seq_strategy = None


@given(seq_strategy)
@settings(max_examples=60, deadline=None)
def test_radix_insert_conserves_tokens_and_match_is_exact(seqs):
    seqs = [s for s in seqs if s]
    tree = RadixKVIndex(PT)
    for t, s in enumerate(seqs):
        tree.insert(s, _mk_pages(s), now=float(t))
    want = _distinct_page_prefixes(seqs)
    # one page per distinct page-aligned prefix; tokens conserved
    assert tree.total_pages() == len(want)
    assert tree.total_tokens() == PT * len(want)
    # match_len returns the longest inserted page-aligned prefix, exactly
    for s in seqs:
        probe = list(s) + [7]  # diverging tail never extends the match
        got = tree.match_len(probe)
        truth = max((len(p) for p in want
                     if tuple(probe[:len(p)]) == p), default=0)
        assert got == truth


@given(seq_strategy)
@settings(max_examples=40, deadline=None)
def test_radix_evict_drains_tree_and_conserves_pages(seqs):
    seqs = [s for s in seqs if s]
    tree = RadixKVIndex(PT)
    held = []
    for t, s in enumerate(seqs):
        _, inserted, _ = tree.insert(s, _mk_pages(s), now=float(t))
        held += inserted
    evicted_pages = []
    while True:
        leaf = tree.pop_lru_leaf()
        if leaf is None:
            break
        evicted_pages += leaf.pages
        # a leaf eviction removes whole pages, never splits one
        assert leaf.n_tokens == PT * len(leaf.pages)
    assert tree.n_nodes() == 0 and tree.total_tokens() == 0
    # every page the tree held came back out exactly once
    assert sorted(map(id, evicted_pages)) == sorted(map(id, held))


@given(seq_strategy, st.integers(min_value=0, max_value=11))
@settings(max_examples=40, deadline=None)
def test_radix_locked_paths_survive_full_eviction(seqs, pin_idx):
    seqs = [s for s in seqs if s]
    tree = RadixKVIndex(PT)
    for t, s in enumerate(seqs):
        tree.insert(s, _mk_pages(s), now=float(t))
    pinned = seqs[pin_idx % len(seqs)]
    m = tree.match(pinned, now=100.0)
    tree.lock(m.node)
    pinned_tokens = m.tokens
    while tree.pop_lru_leaf() is not None:
        pass
    # the pinned path (and nothing below it) survives
    assert tree.total_tokens() == pinned_tokens
    assert tree.match_len(pinned) == pinned_tokens
    tree.unlock(m.node)
    while tree.pop_lru_leaf() is not None:
        pass
    assert tree.n_nodes() == 0


def test_radix_lru_order_and_parent_exposure():
    """Leaf-LRU: oldest unlocked leaf goes first; freeing a leaf exposes
    its parent as the next candidate."""
    tree = RadixKVIndex(PT)
    a = [1] * PT + [2] * PT
    b = [1] * PT + [3] * PT
    tree.insert(a, _mk_pages(a), now=1.0)
    tree.insert(b, _mk_pages(b), now=2.0)
    # tree: [1]*PT -> {[2]*PT, [3]*PT}; leaves are the two tails
    v1 = tree.pop_lru_leaf()
    assert v1.key == tuple([2] * PT)   # older leaf first
    v2 = tree.pop_lru_leaf()
    assert v2.key == tuple([3] * PT)
    v3 = tree.pop_lru_leaf()           # parent now a leaf
    assert v3.key == tuple([1] * PT)
    assert tree.pop_lru_leaf() is None


# ---------------------------------------------------------------------------
# Manager level: refcounts vs live sessions, pinning, region release
# ---------------------------------------------------------------------------


def _mgr(page_tokens=PT, gb=8):
    cfg = get_config("qwen3-8b")
    mem = MemorySystem({"mrm": (MRM_RRAM, gb << 30), "hbm": (HBM3E, 1 << 30)})
    return PagedKVManager(cfg, mem, "mrm", page_tokens=page_tokens), mem


def _check_refcounts(kv):
    """Every page's refcount == tree-holds-it + #sessions holding it."""
    in_tree = {id(p) for n in kv.radix.nodes() for p in n.pages}
    holds = {}
    for s in kv.sessions.values():
        for p in s.pages:
            holds[id(p)] = holds.get(id(p), 0) + 1
    pages = {id(p): p for s in kv.sessions.values() for p in s.pages}
    for n in kv.radix.nodes():
        for p in n.pages:
            pages[id(p)] = p
    for pid, p in pages.items():
        want = (1 if pid in in_tree else 0) + holds.get(pid, 0)
        assert p.refcount == want, (p, want)


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 40)),
                min_size=1, max_size=10))
@settings(max_examples=30, deadline=None)
def test_manager_refcounts_track_sessions_and_tree(ops):
    """Random open(shared-family prompt)/append/register/close traffic
    keeps page refcounts consistent with live sessions at every step, and
    full teardown releases every region."""
    kv, mem = _mgr()
    families = {f: list(range(10 * f, 10 * f + 3)) * 20 for f in range(4)}
    sid = 0
    live = []
    for fam, n_tokens in ops:
        prompt = families[fam][:max(n_tokens, 1)]
        m = kv.match_prefix(prompt)
        kv.open_session(sid, match=m)
        have = kv.sessions[sid].tokens
        if len(prompt) > have:
            kv.append_tokens(sid, len(prompt) - have)
        kv.register_prefix(sid, prompt)
        live.append(sid)
        sid += 1
        _check_refcounts(kv)
        if len(live) > 2:          # close the oldest session
            kv.close_session(live.pop(0))
            _check_refcounts(kv)
    for s in live:
        kv.close_session(s)
    _check_refcounts(kv)
    kv.evict_prefixes()
    assert kv.radix.n_nodes() == 0
    assert kv.live_pages() == 0
    # every region released: the tier's allocator is back to empty
    assert mem.devices["mrm"].alloc.utilization == 0.0


def test_manager_never_evicts_pinned_prefix():
    """A live session pins its matched path: leaf-LRU eviction (pressure
    or watermark) must never free pages under it."""
    kv, _ = _mgr()
    prompt = list(range(100, 100 + 8 * PT))
    kv.open_session(0, match=kv.match_prefix(prompt))
    kv.append_tokens(0, len(prompt))
    kv.register_prefix(0, prompt)
    # session 1 attaches the shared prefix and stays live
    m = kv.match_prefix(prompt)
    assert m.tokens > 0
    kv.open_session(1, match=m)
    kv.close_session(0)
    kv.evict_prefixes()          # drain everything evictable
    s1 = kv.sessions[1]
    assert all(p.refcount >= 1 for p in s1.pages)
    assert all(p.region_id is not None for p in s1.pages)
    assert kv.read_all(1) == s1.tokens * kv.kv_bytes_token
    kv.close_session(1)
    kv.evict_prefixes()
    assert kv.live_pages() == 0 and kv.radix.n_nodes() == 0


def test_register_moves_pin_to_deepest_node():
    """After publishing its prefix, a session pins the new leaf — its own
    freshly shared pages cannot be evicted while it lives."""
    kv, _ = _mgr()
    prompt = list(range(4 * PT))
    kv.open_session(0, match=kv.match_prefix(prompt))
    kv.append_tokens(0, len(prompt))
    kv.register_prefix(0, prompt)
    assert kv.evict_prefixes() == 0          # leaf pinned by session 0
    assert kv.radix.n_nodes() > 0
    kv.close_session(0)
    assert kv.evict_prefixes() > 0
    assert kv.radix.n_nodes() == 0


def test_match_is_page_aligned_and_capped():
    kv, _ = _mgr()
    prompt = list(range(50))                  # 12 full pages + 2 spare
    kv.open_session(0, match=kv.match_prefix(prompt))
    kv.append_tokens(0, 50)
    kv.register_prefix(0, prompt)
    m = kv.match_prefix(prompt, max_tokens=49)
    assert m.tokens == 48 and m.tokens % PT == 0
    m2 = kv.match_prefix(prompt[:11])         # partial page tail ignored
    assert m2.tokens == 8
    kv.close_session(0)


def test_multicodebook_tokens_match():
    """2-D (token, codebook) prompts radix-match like flat ones."""
    tree = RadixKVIndex(2)
    seq = np.arange(12, dtype=np.int32).reshape(6, 2)
    tree.insert(seq, [Page(i, None, 2, sealed=True) for i in range(3)],
                now=0.0)
    assert tree.match_len(seq) == 6
    div = seq.copy()
    div[4] = [99, 99]
    assert tree.match_len(div) == 4
