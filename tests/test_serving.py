"""Serving stack: paged KV manager invariants, scheduler conservation
(hypothesis), end-to-end engine runs with paper-claim validation."""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config, reduced
from repro.core.memclass import HBM3E, MRM_RRAM
from repro.core.simulator import MemorySystem
from repro.models import init_params
from repro.serving import (ClusterFrontend, ContinuousBatchScheduler,
                           EngineConfig, PagedKVManager, Request, ServeEngine)


def _mem(gb=8):
    return MemorySystem({"mrm": (MRM_RRAM, gb << 30), "hbm": (HBM3E, gb << 30)})


# ---------------------------------------------------------------------------
# Paged KV manager
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(min_value=1, max_value=300), min_size=1, max_size=20))
@settings(max_examples=30, deadline=None)
def test_paged_kv_token_accounting(appends):
    cfg = get_config("qwen3-8b")
    kv = PagedKVManager(cfg, _mem(), "mrm", page_tokens=128)
    kv.open_session(0)
    total = 0
    for n in appends:
        kv.append_tokens(0, n)
        total += n
    s = kv.sessions[0]
    assert s.tokens == total
    assert sum(p.n_tokens for p in s.pages) == total
    # every page except possibly the last is sealed exactly at page_tokens
    for p in s.pages[:-1]:
        assert p.sealed and p.n_tokens == 128
    assert s.pages[-1].n_tokens <= 128
    kv.close_session(0)
    assert kv.live_pages() == 0


def test_paged_kv_read_all_bytes():
    cfg = get_config("qwen3-8b")
    kv = PagedKVManager(cfg, _mem(), "mrm", page_tokens=64)
    kv.open_session(1)
    kv.append_tokens(1, 100)
    got = kv.read_all(1)
    assert got == 100 * cfg.kv_bytes_per_token()


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


@given(st.integers(1, 8), st.integers(1, 30), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_scheduler_conservation(slots, n_requests, max_prefills):
    """Every submitted request is eventually admitted exactly once and
    finished exactly once; slots never over-subscribe."""
    sched = ContinuousBatchScheduler(slots, max_prefills)
    for i in range(n_requests):
        sched.submit(Request(i, [1, 2, 3], 4, 0.0))
    seen = set()
    for step in range(500):
        for slot, req in sched.admissions():
            assert req.request_id not in seen
            seen.add(req.request_id)
        assert len(sched.active) <= slots
        for slot in list(sched.decode_slots()):
            req = sched.active[slot]
            req.generated += 1
            if req.generated >= req.max_new_tokens:
                sched.finish(slot, float(step))
        if sched.idle:
            break
    assert sched.idle
    assert len(seen) == n_requests
    assert sched.stats.finished == n_requests


# ---------------------------------------------------------------------------
# Engine end-to-end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_engine_setup():
    full = get_config("deepseek-7b")
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    return full, cfg, params


def test_engine_end_to_end_and_paper_claims(small_engine_setup):
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=3, max_cache_len=96,
                                   weight_tier="mrm", kv_tier="mrm",
                                   expected_session_s=5.0, eos_token=-1),
                      account_cfg=full)
    rng = np.random.default_rng(0)
    for _ in range(5):
        eng.submit(list(rng.integers(2, 400, rng.integers(6, 30))), 8)
    rep = eng.run_until_idle()
    assert rep["finished"] == 5
    assert rep["tokens_generated"] >= 5 * 8
    # paper §2.2: decode-dominated read:write >> 1000:1, sequential
    assert rep["steady_rw_ratio"] > 1000
    assert rep["memory"]["tiers"]["mrm"]["seq_fraction"] > 0.99
    assert rep["kv_live_pages"] == 0  # soft state dropped at session end


def test_engine_deterministic(small_engine_setup):
    full, cfg, params = small_engine_setup
    outs = []
    for _ in range(2):
        mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
        eng = ServeEngine(cfg, params, mem,
                          EngineConfig(max_slots=2, max_cache_len=64,
                                       weight_tier="mrm", kv_tier="mrm"),
                          account_cfg=full)
        rng = np.random.default_rng(7)
        for _ in range(3):
            eng.submit(list(rng.integers(2, 400, 12)), 6)
        eng.run_until_idle()
        outs.append({k: list(v) for k, v in eng.outputs.items()})
    assert outs[0] == outs[1]


def test_engine_refresh_fires_during_long_sessions(small_engine_setup):
    """KV pages written with short DCM retention must get refreshed while
    their session is still live."""
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=1, max_cache_len=96,
                                   weight_tier="hbm", kv_tier="mrm",
                                   expected_session_s=0.02),
                      account_cfg=full)
    # 80 decode steps x ~11.5 ms (weights stream from HBM at its own
    # bandwidth under the per-tier step-latency model) comfortably crosses
    # the DCM-floored 0.5 s refresh deadline
    eng.submit(list(np.arange(2, 34)), 80)
    rep = eng.run_until_idle()
    assert rep["memory"]["refresh_stats"]["refresh"] >= 1


# ---------------------------------------------------------------------------
# Beyond-paper features: prefix caching [53], weight redeploy wear (Fig. 1)
# ---------------------------------------------------------------------------


def test_prefix_caching_shares_pages():
    cfg = get_config("qwen3-8b")
    mem = _mem(32)
    kv = PagedKVManager(cfg, mem, "mrm", page_tokens=64)
    prompt = list(range(100, 300))        # 200 tokens
    w0 = mem.devices["mrm"].stats.write_bytes
    kv.open_session(0, match=kv.match_prefix(prompt))
    kv.append_tokens(0, 200)              # 3 sealed 64-token pages + 8 open
    kv.register_prefix(0, prompt)
    w_first = mem.devices["mrm"].stats.write_bytes - w0
    s1 = kv.open_session(1, match=kv.match_prefix(prompt))
    assert s1.shared_prefix_pages == 3 and s1.tokens == 192
    kv.append_tokens(1, 200 - s1.tokens)  # only the tail is written
    w_second = mem.devices["mrm"].stats.write_bytes - w0 - w_first
    assert w_second < w_first * 0.2
    assert kv.prefix_hits == 1 and kv.prefix_tokens_reused == 192
    # a *partial* prefix (radix, not whole-key) also matches, page-aligned
    s2 = kv.open_session(2, match=kv.match_prefix(prompt[:150]))
    assert s2.tokens == 128 and s2.shared_prefix_pages == 2
    # shared pages survive the first session's close, die with eviction
    kv.close_session(0)
    assert kv.read_all(1) == 200 * cfg.kv_bytes_per_token()
    kv.close_session(1)
    kv.close_session(2)
    assert kv.evict_prefixes() > 0        # leaf-LRU-evict the whole tree
    assert kv.live_pages() == 0 and kv.radix.n_nodes() == 0


def test_engine_prefix_caching_end_to_end(small_engine_setup):
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=2, max_cache_len=96,
                                   weight_tier="mrm", kv_tier="mrm",
                                   eos_token=-1, prefix_caching=True),
                      account_cfg=full)
    prompt = list(range(2, 70))  # 68 tokens, unpadded under prefix caching
    for _ in range(4):
        eng.submit(list(prompt), 4)
    rep = eng.run_until_idle()
    assert rep["finished"] == 4
    # the first two admissions share a step (both cold); the rest hit
    assert rep["prefix_hits"] >= 2
    assert rep["prefix_tokens_reused"] > 0
    # the hit is real in the compute plane: prefill tokens were skipped
    assert rep["prefill_tokens_skipped"] > 0
    assert rep["prefix"]["compute_hits"] >= 2
    # identical prompts must still produce identical outputs
    outs = [tuple(v) for v in eng.outputs.values()]
    assert len(set(outs)) == 1


def test_weight_redeploy_wear_accounting(small_engine_setup):
    """Fig. 1's weight-update endurance bars, measured from the system:
    each redeploy rewrites the weight region once."""
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=1, max_cache_len=64,
                                   weight_tier="mrm", kv_tier="hbm"),
                      account_cfg=full)
    w0 = mem.devices["mrm"].stats.write_bytes
    for _ in range(5):
        eng.redeploy_weights()
    # 5 full weight-region rewrites hit the device...
    assert mem.devices["mrm"].stats.write_bytes - w0 >= 5 * eng.weight_bytes
    # ...and the software wear-leveller spreads them (max/mean stays small)
    assert mem.devices["mrm"].wear.wear_ratio < 3.0
    # lifetime projection at an hourly update cadence stays > 5 years for MRM
    rate = eng.weight_bytes / 3600.0
    proj = mem.devices["mrm"].wear.project_lifetime_s(rate, 0.0)
    from repro.core.memclass import YEAR
    assert proj > 5 * YEAR


# ---------------------------------------------------------------------------
# Modality coverage: multi-codebook audio + VLM serving paths
# ---------------------------------------------------------------------------


def test_engine_multicodebook_audio():
    """musicgen-family serving: (B, 1, K) tokens, K LM heads, greedy per
    codebook."""
    full = get_config("musicgen-large")
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    mem = _mem(32)
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=2, max_cache_len=64,
                                   weight_tier="mrm", kv_tier="mrm",
                                   eos_token=-1, prefix_caching=False),
                      account_cfg=full)
    rng = np.random.default_rng(0)
    for _ in range(3):
        prompt = [list(rng.integers(0, cfg.vocab_size, cfg.n_codebooks))
                  for _ in range(12)]
        eng.submit(prompt, max_new_tokens=5)
    rep = eng.run_until_idle()
    assert rep["finished"] == 3
    assert rep["tokens_generated"] >= 15
    assert eng.last_tokens.shape[-1] == cfg.n_codebooks


# ---------------------------------------------------------------------------
# Chunked prefill: equivalence vs one-maximal-chunk prompts, and prompts
# beyond max_cache_len
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def f32_engine_setup():
    """fp32 compute keeps the (mathematically equivalent) extend path's
    greedy argmax bit-stable vs whole-prompt prefill — bf16's residual
    rounding can amplify fp32-accumulation-order differences."""
    full = get_config("deepseek-7b")
    cfg = reduced(full, dtype="float32", param_dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    return full, cfg, params


def _run_engine(full, cfg, params, chunk_tokens, prompts, max_new=8, **ecfg_kw):
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    kw = dict(max_slots=3, max_cache_len=96, weight_tier="mrm", kv_tier="mrm",
              eos_token=-1, chunk_tokens=chunk_tokens)
    kw.update(ecfg_kw)
    eng = ServeEngine(cfg, params, mem, EngineConfig(**kw), account_cfg=full)
    for p in prompts:
        eng.submit(list(p), max_new)
    rep = eng.run_until_idle()
    return eng, rep


def test_chunked_prefill_token_equivalence(f32_engine_setup):
    """A long prompt split across steps produces exactly the tokens the
    whole-prompt prefill produces."""
    full, cfg, params = f32_engine_setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, 400, n) for n in (41, 70, 23, 55)]
    eng_a, rep_a = _run_engine(full, cfg, params, None, prompts)
    eng_b, rep_b = _run_engine(full, cfg, params, 16, prompts)
    assert rep_a["finished"] == rep_b["finished"] == 4
    assert {k: list(v) for k, v in eng_a.outputs.items()} == \
           {k: list(v) for k, v in eng_b.outputs.items()}
    # chunking actually happened, and interleaved with decode rounds
    assert rep_b["prefill_chunks"] > rep_a["prefill_chunks"] == 4
    assert rep_b["steps"] > rep_a["steps"]


def test_chunked_prefill_admits_prompt_beyond_cache_len(small_engine_setup):
    """Prompts >> max_cache_len are admitted via chunked prefill (ring
    caches keep the attention tail)."""
    full, cfg, params = small_engine_setup
    rng = np.random.default_rng(4)
    long_prompt = rng.integers(2, 400, 210)  # max_cache_len is 96
    eng, rep = _run_engine(full, cfg, params, 32, [long_prompt], max_new=6)
    assert rep["finished"] == 1
    assert rep["tokens_generated"] >= 6
    assert rep["prefill_chunks"] >= 7
    assert rep["kv_live_pages"] == 0


def test_chunked_prefill_interleaves_decode(small_engine_setup):
    """While one request's prompt is still being chunked in, resident
    sessions keep decoding (bounded inter-token latency)."""
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=2, max_cache_len=96,
                                   weight_tier="mrm", kv_tier="mrm",
                                   eos_token=-1, chunk_tokens=16,
                                   max_prefills_per_step=1),
                      account_cfg=full)
    eng.submit(list(np.arange(2, 14)), 24)     # short: decoding quickly
    eng.submit(list(np.arange(2, 90)), 4)      # long: ~6 chunks
    saw_interleave = False
    while not eng.sched.idle and eng.steps < 200:
        out = eng.step()
        if out["prefill_tokens"] > 0 and out["decode_tokens"] > 0:
            saw_interleave = True
    assert saw_interleave
    assert eng.sched.stats.finished == 2


def test_prefix_hit_decodes_identically_to_cold_start(f32_engine_setup):
    """Acceptance: a radix prefix hit (slot caches seeded from the donor
    snapshot, prefill extended from the match boundary) must decode the
    exact tokens a cold start decodes — on the same engine (hit vs its own
    cold donor) and vs a fresh engine that never saw the prefix."""
    full, cfg, params = f32_engine_setup
    rng = np.random.default_rng(21)
    shared = list(rng.integers(2, 400, 40))
    prompts = [shared + list(rng.integers(2, 400, 8)) for _ in range(3)]

    eng, rep = _run_engine(full, cfg, params, 16, [], max_new=8,
                           page_tokens=8)
    for p in prompts:          # sequential: each later prompt hits
        eng.submit(list(p), 8)
        eng.run_until_idle()
    assert eng.kv.prefix_hits >= 2
    assert eng.prefill_tokens_skipped > 0   # compute actually shortened

    # cold baseline: same engine config, but the tree is drained between
    # requests, so every prompt prefills from scratch
    cold, _ = _run_engine(full, cfg, params, 16, [], max_new=8,
                          page_tokens=8)
    for p in prompts:
        cold.submit(list(p), 8)
        cold.run_until_idle()
        cold.kv.evict_prefixes()
        assert cold.kv.radix.n_nodes() == 0
    assert cold.kv.prefix_hits == 0
    assert {k: list(v) for k, v in eng.outputs.items()} == \
           {k: list(v) for k, v in cold.outputs.items()}


def test_wrapped_donor_never_donates_compute(f32_engine_setup):
    """A donor prompt that overflowed the smallest ring wrapped it — its
    snapshot lost the early positions a shorter borrower needs, so it must
    publish pages only (memory reuse), never a compute snapshot. The
    borrower prefills in full and decodes exactly like a cold start."""
    full, cfg, params = f32_engine_setup
    rng = np.random.default_rng(23)
    head = list(rng.integers(2, 400, 32))
    long_donor = head + list(rng.integers(2, 400, 108))  # 140 > ring (96)
    borrower = head + list(rng.integers(2, 400, 16))     # 48, shares 32

    eng, _ = _run_engine(full, cfg, params, 16, [], max_new=6, page_tokens=16)
    eng.submit(list(long_donor), 6)
    eng.run_until_idle()
    assert eng.kv.radix.n_nodes() > 0       # pages published...
    eng.submit(list(borrower), 6)
    eng.run_until_idle()
    assert eng.kv.prefix_hits >= 1          # ...and memory reuse happened
    assert eng.prefill_tokens_skipped == 0  # ...but no compute donation

    cold, _ = _run_engine(full, cfg, params, 16,
                          [long_donor, borrower], max_new=6,
                          page_tokens=16, prefix_caching=False)
    assert {k: list(v) for k, v in eng.outputs.items()} == \
           {k: list(v) for k, v in cold.outputs.items()}


def test_radix_reuse_cuts_prefill_and_kv_writes(f32_engine_setup):
    """Shared-prefix traffic: radix reuse must cut both the prefill tokens
    computed and the KV-tier write bytes at equal output tokens."""
    full, cfg, params = f32_engine_setup
    rng = np.random.default_rng(22)
    shared = list(rng.integers(2, 400, 48))
    prompts = [shared + list(rng.integers(2, 400, 16)) for _ in range(6)]
    kw = dict(page_tokens=16, weight_tier="hbm")
    eng_on, rep_on = _run_engine(full, cfg, params, 16, prompts, max_new=6, **kw)
    eng_off, rep_off = _run_engine(full, cfg, params, 16, prompts, max_new=6,
                                   prefix_caching=False, **kw)
    assert rep_on["tokens_generated"] == rep_off["tokens_generated"]
    assert {k: list(v) for k, v in eng_on.outputs.items()} == \
           {k: list(v) for k, v in eng_off.outputs.items()}
    # >= 30% fewer prefill tokens through the model...
    assert rep_on["prefill_tokens_computed"] <= \
        0.7 * rep_off["prefill_tokens_computed"]
    # ...and >= 30% fewer KV write bytes on the KV tier (weights in hbm)
    w_on = rep_on["memory"]["tiers"]["mrm"]["write_gb"]
    w_off = rep_off["memory"]["tiers"]["mrm"]["write_gb"]
    assert w_on <= 0.7 * w_off


def test_radix_hot_promotion_programs_retention(small_engine_setup):
    """Observed reuse programs retention: a node hit `hot_threshold` times
    is promoted (reprogram write metered, refresh deadline extended)."""
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=2, max_cache_len=96,
                                   weight_tier="hbm", kv_tier="mrm",
                                   eos_token=-1, page_tokens=16,
                                   chunk_tokens=16,
                                   radix_hot_threshold=2,
                                   radix_hot_retention_s=7200.0),
                      account_cfg=full)
    prompt = list(range(2, 50))
    for _ in range(5):
        eng.submit(list(prompt), 4)
        eng.run_until_idle()
    rep = eng.report()
    assert rep["prefix"]["retention_promotions"] >= 1
    assert rep["prefix"]["promoted_pages"] >= 1
    # reprogram writes are metered as refresh traffic, not steady writes
    assert rep["memory"]["tiers"]["mrm"]["refresh_gb"] > 0


def test_radix_auto_hot_tier_solves_placement(small_engine_setup):
    """radix_hot_tier='auto' runs the §4 placement solver over the
    engine's tiers and promotion migrates hot prefix pages there."""
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 4 << 30), "hbm": (HBM3E, 8 << 30),
                        "mrm_cold": (MRM_RRAM, 64 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=2, max_cache_len=96,
                                   weight_tier="hbm", kv_tier="mrm",
                                   eos_token=-1, page_tokens=16,
                                   radix_hot_threshold=2,
                                   radix_hot_tier="auto"),
                      account_cfg=full)
    assert eng.memplane.hot_tier in mem.devices
    prompt = list(range(2, 50))
    for _ in range(4):
        eng.submit(list(prompt), 4)
        eng.run_until_idle()
    rep = eng.report()
    assert rep["prefix"]["retention_promotions"] >= 1
    if eng.memplane.hot_tier != "mrm":
        assert rep["prefix"]["migrated_pages"] >= 1


def test_radix_cold_leaves_decay(small_engine_setup):
    """Unlocked leaves idle past cold_ttl_s decay out of the tree (soft
    state: an identical future prompt recomputes)."""
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=2, max_cache_len=96,
                                   weight_tier="hbm", kv_tier="mrm",
                                   eos_token=-1, page_tokens=16,
                                   radix_cold_ttl_s=0.5),
                      account_cfg=full)
    eng.submit(list(range(2, 50)), 4)
    eng.run_until_idle()
    assert eng.kv.radix.n_nodes() > 0
    # let simulated time pass the TTL; maintenance runs on advance
    eng.mem.advance(1.0)
    eng.kv.maintain()
    assert eng.kv.radix.n_nodes() == 0
    assert eng.kv.radix_stats.cold_decays >= 1


def test_whole_prompt_is_one_maximal_chunk(f32_engine_setup):
    """Single-path invariant (DESIGN.md §5): with ``chunk_tokens=None`` a
    ring-fitting prompt runs as exactly one chunk of the same unpadded
    chunked path, and decodes bit-identically (fp32) whether prefix
    caching is on or off — there is no separate padded whole-prompt mode
    left to diverge from."""
    full, cfg, params = f32_engine_setup
    rng = np.random.default_rng(31)
    prompts = [rng.integers(2, 400, n) for n in (11, 27, 40)]
    eng_on, rep_on = _run_engine(full, cfg, params, None, prompts, max_new=6)
    eng_off, rep_off = _run_engine(full, cfg, params, None, prompts, max_new=6,
                                   prefix_caching=False)
    # one chunk per prompt: the maximal first chunk IS the whole prompt
    assert rep_on["prefill_chunks"] == rep_off["prefill_chunks"] == 3
    assert {k: list(v) for k, v in eng_on.outputs.items()} == \
           {k: list(v) for k, v in eng_off.outputs.items()}


def test_unchunked_long_prompt_admitted_via_ring_chunks(small_engine_setup):
    """A prompt beyond max_cache_len no longer needs an explicit
    chunk_tokens (the legacy padded mode rejected it at submit): the one
    chunked path splits it into ring-bounded pieces — the ring caches
    keep the attention window's tail, exactly as decode does."""
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=2, max_cache_len=64,
                                   weight_tier="mrm", kv_tier="mrm",
                                   eos_token=-1),
                      account_cfg=full)
    eng.submit(list(range(2, 102)), 4)      # 100 tokens > 64-token rings
    rep = eng.run_until_idle()
    assert rep["finished"] == 1
    assert rep["prefill_chunks"] > 1        # really split, not padded
    assert rep["kv_live_pages"] == 0


def test_chunked_prefill_windowed_config_clamps_chunk():
    """Sliding-window layers have per-layer rings smaller than
    max_cache_len; a requested chunk larger than the smallest ring must be
    clamped (an oversized chunk would collide ring slots intra-chunk)."""
    full = get_config("gemma2-27b")   # alternating local(64)/global reduced
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=2, max_cache_len=128,
                                   weight_tier="mrm", kv_tier="mrm",
                                   eos_token=-1, chunk_tokens=128),
                      account_cfg=full)
    rng = np.random.default_rng(11)
    for _ in range(2):
        eng.submit(list(rng.integers(2, 400, 100)), 4)
    rep = eng.run_until_idle()
    assert rep["finished"] == 2
    assert rep["tokens_generated"] >= 8
    # the 128-token request was actually split (min ring is 64)
    assert rep["prefill_chunks"] > 2


# ---------------------------------------------------------------------------
# Capacity pressure: explicit eviction/spill/recompute, never silent drops
# ---------------------------------------------------------------------------


def _tiny_mem(kv_bytes=1 << 26):
    return MemorySystem({"mrm": (MRM_RRAM, kv_bytes), "hbm": (HBM3E, 16 << 30)})


def test_pressure_prefix_lru_eviction_no_silent_drops(small_engine_setup):
    """A capacity-constrained KV tier forces evictions; every failed
    allocation is resolved by an explicit decision and the ledger balances."""
    full, cfg, params = small_engine_setup
    eng = ServeEngine(cfg, params, _tiny_mem(),
                      EngineConfig(max_slots=3, max_cache_len=64,
                                   weight_tier="hbm", kv_tier="mrm",
                                   eos_token=-1, page_tokens=16,
                                   kv_pressure_policy="evict-lru",
                                   kv_high_watermark=0.9),
                      account_cfg=full)
    rng = np.random.default_rng(5)
    for _ in range(10):
        eng.submit(list(rng.integers(2, 400, 64)), 8)
    rep = eng.run_until_idle()
    p = rep["pressure"]
    assert rep["finished"] == 10
    assert p["events"] > 0, "tier was supposed to be capacity-constrained"
    assert p["events"] == (p["resolved_evict"] + p["resolved_spill"] +
                           p["resolved_recompute"] + p["unresolved"])
    assert p["unresolved"] == 0 and rep["dropped_allocs"] == 0


def test_pressure_spill_tier(small_engine_setup):
    """'spill' policy migrates overflow pages to the colder tier: the spill
    device sees KV write traffic it never sees in the uncontended run.
    (The KV tier is sized below the workload's true footprint — which
    shrank when prompt padding was deleted, since pad tokens no longer
    enter the paged KV.)"""
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 25), "hbm": (HBM3E, 16 << 30),
                        "ddr": (MRM_RRAM, 64 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=3, max_cache_len=64,
                                   weight_tier="hbm", kv_tier="mrm",
                                   eos_token=-1, prefix_caching=False,
                                   kv_pressure_policy="spill",
                                   kv_spill_tier="ddr"),
                      account_cfg=full)
    rng = np.random.default_rng(6)
    for _ in range(10):
        eng.submit(list(rng.integers(2, 400, 40)), 8)
    rep = eng.run_until_idle()
    p = rep["pressure"]
    assert p["events"] > 0 and p["resolved_spill"] > 0
    assert rep["dropped_allocs"] == 0
    assert mem.devices["ddr"].stats.write_bytes > 0


def test_pressure_recompute_policy_meters_recompute(small_engine_setup):
    """'recompute' drops soft state and re-materializes it on read, metered
    as recompute tokens (the paper's drop-and-recompute arm)."""
    full, cfg, params = small_engine_setup
    eng = ServeEngine(cfg, params, _tiny_mem(1 << 25),
                      EngineConfig(max_slots=3, max_cache_len=64,
                                   weight_tier="hbm", kv_tier="mrm",
                                   eos_token=-1, prefix_caching=False,
                                   kv_pressure_policy="recompute"),
                      account_cfg=full)
    rng = np.random.default_rng(7)
    for _ in range(8):
        eng.submit(list(rng.integers(2, 400, 40)), 8)
    rep = eng.run_until_idle()
    p = rep["pressure"]
    assert rep["finished"] == 8
    assert p["resolved_recompute"] > 0
    assert p["recompute_tokens"] > 0
    assert rep["dropped_allocs"] == 0


def test_kv_manager_legacy_none_policy_counts_drops():
    cfg = get_config("qwen3-8b")
    mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 22), "hbm": (HBM3E, 1 << 30)})
    kv = PagedKVManager(cfg, mem, "mrm", page_tokens=64, policy="none")
    kv.open_session(0)
    kv.append_tokens(0, 64 * 50)
    assert kv.dropped_allocs > 0  # legacy silent counting is opt-in only
    assert kv.pressure.unresolved == kv.dropped_allocs


# ---------------------------------------------------------------------------
# Cluster frontend: N replicas, affinity routing, conserving fleet report
# ---------------------------------------------------------------------------


def _mk_engine(full, cfg, params, **kw):
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    ecfg = dict(max_slots=2, max_cache_len=64, weight_tier="mrm",
                kv_tier="mrm", eos_token=-1, page_tokens=16)
    ecfg.update(kw)
    return ServeEngine(cfg, params, mem, EngineConfig(**ecfg), account_cfg=full)


def test_cluster_frontend_conserves_tokens_and_bytes(small_engine_setup):
    full, cfg, params = small_engine_setup
    fe = ClusterFrontend([_mk_engine(full, cfg, params) for _ in range(3)])
    rng = np.random.default_rng(8)
    n = 9
    for i in range(n):
        fe.submit(list(rng.integers(2, 400, 12)), 5)
    rep = fe.run_until_idle()
    assert rep["replicas"] == 3
    assert rep["finished"] == n
    assert rep["tokens_generated"] == n * 5
    # conservation: fleet aggregates == sum of replica reports
    assert rep["tokens_generated"] == sum(
        r["tokens_generated"] for r in rep["per_replica"])
    for tier in ("mrm", "hbm"):
        assert rep["tiers"][tier]["read_gb"] == pytest.approx(sum(
            r["memory"]["tiers"][tier]["read_gb"] for r in rep["per_replica"]))
        assert rep["tiers"][tier]["write_gb"] == pytest.approx(sum(
            r["memory"]["tiers"][tier]["write_gb"] for r in rep["per_replica"]))
    # shared simulated clock: all replicas ended at the fleet time
    assert all(abs(e.mem.now - rep["sim_time_s"]) < 1e-9 for e in fe.engines)
    # least-loaded routing spread work across every replica
    assert all(r["tokens_generated"] > 0 for r in rep["per_replica"])


def test_cluster_session_affinity_routes_sticky(small_engine_setup):
    full, cfg, params = small_engine_setup
    fe = ClusterFrontend([_mk_engine(full, cfg, params) for _ in range(3)])
    rng = np.random.default_rng(9)
    prompt = list(rng.integers(2, 400, 16))
    rids = {k: [fe.submit(list(prompt), 4, session_key=k) for _ in range(3)]
            for k in ("alice", "bob")}
    fe.run_until_idle()
    for k, ids in rids.items():
        assert len({fe.replica_of(r) for r in ids}) == 1
    # affinity means the repeated prompt hit the same replica's prefix index
    assert sum(e.kv.prefix_hits for e in fe.engines) >= 2


def test_cluster_radix_affinity_beats_key_hash(small_engine_setup):
    """A request sharing a served prompt's prefix must be routed to the
    replica holding it — whatever its session key hashes to — and arrive
    as a real prefix hit."""
    full, cfg, params = small_engine_setup
    fe = ClusterFrontend([_mk_engine(full, cfg, params, page_tokens=8)
                          for _ in range(3)])
    rng = np.random.default_rng(10)
    prompt = list(rng.integers(2, 400, 24))
    r0 = fe.submit(list(prompt), 4, session_key="alice")
    fe.run_until_idle()
    home = fe.replica_of(r0)
    assert fe.engines[home].kv.radix.n_nodes() > 0
    # different users, shared prefix (e.g. a common system prompt)
    rids = [fe.submit(list(prompt) + [500 + i], 4, session_key=f"user-{i}")
            for i in range(4)]
    fe.run_until_idle()
    assert all(fe.replica_of(r) == home for r in rids)
    assert fe.radix_routed >= 4
    assert fe.engines[home].kv.prefix_hits >= 4


def test_cluster_least_loaded_includes_kv_pressure(small_engine_setup):
    """A replica with a saturated KV tier must lose least-loaded ties to
    an equally-queued replica with free KV capacity."""
    full, cfg, params = small_engine_setup
    busy = _mk_engine(full, cfg, params, prefix_caching=False)
    idle = _mk_engine(full, cfg, params, prefix_caching=False)
    fe = ClusterFrontend([busy, idle])
    # occupy replica 0's KV with a live session (equal queue lengths)
    busy.kv.open_session(999)
    busy.kv.append_tokens(999, 512)
    assert fe.route() == 1  # tie on load -> KV pressure breaks it
    busy.kv.close_session(999)
    assert fe.route() == 0  # pressure gone -> index order


def test_ttft_itl_percentiles_reported(small_engine_setup):
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=2, max_cache_len=64,
                                   weight_tier="mrm", kv_tier="mrm",
                                   eos_token=-1),
                      account_cfg=full)
    rng = np.random.default_rng(12)
    for _ in range(4):
        eng.submit(list(rng.integers(2, 400, 12)), 6)
    rep = eng.run_until_idle()
    lat = rep["latency"]
    assert lat["n"] == 4
    assert lat["ttft_p50"] is not None and lat["ttft_p50"] > 0
    assert lat["itl_p50"] is not None and lat["itl_p50"] > 0
    assert lat["ttft_p95"] >= lat["ttft_p50"]
    # every finished request recorded a first-token time
    for r in eng.sched.latency:
        assert r["ttft"] is not None and r["ttft"] >= 0
    # the cluster fleet report pools the same records
    fe = ClusterFrontend([eng])
    assert fe.report()["latency"]["n"] == 4


# ---------------------------------------------------------------------------
# Per-tier step-latency model + O(1) region lookup
# ---------------------------------------------------------------------------


def test_step_latency_is_per_tier():
    """Traffic on a slow tier must not be charged at the fast tier's
    bandwidth: the slowest tier bounds the step."""
    from repro.core.memclass import get_technology
    mrm = get_technology("mrm_rram")
    mem = MemorySystem({"mrm": (mrm, 16 << 30), "hbm": (HBM3E, 16 << 30)})
    snap = mem.snapshot()
    rid = mem.write_region("mrm", "x", 6e9, expected_lifetime_s=10.0)
    step_s, per_tier = mem.step_latency_since(snap)
    expect = 6e9 / (mrm.write_bw_gbps * 1e9)
    assert step_s == pytest.approx(expect, rel=1e-6)
    assert per_tier["mrm"]["write_bytes"] == pytest.approx(6e9)
    assert per_tier["hbm"]["latency_s"] == 0.0
    # reads charged at read bandwidth, on the region's own tier, O(1) lookup
    snap = mem.snapshot()
    mem.read_region(rid, 8e9)
    step_s, per_tier = mem.step_latency_since(snap)
    assert step_s == pytest.approx(8e9 / (mrm.read_bw_gbps * 1e9), rel=1e-6)
    assert mem.region(rid).tier == "mrm"


def test_engine_vlm_frontend_stub():
    """internvl2-family serving: patch embeddings prepended by the stub
    frontend; positions account for the prefix."""
    full = get_config("internvl2-76b")
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    mem = _mem(32)
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=2, max_cache_len=96,
                                   weight_tier="mrm", kv_tier="mrm",
                                   eos_token=-1, prefix_caching=False),
                      account_cfg=full)
    rng = np.random.default_rng(1)
    for _ in range(2):
        eng.submit(list(rng.integers(2, cfg.vocab_size, 20)), max_new_tokens=4)
    rep = eng.run_until_idle()
    assert rep["finished"] == 2
    # KV accounting includes the frontend prefix tokens
    assert eng.kv.prefix_tokens_reused == 0
