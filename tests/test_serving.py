"""Serving stack: paged KV manager invariants, scheduler conservation
(hypothesis), end-to-end engine runs with paper-claim validation."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.core.memclass import HBM3E, MRM_RRAM
from repro.core.simulator import MemorySystem
from repro.models import init_params
from repro.serving import (ContinuousBatchScheduler, EngineConfig,
                           PagedKVManager, Request, ServeEngine)


def _mem(gb=8):
    return MemorySystem({"mrm": (MRM_RRAM, gb << 30), "hbm": (HBM3E, gb << 30)})


# ---------------------------------------------------------------------------
# Paged KV manager
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(min_value=1, max_value=300), min_size=1, max_size=20))
@settings(max_examples=30, deadline=None)
def test_paged_kv_token_accounting(appends):
    cfg = get_config("qwen3-8b")
    kv = PagedKVManager(cfg, _mem(), "mrm", page_tokens=128)
    kv.open_session(0)
    total = 0
    for n in appends:
        kv.append_tokens(0, n)
        total += n
    s = kv.sessions[0]
    assert s.tokens == total
    assert sum(p.n_tokens for p in s.pages) == total
    # every page except possibly the last is sealed exactly at page_tokens
    for p in s.pages[:-1]:
        assert p.sealed and p.n_tokens == 128
    assert s.pages[-1].n_tokens <= 128
    kv.close_session(0)
    assert kv.live_pages() == 0


def test_paged_kv_read_all_bytes():
    cfg = get_config("qwen3-8b")
    kv = PagedKVManager(cfg, _mem(), "mrm", page_tokens=64)
    kv.open_session(1)
    kv.append_tokens(1, 100)
    got = kv.read_all(1)
    assert got == 100 * cfg.kv_bytes_per_token()


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


@given(st.integers(1, 8), st.integers(1, 30), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_scheduler_conservation(slots, n_requests, max_prefills):
    """Every submitted request is eventually admitted exactly once and
    finished exactly once; slots never over-subscribe."""
    sched = ContinuousBatchScheduler(slots, max_prefills)
    for i in range(n_requests):
        sched.submit(Request(i, [1, 2, 3], 4, 0.0))
    seen = set()
    for step in range(500):
        for slot, req in sched.admissions():
            assert req.request_id not in seen
            seen.add(req.request_id)
        assert len(sched.active) <= slots
        for slot in list(sched.decode_slots()):
            req = sched.active[slot]
            req.generated += 1
            if req.generated >= req.max_new_tokens:
                sched.finish(slot, float(step))
        if sched.idle:
            break
    assert sched.idle
    assert len(seen) == n_requests
    assert sched.stats.finished == n_requests


# ---------------------------------------------------------------------------
# Engine end-to-end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_engine_setup():
    full = get_config("deepseek-7b")
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    return full, cfg, params


def test_engine_end_to_end_and_paper_claims(small_engine_setup):
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=3, max_cache_len=96,
                                   weight_tier="mrm", kv_tier="mrm",
                                   expected_session_s=5.0, eos_token=-1),
                      account_cfg=full)
    rng = np.random.default_rng(0)
    for _ in range(5):
        eng.submit(list(rng.integers(2, 400, rng.integers(6, 30))), 8)
    rep = eng.run_until_idle()
    assert rep["finished"] == 5
    assert rep["tokens_generated"] >= 5 * 8
    # paper §2.2: decode-dominated read:write >> 1000:1, sequential
    assert rep["steady_rw_ratio"] > 1000
    assert rep["memory"]["tiers"]["mrm"]["seq_fraction"] > 0.99
    assert rep["kv_live_pages"] == 0  # soft state dropped at session end


def test_engine_deterministic(small_engine_setup):
    full, cfg, params = small_engine_setup
    outs = []
    for _ in range(2):
        mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
        eng = ServeEngine(cfg, params, mem,
                          EngineConfig(max_slots=2, max_cache_len=64,
                                       weight_tier="mrm", kv_tier="mrm"),
                          account_cfg=full)
        rng = np.random.default_rng(7)
        for _ in range(3):
            eng.submit(list(rng.integers(2, 400, 12)), 6)
        eng.run_until_idle()
        outs.append({k: list(v) for k, v in eng.outputs.items()})
    assert outs[0] == outs[1]


def test_engine_refresh_fires_during_long_sessions(small_engine_setup):
    """KV pages written with short DCM retention must get refreshed while
    their session is still live."""
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=1, max_cache_len=96,
                                   weight_tier="hbm", kv_tier="mrm",
                                   expected_session_s=0.02),
                      account_cfg=full)
    eng.submit(list(np.arange(2, 34)), 40)
    rep = eng.run_until_idle()
    assert rep["memory"]["refresh_stats"]["refresh"] >= 1


# ---------------------------------------------------------------------------
# Beyond-paper features: prefix caching [53], weight redeploy wear (Fig. 1)
# ---------------------------------------------------------------------------


def test_prefix_caching_shares_pages():
    cfg = get_config("qwen3-8b")
    mem = _mem(32)
    kv = PagedKVManager(cfg, mem, "mrm", page_tokens=64)
    w0 = mem.devices["mrm"].stats.write_bytes
    kv.open_session(0, prefix_key="promptA")
    kv.append_tokens(0, 200)          # 3 pages: 64+64+64 sealed + 8 open
    kv.register_prefix(0, "promptA")
    w_first = mem.devices["mrm"].stats.write_bytes - w0
    s1 = kv.open_session(1, prefix_key="promptA")
    assert s1.shared_prefix_pages == 3 and s1.tokens == 192
    kv.append_tokens(1, 200 - s1.tokens)  # only the tail is written
    w_second = mem.devices["mrm"].stats.write_bytes - w0 - w_first
    assert w_second < w_first * 0.2
    assert kv.prefix_hits == 1 and kv.prefix_tokens_reused == 192
    # shared pages survive the first session's close, die with eviction
    kv.close_session(0)
    assert kv.read_all(1) == 200 * cfg.kv_bytes_per_token()
    kv.close_session(1)
    kv.evict_prefix("promptA")
    assert kv.live_pages() == 0


def test_engine_prefix_caching_end_to_end(small_engine_setup):
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=2, max_cache_len=96,
                                   weight_tier="mrm", kv_tier="mrm",
                                   eos_token=-1, prefix_caching=True),
                      account_cfg=full)
    prompt = list(range(2, 70))  # 68 tokens -> padded to 128? bucket -> 96
    for _ in range(4):
        eng.submit(list(prompt), 4)
    rep = eng.run_until_idle()
    assert rep["finished"] == 4
    assert rep["prefix_hits"] >= 3
    assert rep["prefix_tokens_reused"] > 0
    # identical prompts must still produce identical outputs
    outs = [tuple(v) for v in eng.outputs.values()]
    assert len(set(outs)) == 1


def test_weight_redeploy_wear_accounting(small_engine_setup):
    """Fig. 1's weight-update endurance bars, measured from the system:
    each redeploy rewrites the weight region once."""
    full, cfg, params = small_engine_setup
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=1, max_cache_len=64,
                                   weight_tier="mrm", kv_tier="hbm"),
                      account_cfg=full)
    w0 = mem.devices["mrm"].stats.write_bytes
    for _ in range(5):
        eng.redeploy_weights()
    # 5 full weight-region rewrites hit the device...
    assert mem.devices["mrm"].stats.write_bytes - w0 >= 5 * eng.weight_bytes
    # ...and the software wear-leveller spreads them (max/mean stays small)
    assert mem.devices["mrm"].wear.wear_ratio < 3.0
    # lifetime projection at an hourly update cadence stays > 5 years for MRM
    rate = eng.weight_bytes / 3600.0
    proj = mem.devices["mrm"].wear.project_lifetime_s(rate, 0.0)
    from repro.core.memclass import YEAR
    assert proj > 5 * YEAR


# ---------------------------------------------------------------------------
# Modality coverage: multi-codebook audio + VLM serving paths
# ---------------------------------------------------------------------------


def test_engine_multicodebook_audio():
    """musicgen-family serving: (B, 1, K) tokens, K LM heads, greedy per
    codebook."""
    full = get_config("musicgen-large")
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    mem = _mem(32)
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=2, max_cache_len=64,
                                   weight_tier="mrm", kv_tier="mrm",
                                   eos_token=-1, prefix_caching=False),
                      account_cfg=full)
    rng = np.random.default_rng(0)
    for _ in range(3):
        prompt = [list(rng.integers(0, cfg.vocab_size, cfg.n_codebooks))
                  for _ in range(12)]
        eng.submit(prompt, max_new_tokens=5)
    rep = eng.run_until_idle()
    assert rep["finished"] == 3
    assert rep["tokens_generated"] >= 15
    assert eng.last_tokens.shape[-1] == cfg.n_codebooks


def test_engine_vlm_frontend_stub():
    """internvl2-family serving: patch embeddings prepended by the stub
    frontend; positions account for the prefix."""
    full = get_config("internvl2-76b")
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    mem = _mem(32)
    eng = ServeEngine(cfg, params, mem,
                      EngineConfig(max_slots=2, max_cache_len=96,
                                   weight_tier="mrm", kv_tier="mrm",
                                   eos_token=-1, prefix_caching=False),
                      account_cfg=full)
    rng = np.random.default_rng(1)
    for _ in range(2):
        eng.submit(list(rng.integers(2, cfg.vocab_size, 20)), max_new_tokens=4)
    rep = eng.run_until_idle()
    assert rep["finished"] == 2
    # KV accounting includes the frontend prefix tokens
    assert eng.kv.prefix_tokens_reused == 0
