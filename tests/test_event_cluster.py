"""Event-driven clock discipline on the *real* serving cluster
(DESIGN.md §12): the event queue drives actual ``ServeEngine`` replicas —
decoded tokens must match the lockstep compat driver bit-for-bit, event
traces must replay identically, non-quiescence must raise or flag
(never silently return), and queued-request abandonment must drop
requests without leaking queue entries.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.memclass import HBM3E, MRM_RRAM
from repro.core.simulator import MemorySystem
from repro.serving import (ClusterFrontend, EngineConfig, NonQuiescentError,
                           ServeEngine)


@pytest.fixture(scope="module")
def setup():
    from repro.models import init_params
    full = get_config("deepseek-7b")
    cfg = reduced(full)
    return full, cfg, init_params(cfg, jax.random.key(0))


def _mk_engine(full, cfg, params, **kw):
    mem = MemorySystem({"mrm": (MRM_RRAM, 64 << 30), "hbm": (HBM3E, 16 << 30)})
    ecfg = dict(max_slots=2, max_cache_len=96, weight_tier="hbm",
                kv_tier="mrm", eos_token=-1, chunk_tokens=16, page_tokens=16)
    ecfg.update(kw)
    return ServeEngine(cfg, params, mem, EngineConfig(**ecfg), account_cfg=full)


def _mk_cluster(setup, n=2, clock_mode="event", **kw):
    full, cfg, params = setup
    engines = [_mk_engine(full, cfg, params) for _ in range(n)]
    return ClusterFrontend(engines, clock_mode=clock_mode, **kw)


def _prompts(cfg, n=3, shared=32, tail=16, seed=0):
    rng = np.random.default_rng(seed)
    head = list(rng.integers(2, cfg.vocab_size, shared))
    return [head + list(rng.integers(2, cfg.vocab_size, tail))
            for _ in range(n)]


# ---------------------------------------------------------------------------
# lockstep <-> event equivalence on real engines
# ---------------------------------------------------------------------------


def test_event_clock_matches_lockstep_tokens(setup):
    _, cfg, _ = setup
    prompts = _prompts(cfg)

    def run(clock_mode):
        fe = _mk_cluster(setup, clock_mode=clock_mode,
                         migrate_prefixes=True, migrate_load_gap=-1,
                         record_trace=True)
        # wave 1 establishes the shared head on one replica; the fan-out
        # wave then hits the directory and migrates (fleet_reuse shape)
        rids = [fe.submit(list(prompts[0]), 6, session_key="s0")]
        fe.run_until_idle()
        rids += [fe.submit(list(p), 6, session_key=f"s{i}")
                 for i, p in enumerate(prompts[1:], start=1)]
        rep = fe.run_until_idle()
        return fe, rep, [list(fe.output(r)) for r in rids]

    _, rep_ev, toks_ev = run("event")
    _, rep_ls, toks_ls = run("lockstep")
    assert toks_ev == toks_ls, "event clock changed decoded tokens"
    assert rep_ev["finished"] == rep_ls["finished"] == len(prompts)
    assert rep_ev["quiesced"] and rep_ls["quiesced"]
    assert rep_ev["clock_mode"] == "event"
    assert rep_ls["clock_mode"] == "lockstep"
    # migration still flowed through the event-scheduled delivery path
    assert rep_ev["interconnect"]["migrations"] > 0


def test_event_trace_is_replay_identical(setup):
    _, cfg, _ = setup
    prompts = _prompts(cfg)

    def run():
        fe = _mk_cluster(setup, migrate_prefixes=True, record_trace=True)
        for i, p in enumerate(prompts):
            fe.submit(list(p), 4, session_key=f"s{i}")
        rep = fe.run_until_idle()
        return rep["trace"]["digest"], fe.trace.events

    d1, ev1 = run()
    d2, ev2 = run()
    assert d1 == d2 and ev1 == ev2
    # per-replica event times never run backwards
    last = {}
    for (t, kind, replica, key, info) in ev1:
        assert t >= last.get(replica, 0.0) - 1e-12
        last[replica] = t


# ---------------------------------------------------------------------------
# non-quiescence is loud (the silent-max_steps fix)
# ---------------------------------------------------------------------------


def test_engine_stall_raises_with_partial_report(setup):
    full, cfg, params = setup
    eng = _mk_engine(full, cfg, params)
    eng.submit(list(range(2, 34)), 8)
    with pytest.raises(NonQuiescentError, match="not quiescent") as ei:
        eng.run_until_idle(max_steps=1)
    assert ei.value.report["quiesced"] is False
    assert ei.value.report["pending_requests"] >= 1


def test_engine_stall_report_mode_flags_and_resumes(setup):
    full, cfg, params = setup
    eng = _mk_engine(full, cfg, params)
    eng.submit(list(range(2, 34)), 8)
    rep = eng.run_until_idle(max_steps=1, on_stall="report")
    assert rep["quiesced"] is False and rep["pending_requests"] >= 1
    rep = eng.run_until_idle()
    assert rep["quiesced"] is True and rep["pending_requests"] == 0
    assert rep["finished"] == 1


@pytest.mark.parametrize("clock_mode", ["lockstep", "event"])
def test_cluster_stall_paths(setup, clock_mode):
    _, cfg, _ = setup
    fe = _mk_cluster(setup, clock_mode=clock_mode)
    fe.submit(_prompts(cfg, n=1)[0], 8, session_key="a")
    budget = dict(max_steps=1) if clock_mode == "lockstep" else \
        dict(max_steps=1)
    with pytest.raises(NonQuiescentError):
        fe.run_until_idle(**budget)
    rep = fe.run_until_idle(on_stall="report", **budget)
    assert rep["quiesced"] is False
    rep = fe.run_until_idle()
    assert rep["quiesced"] is True and rep["finished"] == 1


# ---------------------------------------------------------------------------
# abandonment on the real scheduler
# ---------------------------------------------------------------------------


def test_engine_abandons_timed_out_queued_requests(setup):
    full, cfg, params = setup
    eng = _mk_engine(full, cfg, params, max_slots=1,
                     abandon_after_s=1e-6)
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(list(rng.integers(2, cfg.vocab_size, 24)), 8)
    rep = eng.run_until_idle()
    assert rep["quiesced"] is True
    # slot holder finishes; the queue drains by timeout, never leaks
    assert rep["finished"] >= 1 and rep["abandoned"] >= 1
    assert rep["finished"] + rep["abandoned"] == 3
    assert rep["pending_requests"] == 0


def test_cluster_event_abandon_only_hits_queued_requests(setup):
    full, cfg, params = setup
    fe = ClusterFrontend([_mk_engine(full, cfg, params, max_slots=1)],
                         clock_mode="event")
    prompts = _prompts(cfg, n=3, seed=1)
    # generous timeout: every request admits before its deadline
    rids = [fe.submit(list(p), 4, session_key=f"s{i}", abandon_after_s=1e9)
            for i, p in enumerate(prompts)]
    rep = fe.run_until_idle()
    assert rep["finished"] == 3 and rep["abandoned"] == 0
    assert all(len(list(fe.output(r))) == 4 for r in rids)
