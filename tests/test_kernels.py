"""Pallas kernel sweeps: shapes x dtypes x feature flags, allclose against
the pure-jnp oracles (interpret mode on CPU; BlockSpec tiling exercised)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.ssd_scan import ssd_scan, ssd_scan_ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (1, 64, 2, 2, 16), (2, 128, 4, 2, 32), (1, 256, 8, 1, 64), (2, 96, 6, 3, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cap,window", [(None, None), (50.0, None), (None, 48)])
def test_flash_attention_sweep(B, S, H, Hkv, D, dtype, cap, window):
    q = jnp.asarray(RNG.normal(0, 1, (B, S, H, D)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (B, S, Hkv, D)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (B, S, Hkv, D)), dtype)
    out = flash_attention(q, k, v, scale=D**-0.5, cap=cap, window=window,
                          q_block=32, kv_block=32)
    G = H // Hkv
    qf = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 3, 1, 4).reshape(B * H, S, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, D)
    ref = attention_ref(qf.astype(jnp.float32), kf.astype(jnp.float32),
                        vf.astype(jnp.float32), scale=D**-0.5, cap=cap, window=window)
    ref = ref.reshape(B, Hkv, G, S, D).transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               **_tol(dtype))


@pytest.mark.parametrize("B,C,H,Hkv,D,page", [
    (2, 96, 4, 2, 16, 32), (1, 128, 8, 1, 32, 64), (3, 64, 6, 3, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, C, H, Hkv, D, page, dtype):
    kc = jnp.asarray(RNG.normal(0, 1, (B, C, Hkv, D)), dtype)
    vc = jnp.asarray(RNG.normal(0, 1, (B, C, Hkv, D)), dtype)
    pos = jnp.asarray(np.where(RNG.random((B, C)) < 0.8,
                               RNG.integers(0, 70, (B, C)), -1), jnp.int32)
    q = jnp.asarray(RNG.normal(0, 1, (B, 1, H, D)), dtype)
    cur = jnp.asarray(RNG.integers(40, 70, (B,)), jnp.int32)
    out = decode_attention(q, kc, vc, pos, cur, scale=D**-0.5, page_size=page)
    G = H // Hkv
    qf = q.reshape(B, Hkv, G, D).reshape(B * Hkv, G, D)
    kf = kc.transpose(0, 2, 1, 3).reshape(B * Hkv, C, D)
    vf = vc.transpose(0, 2, 1, 3).reshape(B * Hkv, C, D)
    posf = jnp.repeat(pos[:, None, :], Hkv, 1).reshape(B * Hkv, C)
    curf = jnp.repeat(cur[:, None], Hkv, 1).reshape(B * Hkv)
    ref = decode_attention_ref(qf.astype(jnp.float32), kf.astype(jnp.float32),
                               vf.astype(jnp.float32), posf, curf, scale=D**-0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32).reshape(B, 1, H, D),
                               np.asarray(ref).reshape(B, 1, H, D), **_tol(dtype))


def test_decode_attention_windowed():
    B, C, H, D = 1, 64, 2, 16
    kc = jnp.asarray(RNG.normal(0, 1, (B, C, H, D)), jnp.float32)
    vc = jnp.asarray(RNG.normal(0, 1, (B, C, H, D)), jnp.float32)
    pos = jnp.arange(C, dtype=jnp.int32)[None]
    q = jnp.asarray(RNG.normal(0, 1, (B, 1, H, D)), jnp.float32)
    cur = jnp.asarray([C - 1], jnp.int32)
    out = decode_attention(q, kc, vc, pos, cur, scale=D**-0.5, window=16, page_size=16)
    ref = decode_attention_ref(
        q.reshape(B, 1, H, D).transpose(0, 2, 1, 3).reshape(B * H, 1, D),
        kc.transpose(0, 2, 1, 3).reshape(B * H, C, D),
        vc.transpose(0, 2, 1, 3).reshape(B * H, C, D),
        jnp.repeat(pos[:, None, :], H, 1).reshape(B * H, C),
        jnp.repeat(cur[:, None], H, 1).reshape(B * H),
        scale=D**-0.5, window=16)
    np.testing.assert_allclose(np.asarray(out).reshape(B * H, 1, D),
                               np.asarray(ref), atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("B,S,H,G,P,N,chunk", [
    (1, 64, 2, 1, 8, 16, 16), (2, 128, 4, 2, 16, 8, 32), (1, 96, 3, 1, 8, 8, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_scan_sweep(B, S, H, G, P, N, chunk, dtype):
    x = jnp.asarray(RNG.normal(0, 1, (B, S, H, P)), dtype)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (B, S, H)), dtype)
    a = -jnp.asarray(RNG.uniform(0.5, 8, (H,)), jnp.float32)
    b = jnp.asarray(RNG.normal(0, 1, (B, S, G, N)), dtype)
    c = jnp.asarray(RNG.normal(0, 1, (B, S, G, N)), dtype)
    y = ssd_scan(x, dt, a, b, c, chunk=chunk)
    rep = H // G
    xdt = (x * dt[..., None]).transpose(0, 2, 1, 3).reshape(B * H, S, P)
    da = (dt * a[None, None, :]).transpose(0, 2, 1).reshape(B * H, S)
    bh = jnp.repeat(b, rep, 2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    ch = jnp.repeat(c, rep, 2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    ref = ssd_scan_ref(xdt.astype(jnp.float32), da.astype(jnp.float32),
                       bh.astype(jnp.float32), ch.astype(jnp.float32))
    ref = ref.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_ssd_kernel_cross_validates_model_path():
    """Kernel vs the model's chunked SSD (independent implementations)."""
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 2, 64, 4, 8, 16
    x = jnp.asarray(RNG.normal(0, 1, (B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (B, S, H)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(1, 8, (H,)), jnp.float32)
    b = jnp.asarray(RNG.normal(0, 1, (B, S, 1, N)), jnp.float32)
    c = jnp.asarray(RNG.normal(0, 1, (B, S, 1, N)), jnp.float32)
    y_kernel = ssd_scan(x, dt, a, b, c, chunk=32)
    y_model, _ = ssd_chunked(x, dt, a, b, c, chunk=32)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=2e-4, rtol=2e-4)
